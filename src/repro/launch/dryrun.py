import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the production mesh, the sharded
ShapeDtypeStruct inputs, jits the right step (train / prefill / serve),
``.lower().compile()``s it, prints ``memory_analysis()`` /
``cost_analysis()``, and dumps the roofline terms to a JSON results file
consumed by EXPERIMENTS.md and benchmarks/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
      --shape train_4k --multi-pod            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all               # 1-pod cells
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod   # 2-pod cells
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.analysis import roofline
from repro.core.config import GemminiConfig
from repro.core.generator import elaborate
from repro.launch import steps as steps_lib
from repro.launch.mesh import activate_mesh, make_production_mesh
from repro.optim import adamw

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def _engine():
    return elaborate(GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                   output_dtype="bf16"), "xla")


def run_cell(arch: str, shape: str, multi_pod: bool, *, verbose: bool = True,
             variant: str = "baseline"):
    cfg = configs.get(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = 512 if multi_pod else 256
    engine = _engine()
    spec = steps_lib.input_specs(cfg, shape, mesh)
    kind = spec["kind"]

    with activate_mesh(mesh):
        if kind == "train":
            fn = steps_lib.make_train_step(
                engine, cfg, adamw.AdamWConfig(), mesh,
                batch=spec["batch"], seq=spec["seq"])
        elif kind == "prefill":
            fn = steps_lib.make_prefill_step(engine, cfg, mesh,
                                             batch=spec["batch"],
                                             seq=spec["seq"])
        else:
            fn = steps_lib.make_serve_step(engine, cfg, mesh,
                                           batch=spec["batch"],
                                           max_seq=spec["seq"])
        t0 = time.time()
        lowered = jax.jit(fn).lower(*spec["args"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mf = roofline.model_flops_for(cfg, kind, spec["batch"], spec["seq"])
    rl = roofline.analyze(compiled, None, arch=arch, shape=shape,
                          mesh_name=mesh_name, n_chips=n_chips,
                          model_flops=mf)
    rl.min_bytes = roofline.model_min_bytes_for(cfg, kind, spec["batch"],
                                                spec["seq"])
    row = rl.row()
    row.update(lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
               kind=kind, variant=variant)
    ma = compiled.memory_analysis()
    row["memory_analysis"] = dict(
        argument_size=ma.argument_size_in_bytes,
        output_size=ma.output_size_in_bytes,
        temp_size=ma.temp_size_in_bytes,
        generated_code_size=ma.generated_code_size_in_bytes)
    if verbose:
        print(f"[{arch} x {shape} x {mesh_name}] kind={kind}")
        print(f"  lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/1e9:.2f}GB"
              f" out={ma.output_size_in_bytes/1e9:.2f}GB"
              f" temp={ma.temp_size_in_bytes/1e9:.2f}GB per device")
        print(f"  cost_analysis: flops/dev={rl.flops:.3e}"
              f" bytes/dev={rl.hbm_bytes:.3e}")
        print(f"  roofline: compute={rl.t_compute*1e3:.2f}ms"
              f" memory={rl.t_memory*1e3:.2f}ms"
              f" collective={rl.t_collective*1e3:.2f}ms"
              f" -> {rl.bottleneck}-bound"
              f" useful={rl.useful_ratio:.2f}"
              f" roofline_frac={rl.roofline_fraction:.3f}")
    return row


def save_row(row, outdir: str):
    os.makedirs(outdir, exist_ok=True)
    name = f"{row['variant']}_{row['arch']}_{row['shape']}_{row['mesh']}.json"
    with open(os.path.join(outdir, name), "w") as f:
        json.dump(row, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--opt", action="append", default=[],
                    help="optimization flag name[=value] (repeatable); "
                         "see repro.core.flags")
    ap.add_argument("--outdir", default=os.path.abspath(RESULTS))
    args = ap.parse_args()

    from repro.core import flags
    for spec in args.opt:
        flags.parse_opt(spec)

    cells = []
    if args.all:
        for arch in configs.names():
            for shape in configs.shapes_for(arch):
                cells.append((arch, shape))
    else:
        shapes = [args.shape] if args.shape else configs.shapes_for(args.arch)
        cells = [(args.arch, s) for s in shapes]

    failures = []
    for arch, shape in cells:
        try:
            row = run_cell(arch, shape, args.multi_pod,
                           variant=args.variant)
            save_row(row, args.outdir)
        except Exception as e:  # noqa
            failures.append((arch, shape, repr(e)))
            print(f"[FAIL {arch} x {shape}]")
            traceback.print_exc()
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells OK")
    for f in failures:
        print("FAILED:", f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
