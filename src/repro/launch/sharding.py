"""Sharding rules: parameter/optimizer/activation PartitionSpecs.

Strategy (DESIGN.md section 4): TP over ``model`` for weights (head / ff /
expert dims), DP over ``pod``x``data`` for the batch, ZeRO-1 over the DP
domain for optimizer state, sequence-sharded storage for the layer-scan
residual (Megatron-style SP), and sequence-sharded KV caches for decode.

Divisibility-safe by construction: every rule asks :func:`_first_divisible`
for the highest-priority tensor dim actually divisible by the mesh-axis
size, falling back to replication -- this is what makes one rule set work
across all 10 archs (56 heads, 40 experts, odd vocabs, ...).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as mesh_lib
from repro.models.transformer import ModelConfig


def _axis_size(mesh, axis) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _first_divisible(shape: Sequence[int], mesh, axis,
                     priority: Sequence[int]) -> Optional[int]:
    n = _axis_size(mesh, axis)
    for dim in priority:
        if dim < len(shape) and shape[dim] % n == 0 and shape[dim] >= n:
            return dim
    return None


def _spec_with(shape, ndim, mesh, axis, priority) -> P:
    dim = _first_divisible(shape, mesh, axis, priority)
    entries: list = [None] * ndim
    if dim is not None:
        entries[dim] = axis
    return P(*entries)


# ---------------------------------------------------------------------------
# parameter rules (path-pattern -> dim priority for the `model` axis)
# ---------------------------------------------------------------------------
def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# priority lists are dim indices *from the right* (negative), so the same
# rule covers stacked (L, ...) block params and unstacked params.
_PARAM_RULES: Tuple[Tuple[str, Tuple[int, ...]], ...] = (
    ("attn/wq",   (-1, -2)),
    ("attn/wk",   (-1, -2)),
    ("attn/wv",   (-1, -2)),
    ("attn/wo",   (-2, -1)),
    ("attn/bq",   (-1,)),
    ("attn/bk",   (-1,)),
    ("attn/bv",   (-1,)),
    ("mlp/wi",    (-1, -2)),
    ("mlp/wg",    (-1, -2)),
    ("mlp/wo",    (-2, -1)),
    ("moe/router", (-1,)),
    ("moe/wi",    (-3, -1)),     # expert dim (EP), else ff
    ("moe/wg",    (-3, -1)),
    ("moe/wo",    (-3, -2)),
    ("shared/wi", (-1, -2)),
    ("shared/wg", (-1, -2)),
    ("shared/wo", (-2, -1)),
    ("mamba/in_proj",  (-1, -2)),
    ("mamba/out_proj", (-2, -1)),
    ("mamba/conv_w",   (-1,)),
    ("mamba/a_log",    (-1,)),
    ("mamba/d_skip",   (-1,)),
    ("mamba/dt_bias",  (-1,)),
    ("heads",     (-1, -2)),     # musicgen output heads: vocab else d
    ("unembed",   (-1, -2)),
    ("embed",     (-2, -1)),     # vocab else d_model
    ("meta_tokens", ()),
)


def param_spec(path, leaf, mesh) -> P:
    ps = _path_str(path)
    shape = leaf.shape
    for pat, prio in _PARAM_RULES:
        if pat in ps:
            prio_abs = [len(shape) + d for d in prio]
            return _spec_with(shape, len(shape), mesh, "model", prio_abs)
    return P()   # norms, scalars: replicated


def param_specs(params_shape, mesh) -> Any:
    """Pytree of PartitionSpecs for a params (ShapeDtypeStruct) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, mesh), params_shape)


def opt_state_specs(params_shape, mesh) -> Dict[str, Any]:
    """ZeRO-1: m/v take the param spec extended with a DP-axis shard on the
    highest-priority still-unsharded divisible dim."""
    dp = mesh_lib.data_axes(mesh)
    dp_ax = dp if len(dp) > 1 else dp[0]

    def mv_spec(path, leaf):
        base = param_spec(path, leaf, mesh)
        entries = list(base) + [None] * (len(leaf.shape) - len(base))
        # try to extend with dp on an unsharded divisible dim (prefer last
        # dims: big vocab/ff/d axes; avoid dim 0 = layer stack, usually odd)
        n = _axis_size(mesh, dp_ax)
        for dim in range(len(leaf.shape) - 1, -1, -1):
            if entries[dim] is None and leaf.shape[dim] % n == 0 \
                    and leaf.shape[dim] >= n:
                entries[dim] = dp_ax
                break
        return P(*entries)

    mv = jax.tree_util.tree_map_with_path(mv_spec, params_shape)
    return {"m": mv, "v": mv, "count": P()}


# ---------------------------------------------------------------------------
# activation / batch / cache rules
# ---------------------------------------------------------------------------
def data_axis(mesh):
    """The mesh axis (name or tuple of names) batch-like dims shard over:
    what an ``ExecutionContext``/``GemminiInstance.with_mesh`` partitions
    its kernels' leading dims by (the same axes every batch rule below
    uses)."""
    dp = mesh_lib.data_axes(mesh)
    return dp if len(dp) > 1 else dp[0]


def batch_spec(mesh) -> P:
    return P(data_axis(mesh))


def tokens_spec(mesh, batch: int, ndim: int = 2) -> P:
    """(B, T[, n_q]) token arrays; replicate if B not divisible (long_500k)."""
    dp = mesh_lib.data_axes(mesh)
    dp_ax = dp if len(dp) > 1 else dp[0]
    if batch % _axis_size(mesh, dp_ax) != 0:
        return P(*([None] * ndim))
    return P(*([dp_ax] + [None] * (ndim - 1)))


def residual_spec(cfg: ModelConfig, mesh, batch: int, seq: int) -> P:
    """Layer-scan carry (B, T, D): DP batch + sequence-parallel T storage."""
    dp = mesh_lib.data_axes(mesh)
    dp_ax = dp if len(dp) > 1 else dp[0]
    b_ok = batch % _axis_size(mesh, dp_ax) == 0
    t_ok = seq % mesh.shape["model"] == 0 and seq >= mesh.shape["model"]
    return P(dp_ax if b_ok else None, "model" if t_ok else None, None)


def logits_spec(cfg: ModelConfig, mesh, batch: int) -> P:
    dp = mesh_lib.data_axes(mesh)
    dp_ax = dp if len(dp) > 1 else dp[0]
    b_ok = batch % _axis_size(mesh, dp_ax) == 0
    v_ok = cfg.vocab % mesh.shape["model"] == 0
    base = [dp_ax if b_ok else None, None]
    if cfg.n_codebooks > 1:
        base.append(None)
    base.append("model" if v_ok else None)
    return P(*base)


def decode_state_specs(cfg: ModelConfig, mesh, batch: int, max_seq: int
                       ) -> Any:
    """Specs for transformer.DecodeState (kv_k, kv_v, conv, ssm, pos)."""
    dp = mesh_lib.data_axes(mesh)
    dp_ax = dp if len(dp) > 1 else dp[0]
    b_ok = batch % _axis_size(mesh, dp_ax) == 0
    bs = dp_ax if b_ok else None

    kv = conv = st = None
    if cfg.has_attn:
        if b_ok:
            # (L, B, S, KVH, D): batch over DP, sequence over model
            kv = P(None, bs, "model", None, None)
        else:
            # long_500k (B=1): sequence over the whole mesh
            seq_ax = tuple(mesh.axis_names)
            kv = P(None, None, seq_ax, None, None)
    if cfg.has_ssm:
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.d_state
        conv_entries = [None, bs, None, None]
        if conv_dim % mesh.shape["model"] == 0:
            conv_entries[3] = "model"
        conv = P(*conv_entries)
        # (L, B, H, N, P): heads over model if divisible, else N, else P
        sshape = (cfg.n_layers, batch, cfg.n_ssm_heads, cfg.d_state,
                  cfg.ssm_head_dim)
        dim = _first_divisible(sshape, mesh, "model", (2, 3, 4))
        entries = [None, bs, None, None, None]
        if dim is not None:
            entries[dim] = "model"
        st = P(*entries)
    from repro.models.transformer import DecodeState
    return DecodeState(kv_k=kv, kv_v=kv, conv=conv, ssm=st, pos=P())


def to_named(tree_of_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))
