"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (a v5e pod-slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis extends
the data-parallel domain across the inter-pod (DCN/ICI) boundary.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state -- required because the
dry-run forces 512 host devices while tests/benches must see 1.
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on jax >= 0.6; older jax treats
    every axis as Auto already."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def activate_mesh(mesh):
    """Context manager installing ``mesh``: ``jax.set_mesh`` on new jax; on
    0.4.x the Mesh object itself is the resource-env context manager."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The axes forming the data-parallel domain."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh) -> int:
    return int(jax.numpy.prod(jax.numpy.asarray(
        [mesh.shape[a] for a in data_axes(mesh)])))


def tp_size(mesh) -> int:
    return mesh.shape["model"]
