"""Jittable train / serve steps with full sharding annotations.

These are the functions the launcher jits and the dry-run lowers: one
train_step (fwd + bwd + AdamW/ZeRO-1 update) and one serve_step (single-token
decode against a sharded KV/SSM cache). Grad accumulation and the elastic /
fault-tolerance wrappers live in launch/train.py and runtime/.

``engine`` throughout is the dispatch value (an elaborated
:class:`GemminiInstance` or a bare
:class:`repro.core.context.ExecutionContext`); give it a mesh
(``with_mesh``) and the pallas/interpret kernels inside these jitted steps
run under shard_map with per-device shapes, which is what makes tuned
Pallas kernels legal in a GSPMD-partitioned step.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.generator import GemminiInstance
from repro.launch import sharding as shd
from repro.models import transformer as tf
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: Dict[str, Any]
    step: jnp.ndarray


def make_train_step(engine: GemminiInstance, cfg: tf.ModelConfig,
                    opt_cfg: adamw.AdamWConfig, mesh, batch: int, seq: int,
                    *, grad_accum: int = 1):
    """Returns train_step(state, batch_dict) -> (state, metrics)."""
    res_shd = shd.to_named(shd.residual_spec(cfg, mesh, batch, seq), mesh)
    log_shd = shd.to_named(shd.logits_spec(cfg, mesh, batch), mesh)

    def loss(params, tokens, labels, extra):
        return tf.loss_fn(engine, params, cfg, tokens, labels, extra,
                          remat=True, residual_sharding=res_shd,
                          logits_sharding=log_shd)

    def train_step(state: TrainState, batch_dict) -> Tuple[TrainState, Dict]:
        tokens = batch_dict["tokens"]
        labels = batch_dict["labels"]
        extra = batch_dict.get("extra_embeds")
        if grad_accum == 1:
            lval, grads = jax.value_and_grad(loss)(state.params, tokens,
                                                   labels, extra)
        else:
            mb_tok = tokens.reshape(grad_accum, -1, *tokens.shape[1:])
            mb_lab = labels.reshape(grad_accum, -1, *labels.shape[1:])
            mb_ext = (None if extra is None else
                      extra.reshape(grad_accum, -1, *extra.shape[1:]))

            def acc_fn(carry, mb):
                tot, g = carry
                t, l, e = mb
                lv, gi = jax.value_and_grad(loss)(state.params, t, l, e)
                return (tot + lv, jax.tree.map(jnp.add, g, gi)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            xs = (mb_tok, mb_lab, mb_ext) if mb_ext is not None \
                else (mb_tok, mb_lab, mb_tok)  # dummy third
            if mb_ext is None:
                def acc_fn2(carry, mb):
                    tot, g = carry
                    t, l, _ = mb
                    lv, gi = jax.value_and_grad(loss)(state.params, t, l,
                                                      None)
                    return (tot + lv, jax.tree.map(jnp.add, g, gi)), None
                (lsum, grads), _ = jax.lax.scan(acc_fn2, (0.0, zeros), xs)
            else:
                (lsum, grads), _ = jax.lax.scan(acc_fn, (0.0, zeros), xs)
            lval = lsum / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        new_params, new_opt, om = adamw.adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = {"loss": lval, **om}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_prefill_step(engine: GemminiInstance, cfg: tf.ModelConfig, mesh,
                      batch: int, seq: int):
    """Inference prefill: forward over the prompt, return last-token logits.

    (Roofline-wise prefill == forward; the cache write is a minor term and is
    exercised by the serving example, examples/serve_decode.py.)
    """
    res_shd = shd.to_named(shd.residual_spec(cfg, mesh, batch, seq), mesh)
    log_shd = shd.to_named(shd.logits_spec(cfg, mesh, batch), mesh)

    def prefill_step(params, batch_dict):
        logits = tf.forward(engine, params, cfg, batch_dict["tokens"],
                            batch_dict.get("extra_embeds"),
                            residual_sharding=res_shd,
                            logits_sharding=log_shd)
        return logits[:, -1]

    return prefill_step


def make_serve_step(engine: GemminiInstance, cfg: tf.ModelConfig, mesh,
                    batch: int, max_seq: int):
    """One-token decode against a KV/SSM cache of ``max_seq``."""

    def serve_step(params, tokens, state: tf.DecodeState):
        logits, new_state = tf.decode_step(engine, params, cfg, tokens,
                                           state)
        return logits, new_state

    return serve_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ---------------------------------------------------------------------------
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

N_VLM_TOKENS = 576   # anyres base-tile patch embeddings (stub frontend)


def param_shapes(cfg: tf.ModelConfig):
    """ShapeDtypeStruct pytree of the model params (no allocation)."""
    return jax.eval_shape(
        functools.partial(tf.init_params, cfg=cfg), jax.random.PRNGKey(0))


def opt_shapes(params_shape):
    return jax.eval_shape(adamw.adamw_init, params_shape)


def _with_shardings(tree_shapes, tree_specs, mesh):
    def attach(s, spec):
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=jax.sharding.NamedSharding(mesh, spec))
    return jax.tree.map(attach, tree_shapes, tree_specs)


def input_specs(cfg: tf.ModelConfig, shape_name: str, mesh) -> Dict[str, Any]:
    """All inputs for the step that this (arch x shape) cell lowers.

    Returns dict with 'kind', 'args' (tuple of ShapeDtypeStructs in step
    order) and 'out_shardings'.
    """
    info = SHAPES[shape_name]
    batch, seq = info["batch"], info["seq"]
    kind = info["kind"]
    tok_nd = 3 if cfg.n_codebooks > 1 else 2
    tspec = shd.tokens_spec(mesh, batch, tok_nd)
    tok_shape = (batch, seq, cfg.n_codebooks) if tok_nd == 3 \
        else (batch, seq)

    pshapes = param_shapes(cfg)
    pspecs = shd.param_specs(pshapes, mesh)
    params = _with_shardings(pshapes, pspecs, mesh)

    def tok_struct(shape):
        return jax.ShapeDtypeStruct(
            shape, jnp.int32, sharding=jax.sharding.NamedSharding(mesh, tspec))

    if kind in ("train", "prefill"):
        text_seq = seq
        extra = None
        if cfg.modality == "vlm":
            text_seq = seq - N_VLM_TOKENS
            extra = jax.ShapeDtypeStruct(
                (batch, N_VLM_TOKENS, cfg.d_model), cfg.dtype,
                sharding=jax.sharding.NamedSharding(
                    mesh, shd.tokens_spec(mesh, batch, 3)))
        tshape = (batch, text_seq, cfg.n_codebooks) if tok_nd == 3 \
            else (batch, text_seq)
        batch_dict = {"tokens": tok_struct(tshape)}
        if kind == "train":
            batch_dict["labels"] = tok_struct(tshape)
        if extra is not None:
            batch_dict["extra_embeds"] = extra
        if kind == "train":
            oshapes = opt_shapes(pshapes)
            ospecs = shd.opt_state_specs(pshapes, mesh)
            opt = _with_shardings(oshapes, ospecs, mesh)
            step0 = jax.ShapeDtypeStruct(
                (), jnp.int32,
                sharding=jax.sharding.NamedSharding(mesh, shd.P()))
            from repro.launch.steps import TrainState
            state = TrainState(params=params, opt=opt, step=step0)
            return dict(kind=kind, args=(state, batch_dict), batch=batch,
                        seq=seq)
        return dict(kind=kind, args=(params, batch_dict), batch=batch,
                    seq=seq)

    # decode: one new token with a cache of `seq`
    dshape = (batch, 1, cfg.n_codebooks) if tok_nd == 3 else (batch, 1)
    tokens = tok_struct(dshape)
    sshapes = jax.eval_shape(
        functools.partial(tf.init_decode_state, cfg, batch, seq))
    sspecs = shd.decode_state_specs(cfg, mesh, batch, seq)
    state = _with_shardings(sshapes, sspecs, mesh)
    return dict(kind=kind, args=(params, tokens, state), batch=batch,
                seq=seq)
