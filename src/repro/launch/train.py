"""End-to-end training driver (the launcher).

Composes every substrate layer: config registry -> Gemmini engine ->
sharded train step -> synthetic data pipeline -> checkpoint manager ->
straggler detection -> restart/elastic loop. Runs real steps on whatever
devices exist (CPU smoke configs through 512-chip production meshes -- the
mesh is chosen from the live device count).

Usage (CPU, reduced config, full fault-tolerant loop):

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 20

Production XLA flags for compute/comm overlap (latency-hiding scheduler)
are applied when --xla-lhs is passed; they must be set before jax import,
so the flag re-execs the process with the env prepared.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import os
import sys
import time

LHS_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_overlap_compute_collective_tc=true"
)


def _maybe_reexec_with_lhs():
    if "--xla-lhs" in sys.argv and not os.environ.get("_REPRO_LHS"):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + LHS_FLAGS).strip()
        env["_REPRO_LHS"] = "1"
        os.execve(sys.executable, [sys.executable] + sys.argv, env)


_maybe_reexec_with_lhs()

import jax                                                      # noqa: E402
import jax.numpy as jnp                                         # noqa: E402
import numpy as np                                              # noqa: E402

from repro import configs                                       # noqa: E402
from repro.checkpoint import CheckpointManager                  # noqa: E402
from repro.core import flags                                    # noqa: E402
from repro.core.config import GemminiConfig                     # noqa: E402
from repro.core.generator import (default_engine_backend,      # noqa: E402
                                  elaborate)
from repro.data import SyntheticLM, SyntheticLMConfig, \
    make_global_batch                                           # noqa: E402
from repro.launch import sharding as shd                        # noqa: E402
from repro.launch.mesh import activate_mesh, make_mesh          # noqa: E402
from repro.launch import steps as steps_lib                     # noqa: E402
from repro.models import transformer as tf                      # noqa: E402
from repro.optim import adamw                                   # noqa: E402
from repro.runtime import (RestartPolicy, StragglerDetector,    # noqa: E402
                           run_with_restarts)


def pick_mesh(tp_hint: int = 0):
    """Largest (data, model) mesh the live devices support."""
    n = jax.device_count()
    tp = tp_hint or max(1, min(16, n))
    while n % tp:
        tp //= 2
    return make_mesh((n // tp, tp), ("data", "model"))


@dataclasses.dataclass
class RunResult:
    steps_done: int
    final_loss: float
    losses: list
    straggler_steps: int


def train_once(args, model_cfg, pods: int) -> RunResult:
    mesh = pick_mesh(args.tp)
    # Mesh-aware dispatch (ExecutionContext under the hood): on a pallas/
    # interpret engine every op runs in shard_map and resolves its tuned
    # schedule at the PER-DEVICE shapes -- the same shapes the shard-aware
    # warm below populates. The xla backend (CPU CI) ignores the mesh and
    # stays on the GSPMD-partitioned plan-free reference.
    engine = elaborate(GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                     output_dtype="bf16"),
                       default_engine_backend()
                       ).with_mesh(mesh, axis=shd.data_axis(mesh))
    opt_cfg = adamw.AdamWConfig(lr=args.lr)
    batch, seq = args.batch, args.seq

    if flags.get("tune_mode") != "off":
        # Warm the schedule cache for every GEMM/attention shape a train
        # step runs, shard-aware: the partitioner splits the global batch
        # over the mesh's data axis, so each device launches the per-device
        # M -- warming the global M would populate entries no kernel hits.
        from repro import tune
        data_shards = engine.ctx.n_shards
        stats = tune.warm_model_plans(engine.cfg, model_cfg, batch, seq,
                                      include_decode=False,
                                      n_shards=data_shards)
        print(f"[train] plan warmup ({flags.get('tune_mode')}, "
              f"{data_shards} data shard(s)): "
              f"{stats['gemm_shapes']} gemm + {stats['attn_shapes']} attn "
              f"shapes, {stats['cache_hits']} cache hits, "
              f"{stats['cache_misses']} misses")

    data_cfg = SyntheticLMConfig(
        vocab=model_cfg.vocab, seq=seq, global_batch=batch, seed=args.seed,
        n_codebooks=model_cfg.n_codebooks)
    gen = SyntheticLM(data_cfg)
    tok_nd = 3 if model_cfg.n_codebooks > 1 else 2
    tok_sharding = jax.sharding.NamedSharding(
        mesh, shd.tokens_spec(mesh, batch, tok_nd))

    with activate_mesh(mesh):
        pshapes = steps_lib.param_shapes(model_cfg)
        pspecs = shd.param_specs(pshapes, mesh)
        pshard = shd.to_named(pspecs, mesh)
        oshapes = steps_lib.opt_shapes(pshapes)
        ospecs = shd.opt_state_specs(pshapes, mesh)
        oshard = shd.to_named(ospecs, mesh)

        mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir \
            else None
        start_step = 0
        state = None
        if mgr is not None:
            target = steps_lib.TrainState(
                params=pshapes, opt=oshapes,
                step=jax.ShapeDtypeStruct((), jnp.int32))
            tshard = steps_lib.TrainState(
                params=pshard, opt=oshard,
                step=jax.sharding.NamedSharding(mesh, shd.P()))
            step_found, restored = mgr.restore_latest(
                target, tshard, expect_meta={"arch": model_cfg.name})
            if step_found is not None:
                start_step, state = step_found, restored
                print(f"[train] restored checkpoint step={start_step} "
                      f"(mesh={tuple(mesh.shape.items())})")
        if state is None:
            init = jax.jit(
                functools.partial(tf.init_params, cfg=model_cfg),
                out_shardings=pshard)
            params = init(jax.random.PRNGKey(args.seed))
            opt = jax.jit(adamw.adamw_init, out_shardings=oshard)(params)
            state = steps_lib.TrainState(
                params=params, opt=opt, step=jnp.zeros((), jnp.int32))

        train_step = jax.jit(
            steps_lib.make_train_step(engine, model_cfg, opt_cfg, mesh,
                                      batch=batch, seq=seq,
                                      grad_accum=args.grad_accum),
            donate_argnums=(0,))

        detector = StragglerDetector()
        losses, stragglers = [], 0
        step = start_step
        try:
            while step < args.steps:
                if args.fail_at is not None and step == args.fail_at \
                        and not os.environ.get("_REPRO_FAILED"):
                    os.environ["_REPRO_FAILED"] = "1"
                    raise RuntimeError(f"injected failure at step {step}")
                t0 = time.time()
                batch_dict = make_global_batch(gen, step, tok_sharding)
                if model_cfg.modality == "vlm":
                    batch_dict = make_global_batch(
                        gen, step, tok_sharding,
                        extra_embed_dim=model_cfg.d_model,
                        extra_tokens=steps_lib.N_VLM_TOKENS)
                state, metrics = train_step(state, batch_dict)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                if detector.observe(dt):
                    stragglers += 1
                    print(f"[train] step {step}: straggler ({dt*1e3:.0f}ms)")
                losses.append(loss)
                if step % args.log_every == 0:
                    print(f"[train] step {step:5d} loss={loss:.4f} "
                          f"({dt*1e3:.0f}ms)")
                step += 1
                if mgr is not None and step % args.ckpt_every == 0:
                    mgr.save_async(step, state,
                                   extra_meta={"arch": model_cfg.name})
            if mgr is not None:
                mgr.save(step, state, extra_meta={"arch": model_cfg.name})
            return RunResult(step, losses[-1] if losses else float("nan"),
                             losses, stragglers)
        finally:
            # Flush any in-flight async checkpoint before this attempt
            # unwinds: an in-process restart (run_with_restarts) builds a
            # fresh manager and calls restore_latest immediately -- racing
            # the daemon writer would make it restart from step 0.
            if mgr is not None:
                mgr.wait()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--tp", type=int, default=0)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject one failure at this step (FT demo)")
    ap.add_argument("--max-restarts", type=int, default=2)
    ap.add_argument("--xla-lhs", action="store_true",
                    help="enable latency-hiding-scheduler XLA flags")
    ap.add_argument("--tune", choices=flags.TUNE_MODES, default=None,
                    help="tile-plan autotuning mode (default: $GEMMINI_TUNE)")
    args = ap.parse_args(argv)
    # Always re-set: set_flag validates, so a typo'd $GEMMINI_TUNE fails at
    # startup instead of (maybe never) at the first plan resolution.
    flags.set_flag("tune_mode", args.tune if args.tune is not None
                   else flags.get("tune_mode"))

    model_cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get(args.arch)

    def make_runner(attempt, pods):
        if attempt:
            print(f"[train] restart #{attempt} on {pods} pod(s)")
        return lambda: train_once(args, model_cfg, pods)

    result, attempts, pods = run_with_restarts(
        make_runner, RestartPolicy(max_failures=args.max_restarts),
        n_pods=1,
        on_failure=lambda a, e: print(f"[train] FAILURE (attempt {a}): {e}"))
    print(f"[train] done: {result.steps_done} steps, "
          f"final_loss={result.final_loss:.4f}, attempts={attempts}, "
          f"stragglers={result.straggler_steps}")
    return result


if __name__ == "__main__":
    main()
