"""Pipeline parallelism: GPipe-style stage loop over a ``stage`` mesh axis.

Not enabled by default at 512 chips (DP x TP fills the mesh; see DESIGN.md
section 4) but provided -- and tested -- as the scaling path beyond ~4k
chips, where TP hits the ICI diameter and layer stages must be split.

Mechanics (TPU-native): the layer stack is split into S stages whose
parameters are sharded over the ``stage`` mesh axis (each device group holds
only its stage's layers -- the PP memory win). Microbatches march through
the classic GPipe schedule: at tick ``t`` stage ``s`` processes microbatch
``t - s``; activations hop stage->stage+1 through ``jax.lax.ppermute``
(point-to-point neighbor traffic on the ICI torus -- never a broadcast).
The loop is a ``lax.scan``, so ``jax.grad`` differentiates straight through
the schedule: the transpose of ppermute is the reverse rotation, giving the
backward pipeline for free, with the bubble fraction (S-1)/(T+S-1) exactly
as in GPipe.

``pipeline_apply`` operates on the residual stream; embedding/unembedding
stay outside (replicated or TP-sharded as usual), which composes PP with
the DP/TP rules in launch/sharding.py: mesh axes (pod, stage, data, model).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def split_stages(stacked_params: Any, n_stages: int) -> Any:
    """Reshape (L, ...) stacked layer params to (S, L/S, ...)."""
    def one(p):
        l = p.shape[0]
        if l % n_stages:
            raise ValueError(f"{l} layers not divisible into {n_stages} stages")
        return p.reshape(n_stages, l // n_stages, *p.shape[1:])
    return jax.tree.map(one, stacked_params)


def pipeline_apply(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   stage_params: Any, x_microbatches: jnp.ndarray, *,
                   mesh, axis: str = "stage") -> jnp.ndarray:
    """Run microbatches through the S-stage pipeline.

    stage_fn(params_for_one_stage, h) -> h   (applies that stage's layers)
    stage_params: pytree with leading dim S (sharded over ``axis``)
    x_microbatches: (n_micro, mb, ...) residual-stream inputs
    Returns (n_micro, mb, ...) outputs (last stage's results, replicated).
    """
    n_stages = mesh.shape[axis]
    nm = x_microbatches.shape[0]

    def inner(params_local, x_local):
        # params_local leaves: (1, L/S, ...) -- this stage's slice
        params1 = jax.tree.map(lambda p: p[0], params_local)
        s = jax.lax.axis_index(axis)
        total = nm + n_stages - 1
        buf0 = jnp.zeros_like(x_local[0])
        outs0 = jnp.zeros_like(x_local)

        def tick(carry, t):
            buf, outs = carry
            mb_idx = jnp.clip(t, 0, nm - 1)
            inp = x_local[mb_idx]
            h_in = jnp.where(s == 0, inp, buf)
            h_out = stage_fn(params1, h_in)
            out_idx = t - (n_stages - 1)
            valid = (s == n_stages - 1) & (out_idx >= 0)
            upd = jnp.where(valid, h_out,
                            outs[jnp.clip(out_idx, 0, nm - 1)])
            outs = outs.at[jnp.clip(out_idx, 0, nm - 1)].set(upd)
            buf = jax.lax.ppermute(
                h_out, axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                      jnp.arange(total))
        # replicate the last stage's outputs to every stage
        outs = jax.lax.psum(
            jnp.where(s == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    # other mesh axes: params/x replicated from PP's point of view (their
    # sharding is handled by the surrounding pjit partitioner)
    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(inner, mesh=mesh,
                     in_specs=(pspec, P()), out_specs=P(),
                     check_rep=False)(stage_params, x_microbatches)


def pipeline_loss_fn(stage_fn, embed_fn, unembed_loss_fn):
    """Compose embed -> pipeline -> unembed+loss for training."""

    def loss(params, tokens, labels, *, mesh, n_micro: int,
             axis: str = "stage"):
        h = embed_fn(params, tokens)                     # (B, T, D)
        b = h.shape[0]
        hm = h.reshape(n_micro, b // n_micro, *h.shape[1:])
        ym = pipeline_apply(
            lambda sp, hh: stage_fn(params, sp, hh),
            params["stages"], hm, mesh=mesh, axis=axis)
        y = ym.reshape(b, *ym.shape[2:])
        lm = labels
        return unembed_loss_fn(params, y, lm)

    return loss
