"""Batched serving driver: prefill + decode loop with sampling.

Serves a (reduced or full) model with a batch of requests: one prefill pass
builds the KV/SSM caches, then single-token decode steps run against them
(the ``serve_step`` the dry-run lowers). Requests can terminate early on an
EOS token; a finished slot keeps decoding padding (static shapes) but its
output is frozen -- the standard static-batch serving discipline.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import flags
from repro.core.config import GemminiConfig
from repro.core.generator import default_engine_backend, elaborate
from repro.models import transformer as tf


def sample(logits: jnp.ndarray, key, temperature: float = 1.0) -> jnp.ndarray:
    """logits: (B, V) [or (B, n_q, V)] -> token ids."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def serve(model_cfg, *, batch: int, prompt_len: int, gen_len: int,
          temperature: float = 1.0, seed: int = 0, eos_id: int = -1):
    engine = elaborate(GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                     output_dtype="bf16"),
                       default_engine_backend())
    max_seq = prompt_len + gen_len
    if flags.get("tune_mode") != "off":
        # Pre-resolve (and under tune_mode=full, tune + persist) a schedule
        # for every projection GEMM (with its has_bias flag -- biased QKV
        # fingerprints differently) and every attention shape before the
        # first request hits the engine.
        from repro import tune
        stats = tune.warm_model_plans(engine.cfg, model_cfg, batch,
                                      prompt_len)
        print(f"[serve] plan warmup ({flags.get('tune_mode')}): "
              f"{stats['gemm_shapes']} gemm + {stats['attn_shapes']} attn "
              f"shapes, {stats['cache_hits']} cache hits, "
              f"{stats['cache_misses']} misses "
              f"(cache: {tune.default_cache_path()})")
    key = jax.random.PRNGKey(seed)
    key, pk, sk = jax.random.split(key, 3)

    params = tf.init_params(pk, model_cfg)
    tok_shape = (batch, prompt_len, model_cfg.n_codebooks) \
        if model_cfg.n_codebooks > 1 else (batch, prompt_len)
    prompts = jax.random.randint(sk, tok_shape, 0, model_cfg.vocab, jnp.int32)

    # ---- prefill: forward over the prompt + cache build -------------------
    t0 = time.time()
    state = tf.init_decode_state(model_cfg, batch, max_seq,
                                 dtype=model_cfg.dtype)
    state = state._replace(pos=jnp.zeros((), jnp.int32))
    prefill = jax.jit(lambda p, tk, st: tf.prefill_into_cache(
        engine, p, model_cfg, tk, st))
    logits, state = prefill(params, prompts, state)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    decode = jax.jit(lambda p, tk, st: tf.decode_step(
        engine, p, model_cfg, tk, st), donate_argnums=(2,))

    last = logits[:, -1]
    done = jnp.zeros((batch,), bool)
    outputs = []
    t0 = time.time()
    for i in range(gen_len):
        key, k = jax.random.split(key)
        nxt = sample(last, k, temperature)           # (B,) or (B, n_q)
        if model_cfg.n_codebooks > 1:
            step_tok = nxt[:, None, :]
        else:
            nxt = jnp.where(done, 0, nxt)
            done = done | (nxt == eos_id)
            step_tok = nxt[:, None]
        outputs.append(np.asarray(nxt))
        logits, state = decode(params, step_tok, state)
        last = logits[:, -1]
    jax.block_until_ready(last)
    t_decode = time.time() - t0
    toks = np.stack(outputs, axis=1)
    return dict(tokens=toks, t_prefill=t_prefill, t_decode=t_decode,
                tok_per_s=batch * gen_len / max(t_decode, 1e-9))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--tune", choices=flags.TUNE_MODES, default=None,
                    help="tile-plan autotuning mode (default: $GEMMINI_TUNE)")
    args = ap.parse_args(argv)
    # Always re-set: set_flag validates, so a typo'd $GEMMINI_TUNE fails at
    # startup instead of (maybe never) at the first plan resolution.
    flags.set_flag("tune_mode", args.tune if args.tune is not None
                   else flags.get("tune_mode"))
    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    out = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                gen_len=args.gen, temperature=args.temperature)
    print(f"[serve] prefill {out['t_prefill']*1e3:.0f}ms, "
          f"decode {out['t_decode']*1e3:.0f}ms "
          f"({out['tok_per_s']:.1f} tok/s), "
          f"out shape {out['tokens'].shape}")
    return out


if __name__ == "__main__":
    main()
