"""Serving driver over the continuous-batching engine.

Requests run through ``repro.serving.ServingEngine``: paged KV cache,
admission queue, prefill/decode interleaving, preemption under cache
pressure -- the request-level system layer (docs/serving.md). The old
static batch loop survives as ``--policy static`` (admission barrier, no
slot recycling) for A/B comparison; ``benchmarks/bench_serving.py`` tracks
the two policies against each other per CI run.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro import configs
from repro.core import flags
from repro.serving import ServingEngine


def serve(model_cfg, *, batch: int, prompt_len: int, gen_len: int,
          temperature: float = 1.0, seed: int = 0, eos_id: int = -1,
          policy: str = "continuous", max_slots: int = 0,
          page_size: int = 0, prefill_chunk: int = 0,
          backend: str = "", admission_policy: str = "fifo",
          faults: str = "", enforce_deadlines: bool = False,
          deadline_s: float = 0.0, trace=None,
          kv_offload: bool = False, prefix_cache: bool = False,
          host_pool_pages: int = 0):
    """Serve ``batch`` random-prompt requests; returns the old static-loop
    schema (tokens (B, gen[, n_q]), t_prefill, t_decode, tok_per_s) plus
    the engine's full telemetry under ``report``.

    ``prefill_chunk``: chunked-prefill granularity in cache positions --
    0 = one page (the default: page-multiple chunks keep chunk boundaries
    page-aligned), negative = disabled (single-pass prefill).
    ``backend``: the engine ``ExecutionContext`` backend (empty = host
    default: pallas on TPU, xla elsewhere); ``admission_policy``:
    fifo | priority | deadline (scheduler admission order).

    Robustness knobs (docs/serving.md#robustness): ``faults`` is a
    ``GEMMINI_FAULTS``-grammar spec string (empty = env/off);
    ``enforce_deadlines`` sheds expired requests instead of serving
    them; ``deadline_s`` stamps every submitted request with a relative
    per-request SLO (0 = best-effort).

    KV-lifecycle knobs (docs/serving.md#kv-lifecycle): ``kv_offload``
    spills preemption victims to a host pool (``host_pool_pages`` deep,
    0 = arena-sized) so restart is a restore instead of a recompute;
    ``prefix_cache`` maps shared prompt prefixes copy-on-write. Both off
    by default and bit-exact either way.

    ``trace`` follows ``ServingEngine(trace=)``: None consults
    ``$GEMMINI_TRACE``, True/int/Tracer turns span tracing on for this
    run (docs/observability.md). The engine's tracer is also installed
    process-globally for the duration so tuner-measurement and
    fault-injection spans land on the same timeline."""
    rng = np.random.default_rng(seed)
    max_slots = max_slots or min(batch, 8)
    max_context = prompt_len + model_cfg.n_meta_tokens + gen_len + 64
    engine = ServingEngine(
        model_cfg, max_slots=max_slots, max_context=max_context,
        page_size=page_size or None, seed=seed, temperature=temperature,
        policy=policy, warm_prompt_lens=[prompt_len],
        prefill_chunk=None if prefill_chunk < 0 else prefill_chunk,
        backend=backend or None, admission_policy=admission_policy,
        faults=faults or None, enforce_deadlines=enforce_deadlines,
        trace=trace, kv_offload=kv_offload, prefix_cache=prefix_cache,
        host_pool_pages=host_pool_pages or None)
    if engine.tracer is not None:
        from repro.obs import trace as otrace
        otrace.install(engine.tracer)
    if engine.warm_stats is not None:
        from repro import tune
        s = engine.warm_stats
        print(f"[serve] plan warmup ({flags.get('tune_mode')}): "
              f"{s['gemm_shapes']} gemm + {s['attn_shapes']} attn + "
              f"{s['paged_shapes']} paged shapes, {s['cache_hits']} cache "
              f"hits, {s['cache_misses']} misses "
              f"(cache: {tune.default_cache_path()})")
        print(f"[serve] paged cache: page={engine.page_size} tokens, "
              f"arena={engine.alloc.n_pages} pages")

    tok_shape = (prompt_len, model_cfg.n_codebooks) \
        if model_cfg.n_codebooks > 1 else (prompt_len,)
    # Deadlines are absolute timestamps on the ENGINE clock (monotonic by
    # default -- wall clocks step under NTP), so derive from engine.now().
    deadline = (engine.now() + deadline_s) if deadline_s > 0 else None
    for _ in range(batch):
        prompt = rng.integers(0, model_cfg.vocab, tok_shape).astype(np.int32)
        engine.submit(prompt, gen_len, eos_id=eos_id, deadline=deadline)
    t0 = time.time()
    try:
        report = engine.run()
    finally:
        if engine.tracer is not None:
            from repro.obs import trace as otrace
            if otrace.active() is engine.tracer:
                otrace.deactivate()
    wall = time.time() - t0

    # Old static-loop output schema: (B, gen) tokens, frozen-at-0 past EOS
    # (shed requests contribute their exact partial stream, zero-padded).
    full_shape = (gen_len, model_cfg.n_codebooks) \
        if model_cfg.n_codebooks > 1 else (gen_len,)
    outs = []
    for r in report["requests"]:
        toks = np.asarray(r["tokens"], np.int32).reshape(
            (-1,) + full_shape[1:])
        pad_shape = (gen_len - toks.shape[0],) + toks.shape[1:]
        outs.append(np.concatenate([toks, np.zeros(pad_shape, np.int32)]))
    toks = np.stack(outs)
    summ = report["summary"]
    ttft = max(r["ttft_s"] or 0.0 for r in report["requests"])
    return dict(tokens=toks, t_prefill=ttft, t_decode=wall - ttft,
                tok_per_s=summ["tokens_per_s"], report=report,
                engine=engine)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--policy", choices=("continuous", "static"),
                    default="continuous",
                    help="continuous batching (default) or the static "
                         "group-barrier baseline")
    ap.add_argument("--slots", type=int, default=0,
                    help="decode slots (default: min(batch, 8))")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV page size (default: tuned or 64)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill granularity in tokens (cache "
                         "positions per chunk, interleaved with decode "
                         "steps). Default 0 = one page; negative disables "
                         "chunking (single-pass prefill)")
    ap.add_argument("--tune", choices=flags.TUNE_MODES, default=None,
                    help="tile-plan autotuning mode (default: $GEMMINI_TUNE)")
    ap.add_argument("--backend", choices=("xla", "pallas", "interpret"),
                    default="",
                    help="engine ExecutionContext backend (default: pallas "
                         "on TPU hosts, xla elsewhere)")
    ap.add_argument("--admission", choices=("fifo", "priority", "deadline"),
                    default="fifo",
                    help="scheduler admission order (priority/deadline use "
                         "Request.priority / Request.deadline)")
    ap.add_argument("--faults", default="",
                    help="deterministic fault-injection spec "
                         "(GEMMINI_FAULTS grammar, e.g. "
                         "'seed=7;nan@decode:p=0.2,max=2'); empty = "
                         "$GEMMINI_FAULTS / off")
    ap.add_argument("--enforce-deadlines", action="store_true",
                    help="shed requests whose deadline passed "
                         "(terminal deadline_missed status) instead of "
                         "serving them to completion")
    ap.add_argument("--deadline", type=float, default=0.0, metavar="S",
                    help="per-request SLO: stamp every request with "
                         "submit-time + S seconds (0 = best-effort)")
    ap.add_argument("--kv-offload", action="store_true",
                    help="spill preemption victims' committed KV pages to "
                         "a host pool so restart is a DMA restore instead "
                         "of a full re-prefill (docs/serving.md#kv-lifecycle)")
    ap.add_argument("--host-pool-pages", type=int, default=0,
                    help="host offload pool capacity in pages "
                         "(0 = arena-sized; only with --kv-offload)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-hash full KV pages at prefill commit and "
                         "map shared prompt prefixes copy-on-write "
                         "(attention-only families)")
    ap.add_argument("--trace", action="store_true",
                    help="record request/engine/allocator/tuner spans and "
                         "export a Chrome-trace JSON (see --trace-out); "
                         "off by default, also togglable via $GEMMINI_TRACE")
    ap.add_argument("--trace-out", default="TRACE_serve.json", metavar="PATH",
                    help="Chrome-trace output path for --trace "
                         "(default: TRACE_serve.json; load in "
                         "chrome://tracing or ui.perfetto.dev, or summarize "
                         "with python -m repro.obs PATH)")
    ap.add_argument("--profile", action="store_true",
                    help="time every ExecutionContext op (blocking sync per "
                         "dispatch) and print achieved-vs-roofline "
                         "utilization per kernel bucket")
    args = ap.parse_args(argv)
    # Always re-set: set_flag validates, so a typo'd $GEMMINI_TUNE fails at
    # startup instead of (maybe never) at the first plan resolution.
    flags.set_flag("tune_mode", args.tune if args.tune is not None
                   else flags.get("tune_mode"))
    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    profiler = None
    import contextlib
    run_ctx = contextlib.nullcontext()
    if args.profile:
        from repro.obs import profile as oprofile
        profiler = oprofile.Profiler()
        oprofile.install(profiler)
        # Per-op timing happens at the ExecutionContext dispatch boundary,
        # which the engine's jitted step functions would trace through
        # (one opaque XLA call, no per-op boundaries). disable_jit makes
        # every dispatch eager -- slower, but that's what opt-in profiling
        # is for, and the op stream is identical.
        import jax
        run_ctx = jax.disable_jit()
        print("[serve] profiling: per-op sync timing (jit disabled)")
    try:
        with run_ctx:
            out = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen_len=args.gen, temperature=args.temperature,
                        policy=args.policy, max_slots=args.slots,
                        page_size=args.page_size,
                        prefill_chunk=args.prefill_chunk,
                        backend=args.backend,
                        admission_policy=args.admission,
                        faults=args.faults,
                        enforce_deadlines=args.enforce_deadlines,
                        deadline_s=args.deadline,
                        trace=True if args.trace else None,
                        kv_offload=args.kv_offload,
                        prefix_cache=args.prefix_cache,
                        host_pool_pages=args.host_pool_pages)
    finally:
        if profiler is not None:
            from repro.obs import profile as oprofile
            oprofile.deactivate()
    s = out["report"]["summary"]

    def ms(v):
        # Percentiles are None (JSON null) for empty populations.
        return "n/a" if v is None else f"{v * 1e3:.0f}ms"

    print(f"[serve] {args.policy}: {int(s['requests'])} reqs, "
          f"{int(s['new_tokens'])} tokens in {s['wall_s']*1e3:.0f}ms "
          f"({out['tok_per_s']:.1f} tok/s), "
          f"p50 latency {ms(s['p50_latency_s'])}, "
          f"p99 {ms(s['p99_latency_s'])}, "
          f"ITL p50 {ms(s['p50_itl_s'])} / p95 {ms(s['p95_itl_s'])}, "
          f"{int(s['prefill_chunks'])} prefill chunks, "
          f"preemptions {int(s['preemptions'])}, "
          f"out shape {out['tokens'].shape}")
    if s["injected_faults"] or s["retries"] or s["fallbacks"] or s["shed"]:
        faults_seen = out["report"].get("faults", {})
        print(f"[serve] robustness: {int(s['injected_faults'])} injected "
              f"({faults_seen}), {int(s['retries'])} retries, "
              f"{int(s['fallbacks'])} xla fallbacks, "
              f"{int(s['shed'])} shed, "
              f"{int(s['straggler_steps'])} straggler steps, "
              f"quarantined {out['report']['quarantined'] or 'none'}")
    if args.kv_offload or args.prefix_cache:
        print(f"[serve] kv-lifecycle: "
              f"{int(s['prefill_tokens'])} prefill tokens computed, "
              f"{int(s['prefix_hit_tokens'])} prefix-hit (skipped), "
              f"{int(s['offload_spills'])} spills / "
              f"{int(s['offload_restores'])} restores, restarts "
              f"{int(s['restarts_restored'])} restored / "
              f"{int(s['restarts_recomputed'])} recomputed")
    tracer = out["engine"].tracer
    if tracer is not None and args.trace:
        tracer.export_chrome(args.trace_out)
        print(f"[serve] trace: {len(tracer.events)} events "
              f"({tracer.dropped} dropped) -> {args.trace_out} "
              f"(summarize: python -m repro.obs {args.trace_out})")
    if profiler is not None:
        print(profiler.report())
    return out


if __name__ == "__main__":
    main()
