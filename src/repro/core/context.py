"""ExecutionContext: one mesh-aware dispatch API for every engine op.

The paper's full-stack argument (kernels are only meaningful inside the
programming stack and system that launch them) previously leaked into our
code as plumbing: every layer threaded a stringly-typed ``backend=``
argument plus the implicit ``GEMMINI_TUNE`` process global through ~10
modules, and under jit+GSPMD the Pallas ops resolved tile plans at trace
time with the GLOBAL logical shape -- making the tuned-kernel path
single-host only.  :class:`ExecutionContext` owns all of that in one
frozen value:

  * ``cfg``       -- the elaborated :class:`GemminiConfig` the kernels
                     tile against (``None`` is legal for the attention
                     ops, which fall back to the bf16 engine default);
  * ``backend``   -- ``pallas`` | ``interpret`` | ``xla`` | ``xla_twin``,
                     chosen once instead of per call.  ``xla_twin`` is the
                     degraded-mode backend: every *kernel* dispatches its
                     plan-free XLA twin (bit-identical to the Pallas body,
                     no tuned schedule involved), but the model layers
                     still see a non-``xla`` backend and keep routing
                     projections through the engine datapath -- so a step
                     re-run on the twin after a fault is bit-exact against
                     the faulted engine's own step, which the plain
                     ``xla`` backend (float-LM projection path) is not;
  * ``tune_mode`` -- per-context override of the ``GEMMINI_TUNE`` flag
                     (``None`` inherits the process flag), scoped around
                     each dispatch so two contexts with different tune
                     policies can coexist in one process;
  * ``mesh`` / ``axis`` -- when set, every batched op is wrapped in
                     ``shard_map`` over the mesh's ``axis`` so the Pallas
                     kernel body AND its schedule resolution
                     (``_resolve_plan`` / ``_resolve_attn_blocks``) see
                     PER-DEVICE shapes.  This is what makes tuned Pallas
                     kernels legal inside a GSPMD-partitioned step, and
                     what ``tune.warm_model_plans(n_shards=...)`` warms:
                     exactly the shapes each device launches.

Ops are looked up in a registry, so ``ctx.gemm(...)``,
``ctx.flash_attention(...)``, ``ctx.conv2d(...)``, ``ctx.ssd(...)``,
``ctx.paged_attention(...)``, ``ctx.paged_prefill_attention(...)`` and
``ctx.matmul(...)`` all dispatch through the same mesh/tune/backend
policy; new ops join via :func:`register_op`.

The old ``repro.kernels.ops.*(backend=...)`` entry points are gone (their
one-release deprecation-shim grace period ended in PR 7); lint rule GL506
forbids rebinding the legacy names, and :class:`GemminiDeprecationWarning`
remains the class any future repro deprecation must emit (the test suite
escalates it to an error for in-tree callers).

Sharding semantics (the ``mesh`` wrap):

  * only the leading *batch-like* axis is partitioned (GEMM rows M,
    attention/conv/SSD batch B, paged-decode slots); weights, KV pools
    and other broadcast operands are replicated -- this mirrors the
    data-parallel request path the launchers run;
  * the wrap applies only to the ``pallas`` / ``interpret`` backends.
    The ``xla`` reference is plan-free and SPMD-partitionable by
    construction, so the GSPMD partitioner (not shard_map) remains the
    right tool there and ``mesh`` is ignored;
  * a batch axis not divisible by the mesh axis falls back to the
    unsharded dispatch (same divisibility-or-replicate philosophy as
    ``launch.sharding``).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core import flags
from repro.core.config import GemminiConfig

BACKENDS = ("xla", "pallas", "interpret", "xla_twin")


class GemminiDeprecationWarning(DeprecationWarning):
    """Deprecated repro API surface (the pre-ExecutionContext op entries).

    A distinct subclass so the test suite can escalate exactly our own
    deprecations to errors (``pytest.ini``) without tripping on
    unrelated DeprecationWarnings from jax/numpy.
    """


# ---------------------------------------------------------------------------
# op registry
# ---------------------------------------------------------------------------
_OPS: Dict[str, Callable] = {}


def register_op(name: str) -> Callable[[Callable], Callable]:
    """Register ``fn(ctx, *args, **kw)`` as the dispatch for ``ctx.<name>``.

    The registry is how the context stays open for extension: a new kernel
    class adds one impl + one ``register_op`` call and every context
    (mesh'd or not) can launch it.
    """

    def deco(fn: Callable) -> Callable:
        if name in _OPS:
            raise ValueError(f"op {name!r} already registered")
        _OPS[name] = fn
        return fn

    return deco


def registered_ops() -> Tuple[str, ...]:
    return tuple(sorted(_OPS))


# ---------------------------------------------------------------------------
# the context
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """Backend + tune policy + partitioning for every engine op.

    Frozen and hashable: a context is a *value* (jit caches and the
    serving engine key on it), and derived contexts come from
    :meth:`with_backend` / :meth:`with_mesh` rather than mutation.
    """

    cfg: Optional[GemminiConfig] = None
    backend: str = "xla"
    tune_mode: Optional[str] = None     # None = inherit the process flag
    mesh: Any = None                    # jax.sharding.Mesh or None
    axis: Any = "data"                  # mesh axis name (or tuple of names)

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"have {BACKENDS}")
        if self.tune_mode is not None and \
                self.tune_mode not in flags.TUNE_MODES:
            raise ValueError(f"tune_mode must be None or one of "
                             f"{flags.TUNE_MODES}, got {self.tune_mode!r}")
        if self.mesh is not None:
            names = self.axis if isinstance(self.axis, tuple) else (self.axis,)
            missing = [a for a in names if a not in self.mesh.axis_names]
            if missing:
                raise ValueError(f"axis {missing} not in mesh axes "
                                 f"{self.mesh.axis_names}")

    # -- derivation --------------------------------------------------------
    def with_backend(self, backend: str) -> "ExecutionContext":
        return dataclasses.replace(self, backend=backend)

    def with_mesh(self, mesh, axis: Any = "data") -> "ExecutionContext":
        return dataclasses.replace(self, mesh=mesh, axis=axis)

    def unsharded(self) -> "ExecutionContext":
        """The same context without the mesh (single-host dispatch)."""
        return dataclasses.replace(self, mesh=None)

    def with_tune_mode(self, tune_mode: Optional[str]) -> "ExecutionContext":
        return dataclasses.replace(self, tune_mode=tune_mode)

    # -- mesh introspection ------------------------------------------------
    @property
    def n_shards(self) -> int:
        """Devices along ``axis`` (1 without a mesh) -- the divisor
        per-device batch shapes are warmed with
        (``tune.warm_model_plans(n_shards=...)``)."""
        if self.mesh is None:
            return 1
        names = self.axis if isinstance(self.axis, tuple) else (self.axis,)
        n = 1
        for a in names:
            n *= self.mesh.shape[a]
        return n

    @property
    def impl_backend(self) -> str:
        """The kernel-impl dispatch string: ``xla_twin`` lowers every op
        to its plan-free XLA twin (``backend="xla"`` at the impl layer)
        while remaining a distinct *model-level* backend -- see the class
        docstring for why the twin must not take the float-LM projection
        shortcut."""
        return "xla" if self.backend == "xla_twin" else self.backend

    @property
    def sharded(self) -> bool:
        """True when dispatch wraps kernels in shard_map: a mesh is set
        AND the backend runs real kernel bodies (the xla reference and
        the xla_twin are already SPMD-partitionable; GSPMD owns them)."""
        return self.mesh is not None and self.impl_backend != "xla" \
            and self.n_shards > 1

    # -- dispatch ----------------------------------------------------------
    @contextlib.contextmanager
    def _tune_scope(self):
        """Apply this context's tune policy for the duration of one
        dispatch (trace-time: schedule resolution happens while tracing),
        restoring the process flag afterwards."""
        if self.tune_mode is None or self.tune_mode == flags.get("tune_mode"):
            yield
            return
        prev = flags.get("tune_mode")
        flags.set_flag("tune_mode", self.tune_mode)
        try:
            yield
        finally:
            flags.set_flag("tune_mode", prev)

    def _shard_call(self, fn: Callable, arrays: Tuple, batched: Tuple[bool, ...],
                    out_batched: Any = True):
        """Run ``fn(*arrays)`` under shard_map, dim 0 of each batched
        array partitioned over ``self.axis`` (others replicated), so the
        kernel and its schedule resolution see per-device shapes.

        ``out_batched``: pytree-prefix of bools for the outputs (True =
        dim 0 partitioned). Falls back to the plain call when any batched
        dim does not divide the mesh axis.
        """
        import jax
        from jax.sharding import PartitionSpec as P
        try:
            from jax.experimental.shard_map import shard_map
        except ImportError:                       # newer jax: jax.shard_map
            shard_map = jax.shard_map
        n = self.n_shards
        if not self.sharded or any(
                b and (a.shape[0] % n != 0 or a.shape[0] < n)
                for a, b in zip(arrays, batched)):
            return fn(*arrays)
        bspec = P(self.axis)
        in_specs = tuple(bspec if b else P() for b in batched)

        def out_spec(b):
            return bspec if b else P()

        wrapped = shard_map(
            fn, mesh=self.mesh, in_specs=in_specs,
            out_specs=jax.tree.map(out_spec, out_batched),
            check_rep=False)
        return wrapped(*arrays)

    def __getattr__(self, name: str):
        # Only consulted for attributes not found normally: op dispatch.
        if name.startswith("_") or name not in _OPS:
            raise AttributeError(
                f"ExecutionContext has no op {name!r}; registered ops: "
                f"{registered_ops()}")
        fn = functools.partial(_OPS[name], self)
        prof = _profiler()
        if prof is not None:
            # Innermost wrap: timing excludes the fault injector's
            # host-side bookkeeping (and a poisoned output is still the
            # op the bucket timed).
            fn = _profiled_op(name, fn, prof, self)
        inj = _fault_injector()
        return fn if inj is None else _faulted_op(name, fn, inj)


def _profiler():
    """The process-global kernel profiler, if one is installed (see
    :mod:`repro.obs.profile`). Lazy import, same layering rule as the
    fault injector below; the common case (no profiling) costs one None
    check per dispatch."""
    try:
        from repro.obs import profile
    except ImportError:                       # pragma: no cover - stub envs
        return None
    return profile.active()


def _profiled_op(name: str, fn: Callable, prof, ctx) -> Callable:
    """Wrap one op dispatch with blocking-sync timing into the profiler's
    (op, shape-signature) bucket, joined with the op's KernelContract
    FLOPs/bytes (repro.obs.kernel_costs).

    EAGER calls only — under a jit trace the wrapper is a pass-through:
    a timer at trace time would measure tracing, and the blocking sync
    would serialize the compiled pipeline (the exact rule _faulted_op
    follows)."""

    @functools.wraps(fn)
    def wrapped(*args, **kw):
        import jax
        clean = getattr(jax.core, "trace_state_clean", None)
        if clean is not None and not clean():
            return fn(*args, **kw)
        bucket = prof.bucket(name, args, kw, ctx.cfg)
        t0 = prof.clock()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        prof.record(bucket, t0, prof.clock())
        return out

    return wrapped


def _fault_injector():
    """The process-global fault injector, if one is installed (see
    :mod:`repro.runtime.faults`). Lazy import: core must not depend on
    runtime at import time, and the common case (no faults) costs one
    None check per dispatch."""
    try:
        from repro.runtime import faults
    except ImportError:                       # pragma: no cover - stub envs
        return None
    return faults.active()


def _faulted_op(name: str, fn: Callable, inj) -> Callable:
    """Wrap one op dispatch with the injector's op-boundary hooks at site
    ``op:<name>``: a transient spec raises before the call, a poison spec
    NaN/Inf-fills the (first) output after it.

    Injection applies only to EAGER calls. Under a jit trace the wrapper
    is a pass-through: a fault injected at trace time would be baked into
    the compiled function -- permanent, unseedable, and invisible to the
    engine's host-level guards -- so traced ops fault at the engine's
    step boundaries instead (see ServingEngine._run_guarded)."""

    @functools.wraps(fn)
    def wrapped(*args, **kw):
        import jax
        clean = getattr(jax.core, "trace_state_clean", None)
        if clean is not None and not clean():
            return fn(*args, **kw)
        site = f"op:{name}"
        inj.check_transient(site)
        out = fn(*args, **kw)
        if isinstance(out, tuple):
            return (inj.poison(site, out[0]),) + out[1:]
        return inj.poison(site, out)

    return wrapped


@functools.lru_cache(maxsize=1)
def default_context() -> ExecutionContext:
    """The plan-free XLA reference context (what ops ran with before a
    caller ever chose a backend)."""
    return ExecutionContext(cfg=None, backend="xla")


def as_context(obj: Any) -> ExecutionContext:
    """Normalize the model zoo's dispatch argument: an
    :class:`ExecutionContext` passes through, an elaborated
    ``GemminiInstance`` contributes its ``.ctx``, and ``None`` means the
    default XLA reference context."""
    if obj is None:
        return default_context()
    if isinstance(obj, ExecutionContext):
        return obj
    ctx = getattr(obj, "ctx", None)
    if isinstance(ctx, ExecutionContext):
        return ctx
    raise TypeError(f"cannot derive an ExecutionContext from {type(obj)!r}")


def _require_cfg(ctx: ExecutionContext, op: str) -> GemminiConfig:
    if ctx.cfg is None:
        raise ValueError(f"ctx.{op} needs an elaborated GemminiConfig; "
                         f"this context has cfg=None (the attention ops "
                         f"accept that, the engine ops do not)")
    return ctx.cfg


# ---------------------------------------------------------------------------
# registered ops (thin policy wrappers over kernels.ops *_impl entries)
# ---------------------------------------------------------------------------
@register_op("gemm")
def _gemm(ctx: ExecutionContext, a, b, d=None, **kw):
    """C = act(round_shift(A @ B + D)); under a mesh the GEMM's M rows are
    partitioned so each device resolves (and launches) the per-device
    plan. See :func:`repro.kernels.ops.gemm_impl` for the backend x
    tune-mode matrix."""
    from repro.kernels import ops
    cfg = _require_cfg(ctx, "gemm")
    with ctx._tune_scope():
        m = a.shape[0]
        if d is None or not ctx.sharded or m % ctx.n_shards or \
                m < ctx.n_shards:
            # Unsharded dispatch (or no bias): hand d through untouched --
            # the impl owns its (1|M, N) broadcast/padding exactly as
            # before the context existed.
            return ctx._shard_call(
                lambda aa, bb: ops.gemm_impl(aa, bb, d, cfg=cfg,
                                             backend=ctx.impl_backend, **kw),
                (a, b), (True, False))
        import jax.numpy as jnp
        # Sharded + biased: a broadcast (1, N) bias row cannot shard over
        # M, so materialize it to the M rows only HERE, where each device
        # must see its own slice (the engine kernel streams a full (M, N)
        # D operand either way).
        db = jnp.broadcast_to(d, (m, b.shape[1]))
        return ctx._shard_call(
            lambda aa, bb, dd: ops.gemm_impl(aa, bb, dd, cfg=cfg,
                                             backend=ctx.impl_backend, **kw),
            (a, b, db), (True, False, True))


@register_op("matmul")
def _matmul(ctx: ExecutionContext, a, b, **kw):
    """Batched-LHS matmul sugar over ``ctx.gemm`` (M = prod of leading
    dims; the flattened rows are what a mesh partitions)."""
    lead = a.shape[:-1]
    y = _gemm(ctx, a.reshape(-1, a.shape[-1]), b, **kw)
    return y.reshape(*lead, b.shape[-1])


@register_op("conv2d")
def _conv2d(ctx: ExecutionContext, x, w, b=None, **kw):
    """Conv2D on the GEMM engine; under a mesh the image batch N is
    partitioned (weights/bias replicated). See
    :func:`repro.kernels.ops.conv2d_impl` for the backend x fused
    matrix."""
    from repro.kernels import ops
    cfg = _require_cfg(ctx, "conv2d")
    with ctx._tune_scope():
        return ctx._shard_call(
            lambda xx: ops.conv2d_impl(xx, w, b, cfg=cfg,
                                       backend=ctx.impl_backend, **kw),
            (x,), (True,))


@register_op("flash_attention")
def _flash_attention(ctx: ExecutionContext, q, k, v, **kw):
    """Blockwise-softmax attention; under a mesh the batch B is
    partitioned, so ``_resolve_attn_blocks`` fingerprints the per-device
    batch (the shape ``warm_model_plans(n_shards=...)`` warms). See
    :func:`repro.kernels.ops.flash_attention_impl`."""
    from repro.kernels import ops
    with ctx._tune_scope():
        return ctx._shard_call(
            lambda qq, kk, vv: ops.flash_attention_impl(
                qq, kk, vv, cfg=ctx.cfg, backend=ctx.impl_backend, **kw),
            (q, k, v), (True, True, True))


@register_op("paged_attention")
def _paged_attention(ctx: ExecutionContext, q, k_pool, v_pool, block_tables,
                     lengths, **kw):
    """Paged-KV single-token decode; under a mesh the decode *slots* are
    partitioned (each device attends its slots against the replicated
    page pools -- the sequence-sharded arena is the ROADMAP follow-on).
    See :func:`repro.kernels.ops.paged_attention_impl`."""
    from repro.kernels import ops
    with ctx._tune_scope():
        return ctx._shard_call(
            lambda qq, bt, ln: ops.paged_attention_impl(
                qq, k_pool, v_pool, bt, ln, backend=ctx.impl_backend, **kw),
            (q, block_tables, lengths), (True, True, True))


@register_op("paged_prefill_attention")
def _paged_prefill_attention(ctx: ExecutionContext, q, k_pool, v_pool,
                             block_table, start, **kw):
    """Chunked-prefill attention over a paged cache. Per-request by
    construction (B == 1), so there is no batch axis to partition and the
    mesh never wraps it; on a sharded engine it runs replicated inside
    the surrounding step. See
    :func:`repro.kernels.ops.paged_prefill_attention_impl`."""
    from repro.kernels import ops
    with ctx._tune_scope():
        return ops.paged_prefill_attention_impl(
            q, k_pool, v_pool, block_table, start, backend=ctx.impl_backend, **kw)


@register_op("ssd")
def _ssd(ctx: ExecutionContext, x, dt, a_log, b, c, **kw):
    """Mamba-2 SSD mixer; under a mesh the batch B is partitioned
    (``a_log``/``d_skip`` replicated). See
    :func:`repro.kernels.ops.ssd_impl` for the backend matrix and the
    ``initial_state`` / ``return_final_state`` resume contract."""
    from repro.kernels import ops
    with ctx._tune_scope():
        init = kw.get("initial_state")
        out_batched = (True, True) if kw.get("return_final_state") else True
        if init is not None:
            kw = dict(kw)
            del kw["initial_state"]
            return ctx._shard_call(
                lambda xx, dd, bb, cc, ii: ops.ssd_impl(
                    xx, dd, a_log, bb, cc, initial_state=ii,
                    backend=ctx.impl_backend, **kw),
                (x, dt, b, c, init), (True,) * 5, out_batched)
        return ctx._shard_call(
            lambda xx, dd, bb, cc: ops.ssd_impl(
                xx, dd, a_log, bb, cc, backend=ctx.impl_backend, **kw),
            (x, dt, b, c), (True,) * 4, out_batched)
