"""Quantization numerics of the Gemmini datapath (paper sections 2.1-2.2).

Gemmini accumulates int8 x int8 products into 32-bit accumulators and scales
the result back down with a *rounding, saturating bitshift* ("Gemmini saturates
and rounds such scaling operations to the nearest bit in order to maximize
accuracy", citing Jacob et al. [18]).  This module implements those exact
numerics as pure-jnp functions shared by the Pallas kernel epilogue, the XLA
fallback path, and the ref oracle -- so all three are bit-identical.

Also provides the host-side helpers the software library needs: per-tensor
scale calibration, fake-quant for accuracy experiments, and the
multiplier+shift decomposition used when a real-valued rescale must run on
integer hardware (gemmlowp-style fixed-point multiply).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rounding_shift(x: jnp.ndarray, shift) -> jnp.ndarray:
    """Round-half-to-even right shift of an integer tensor (Gemmini's unit).

    Equivalent to round(x / 2**shift) with ties-to-even, computed purely with
    integer ops so it lowers to the same arithmetic the PE's bitshift unit
    performs. ``shift`` may be a python int or a traced int32 scalar; shift=0
    is the identity.
    """
    x = x.astype(jnp.int32)
    shift = jnp.asarray(shift, jnp.int32)

    def do_shift(x):
        half = jnp.left_shift(jnp.int32(1), shift - 1)
        frac = jnp.bitwise_and(x, jnp.left_shift(jnp.int32(1), shift) - 1)
        shifted = jnp.right_shift(x, shift)  # arithmetic shift (floor)
        # round half to even: bump when frac > half, or frac == half and odd.
        bump = (frac > half) | ((frac == half) & (jnp.bitwise_and(shifted, 1) == 1))
        return shifted + bump.astype(jnp.int32)

    return jnp.where(shift > 0, do_shift(x), x)


def saturate(x: jnp.ndarray, dtype) -> jnp.ndarray:
    """Saturating cast to a narrower integer dtype."""
    info = jnp.iinfo(dtype)
    return jnp.clip(x, info.min, info.max).astype(dtype)


def scale_and_saturate(acc: jnp.ndarray, shift, out_dtype) -> jnp.ndarray:
    """The accumulator-output path: rounding shift then saturating cast."""
    return saturate(rounding_shift(acc, shift), out_dtype)


def quantize_multiplier(scale: float) -> Tuple[int, int]:
    """Decompose a real rescale into (int32 multiplier, right shift).

    gemmlowp-style: scale ~= multiplier * 2**-shift with multiplier in
    [2**30, 2**31). Used when layers need non-power-of-two rescales on the
    integer datapath.
    """
    if scale <= 0:
        raise ValueError("scale must be positive")
    mant, exp = np.frexp(scale)            # scale = mant * 2**exp, mant in [0.5,1)
    q = int(np.round(mant * (1 << 31)))
    if q == (1 << 31):
        q //= 2
        exp += 1
    shift = 31 - exp
    if shift < 0:
        raise ValueError(f"scale {scale} too large for fixed-point path")
    return q, int(shift)


def fixed_point_rescale(acc, multiplier: int, shift: int) -> np.ndarray:
    """int32 acc * (multiplier * 2**-shift) on integer arithmetic.

    Implements SaturatingRoundingDoublingHighMul + rounding shift, matching
    the quantized-inference reference of Jacob et al. [18]. Host-side
    (numpy int64): the *device* datapath uses the paper's power-of-two
    rounding bitshift (``rounding_shift``); non-power-of-two per-tensor
    rescales are resolved to (multiplier, shift) on the host at calibration
    time, exactly as the Gemmini software library bakes them into the
    generated header. (Not jittable: JAX CPU runs with x64 disabled, which
    would silently truncate the 62-bit product.)
    """
    acc64 = np.asarray(acc, np.int64)
    prod = acc64 * np.int64(multiplier)
    nudge = np.where(prod >= 0, np.int64(1) << 30,
                     np.int64(1) - (np.int64(1) << 30))
    q64 = prod + nudge
    # gemmlowp divides by 2^31 truncating toward zero (not a floor shift)
    high = np.sign(q64) * (np.abs(q64) >> 31)     # fits in int32
    rs = shift - 31
    if rs <= 0:                                   # scale >= 1: left shift
        return (high << (-rs)).astype(np.int32)
    # round-half-to-even right shift of the remaining factor
    half = np.int64(1) << (rs - 1)
    frac = high & ((np.int64(1) << rs) - 1)
    shifted = high >> rs
    bump = (frac > half) | ((frac == half) & ((shifted & 1) == 1))
    return (shifted + bump).astype(np.int32)


def calibrate_symmetric(x: jnp.ndarray, dtype=jnp.int8) -> float:
    """Per-tensor symmetric scale: max|x| mapped to the dtype max."""
    amax = float(jnp.max(jnp.abs(x)))
    qmax = jnp.iinfo(dtype).max
    return (amax / qmax) if amax > 0 else 1.0


def quantize(x: jnp.ndarray, scale: float, dtype=jnp.int8) -> jnp.ndarray:
    info = jnp.iinfo(dtype)
    q = jnp.round(x / scale)
    return jnp.clip(q, info.min, info.max).astype(dtype)


def dequantize(q: jnp.ndarray, scale: float) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def fake_quant(x: jnp.ndarray, scale: float, dtype=jnp.int8) -> jnp.ndarray:
    """Quantize-dequantize with a straight-through gradient estimator."""

    @jax.custom_vjp
    def _fq(x):
        return dequantize(quantize(x, scale, dtype), scale)

    def fwd(x):
        return _fq(x), None

    def bwd(_, g):
        return (g,)

    _fq.defvjp(fwd, bwd)
    return _fq(x)
