"""Optimization switches for the perf-iteration loop (EXPERIMENTS.md §Perf).

Each flag gates one beyond-paper optimization so the paper-faithful
baseline and the optimized variant can be lowered from the same source
tree and compared cell-by-cell. The dry-run CLI sets them via
``--opt name[=value]``; tests pin them explicitly.
"""

from __future__ import annotations

import os
from typing import Any, Dict

TUNE_MODES = ("off", "cached", "full")

_DEFAULTS: Dict[str, Any] = {
    # T: empirical kernel-schedule autotuner (src/repro/tune), covering all
    # three kernel classes: GEMM tile plans, attention block_q/block_k, and
    # conv co_tile. "off" = static schedules only (greedy analytic GEMM
    # plans, the kernels' shipped block defaults); "cached" = consult the
    # persistent schedule cache, static on a miss (never measures); "full"
    # = measure candidate schedules for unseen shapes and persist the
    # winners. Seeded from $GEMMINI_TUNE so whole-model launchers pick it
    # up without code changes.
    "tune_mode": os.environ.get("GEMMINI_TUNE", "off"),
    # Plan-cache file override; empty = $GEMMINI_TUNE_CACHE, else
    # ~/.cache/gemmini-repro/tile_plans.json (see repro.tune.cache).
    "tune_cache": os.environ.get("GEMMINI_TUNE_CACHE", ""),
    # A: update KV caches with a one-hot select instead of
    # dynamic-update-slice (DUS on a sequence-sharded cache forces the
    # partitioner to all-gather the whole cache; select is elementwise and
    # sharding-preserving).
    "onehot_cache_update": False,
    # B: group MoE dispatch per data-parallel shard so the scatter-add /
    # gather stay shard-local and the expert regroup lowers to an
    # all-to-all instead of a full-buffer all-reduce.
    "moe_grouped_dispatch": 0,      # truthy = group by the mesh shard grid
    # C: activation-rematerialization policy for the train step:
    # "full" (paper-style minimal residency), "dots" (save MXU outputs,
    # recompute elementwise), "none" (save everything).
    "remat_policy": "full",
    # A3: carry the stacked KV/SSM caches through the layer scan and
    # dynamic-update-slice the current layer's slice in place, instead of
    # streaming them through scan xs/ys. The xs/ys path makes XLA stage the
    # stack through f32 convert round-trips and a non-in-place update
    # fusion that rewrites the WHOLE stack every layer (measured 15 GB /
    # device/token on gemma2-2b @ 500k).
    "cache_as_carry": False,
    # A4: unroll the decode layer loop: static layer indices turn every
    # cache update into an in-place static-index DUS and remove the scan's
    # xs/ys staging entirely (decode bodies are small; HLO size is fine).
    "decode_unroll": False,
    # A2: grouped-GQA decode attention: contract per KV-head group with
    # einsum batch dims instead of jnp.repeat-ing K/V up to H heads.
    # repeat materializes an H-wide cache copy AND breaks the partitioner's
    # sharding propagation on the sequence axis (measured: SPMD falls back
    # to "involuntary full rematerialization" = all-gather of the cache).
    "gqa_grouped_decode": False,
}

_values: Dict[str, Any] = dict(_DEFAULTS)


def get(name: str) -> Any:
    return _values[name]


def set_flag(name: str, value: Any) -> None:
    if name not in _DEFAULTS:
        raise KeyError(f"unknown flag {name!r}; have {sorted(_DEFAULTS)}")
    if name == "tune_mode" and value not in TUNE_MODES:
        raise ValueError(f"tune_mode must be one of {TUNE_MODES}, got {value!r}")
    _values[name] = value


def reset() -> None:
    _values.clear()
    _values.update(_DEFAULTS)


def parse_opt(spec: str) -> None:
    """``name`` (-> True) or ``name=value`` with int/bool coercion."""
    if "=" in spec:
        name, raw = spec.split("=", 1)
        if raw.lower() in ("true", "false"):
            val: Any = raw.lower() == "true"
        else:
            try:
                val = int(raw)
            except ValueError:
                val = raw
    else:
        name, val = spec, True
    set_flag(name, val)
