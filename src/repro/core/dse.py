"""Design-space exploration engine (paper section 3).

Reproduces the paper's methodology: vary one generator parameter at a time
from the baseline (Table 1 design points), evaluate *whole workloads* (not
single layers), and report performance plus efficiency proxies.

Because we target TPUs in software, the three evaluation axes map to:
  performance  -> decoupled-queue cycle model (core.isa) over the workload's
                  full GEMM stream + measured kernel wall-time where runnable
  energy proxy -> total HBM bytes moved (the paper itself notes external
                  memory access dominates inference energy)
  area proxy   -> VMEM residency + streamed working set of the elaborated
                  schedule (scratchpad + accumulator provisioning)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import isa
from repro.core.config import (DESIGN_POINTS, PAPER_DESIGN_POINTS, Dataflow,
                               GemminiConfig)
from repro.core.tiling import TilePlan, plan_gemm

# Signature of plan_gemm; the tuner provides a measured-schedule drop-in.
PlanFn = Callable[..., TilePlan]


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """One GEMM in a workload, with an optional host-side (CPU) cost.

    ``host_flops`` models work that cannot map to the engine (im2col,
    depthwise conv, bookkeeping) -- the paper's Amdahl term.
    """

    m: int
    n: int
    k: int
    has_bias: bool = True
    repeats: int = 1
    host_flops: float = 0.0


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    gemms: Tuple[GemmShape, ...]
    # Host-only work (cycles on the host core @ engine clock) that no design
    # point can accelerate: depthwise convs, reshapes, activations glue.
    host_only_flops: float = 0.0


@dataclasses.dataclass(frozen=True)
class DSEResult:
    point: int
    workload: str
    engine_cycles: float
    host_cycles: float
    total_cycles: float
    bottleneck: str
    hbm_bytes: float
    vmem_bytes: int
    macs: float
    utilization: float

    @property
    def macs_per_cycle(self) -> float:
        return self.macs / self.total_cycles if self.total_cycles else 0.0


# Host core sustained FLOPs/cycle for non-engine work.
_HOST_FLOPS_PER_CYCLE = {"rocket": 1.0, "boom": 3.0}


def evaluate(cfg: GemminiConfig, wl: Workload, sys: isa.SystemParams,
             host: str = "rocket",
             dataflow: Optional[Dataflow] = None,
             plan_fn: Optional[PlanFn] = None) -> Dict[str, float]:
    """``plan_fn`` swaps the schedule source: default is the greedy analytic
    solver; pass ``repro.tune.tuned_plan_fn()`` to evaluate design points on
    *measured* schedules -- the measured-cost backend that calibrates this
    analytic model."""
    plan_fn = plan_fn or plan_gemm
    engine_cycles = 0.0
    hbm = 0.0
    macs = 0.0
    vmem = 0
    useful = 0.0
    bottlenecks: Dict[str, float] = {}
    for g in wl.gemms:
        plan = plan_fn(cfg, g.m, g.n, g.k, dataflow=dataflow,
                       has_bias=g.has_bias)
        t = isa.simulate(plan, cfg, sys, has_bias=g.has_bias)
        engine_cycles += t.total_cycles * g.repeats
        bottlenecks[t.bottleneck] = bottlenecks.get(t.bottleneck, 0.0) + \
            t.total_cycles * g.repeats
        hbm += (plan.hbm_read_bytes + plan.hbm_write_bytes) * g.repeats
        macs += plan.macs * g.repeats
        useful += plan.macs * plan.utilization * g.repeats
        vmem = max(vmem, plan.vmem_streamed_bytes + plan.vmem_resident_bytes)
    host_flops = wl.host_only_flops + sum(g.host_flops * g.repeats
                                          for g in wl.gemms)
    host_cycles = host_flops / _HOST_FLOPS_PER_CYCLE[host]
    return dict(engine_cycles=engine_cycles, host_cycles=host_cycles,
                total_cycles=engine_cycles + host_cycles,
                bottleneck=max(bottlenecks, key=bottlenecks.get)
                if bottlenecks else "none",
                hbm_bytes=hbm, vmem_bytes=vmem, macs=macs,
                utilization=useful / macs if macs else 0.0)


def run_design_points(wl: Workload,
                      points: Sequence[int] = tuple(range(1, 11)),
                      design_points=None,
                      plan_fn: Optional[PlanFn] = None) -> List[DSEResult]:
    """Evaluate Table-1 design points 1-10 on a workload (paper-native
    scale by default; pass config.DESIGN_POINTS for the TPU-scaled set)."""
    out = []
    for p in points:
        cfg = (design_points or PAPER_DESIGN_POINTS)[p]
        sys = isa.NARROW_BUS if p == 9 else \
            (isa.BOOM if p == 10 else isa.ROCKET)
        host = "boom" if p == 10 else "rocket"
        df = Dataflow.WS if p == 2 else (None if cfg.dataflow is not
                                         Dataflow.BOTH else Dataflow.OS)
        r = evaluate(cfg, wl, sys, host=host, dataflow=df, plan_fn=plan_fn)
        out.append(DSEResult(point=p, workload=wl.name,
                             engine_cycles=r["engine_cycles"],
                             host_cycles=r["host_cycles"],
                             total_cycles=r["total_cycles"],
                             bottleneck=r["bottleneck"],
                             hbm_bytes=r["hbm_bytes"],
                             vmem_bytes=int(r["vmem_bytes"]),
                             macs=r["macs"],
                             utilization=r["utilization"]))
    return out


# ---------------------------------------------------------------------------
# The paper's workloads, reconstructed at the GEMM-stream level.
# Layer dims from the public model definitions; host_flops carries the
# CPU-side im2col / depthwise / glue work the paper identifies.
# ---------------------------------------------------------------------------
def _conv_gemm(oh, ow, kh, kw, cin, cout, repeats=1, batch=1,
               im2col_on_host=True) -> GemmShape:
    m = oh * ow * batch
    k = kh * kw * cin
    # im2col expands the input kh*kw-fold; the paper does it on the host
    # (~1 host op per patch element moved; 1x1 convs need no reshaping).
    host = float(m * k) if im2col_on_host and (kh, kw) != (1, 1) else 0.0
    return GemmShape(m=m, n=cout, k=k, repeats=repeats, host_flops=host)


def mobilenet_v1(batch: int = 1) -> Workload:
    """MobileNetV1: pointwise convs on the engine; depthwise on the host
    (the paper's own mapping, section 3.3)."""
    gemms, host = [], 0.0
    spec = [  # (oh, cin, cout) for each pointwise conv after a dw conv
        (112, 32, 64), (56, 64, 128), (56, 128, 128), (28, 128, 256),
        (28, 256, 256), (14, 256, 512), *[(14, 512, 512)] * 5,
        (7, 512, 1024), (7, 1024, 1024)]
    # first standard 3x3 conv
    gemms.append(_conv_gemm(112, 112, 3, 3, 3, 32, batch=batch))
    for oh, cin, cout in spec:
        gemms.append(_conv_gemm(oh, oh, 1, 1, cin, cout, batch=batch))
        # depthwise 3x3 on the host: 9 MACs/output at ~5 host cycles/MAC
        # (strided gathers defeat the scalar in-order core's pipelining --
        # the paper: depthwise "take up nearly 100% of the execution time
        # in the accelerated workload")
        host += 5.0 * 9 * oh * oh * cin * batch
    gemms.append(GemmShape(m=batch, n=1000, k=1024))  # classifier
    return Workload("mobilenet", tuple(gemms), host_only_flops=host)


def _resnet_block(oh, cin, cmid, cout, stride, batch):
    return [
        _conv_gemm(oh, oh, 1, 1, cin, cmid, batch=batch),
        _conv_gemm(oh, oh, 3, 3, cmid, cmid, batch=batch),
        _conv_gemm(oh, oh, 1, 1, cmid, cout, batch=batch),
    ]


def resnet(depth: int, batch: int = 1) -> Workload:
    blocks = {50: (3, 4, 6, 3), 152: (3, 8, 36, 3)}[depth]
    gemms = [_conv_gemm(112, 112, 7, 7, 3, 64, batch=batch)]
    oh, cin = 56, 64
    for stage, nblocks in enumerate(blocks):
        cmid = 64 * (2 ** stage)
        cout = cmid * 4
        for b in range(nblocks):
            gemms += _resnet_block(oh, cin, cmid, cout, 1, batch)
            cin = cout
        oh //= 2
    gemms.append(GemmShape(m=batch, n=1000, k=2048))
    return Workload(f"resnet{depth}", tuple(gemms))


def mlp(dims: Sequence[int], batch: int = 128, name: str = "mlp") -> Workload:
    """Batched MLP inference (cloud MLPs exploit batch-level parallelism,
    paper section 2.2)."""
    gemms = [GemmShape(m=batch, n=dims[i + 1], k=dims[i])
             for i in range(len(dims) - 1)]
    return Workload(name, tuple(gemms))


# The four MLPs of Fig. 7b ([27][28][29][30]): digit MLPs, speech-enhancement
# autoencoder, multimodal net. MLP4's power-of-two dims tile better than
# MLP3's -- the paper's tiling-fit finding.
PAPER_MLPS = {
    "mlp1": mlp([784, 2500, 2000, 1500, 1000, 500, 10], name="mlp1"),
    "mlp2": mlp([784, 800, 800, 10], name="mlp2"),
    "mlp3": mlp([257, 2048, 2048, 2048, 257], name="mlp3"),
    "mlp4": mlp([512, 1024, 1024, 1024, 512, 128], name="mlp4"),
}

PAPER_DNNS = {
    "mobilenet": mobilenet_v1(),
    "resnet50": resnet(50),
    "resnet152": resnet(152),
}
