"""Gemmini generator configuration.

This module is the TPU-native analogue of the Chisel generator's parameter
space (paper §2.2).  A :class:`GemminiConfig` fully determines one "elaborated
accelerator instance": the dataflow, the systolic tile dimensions (mapped to
MXU-aligned Pallas block shapes), the input/accumulator datatypes, the
scratchpad (VMEM) budget that the tiling solver must respect, the pipelining
depth (number of in-flight double-buffered blocks), and the banking analogue.

``DESIGN_POINTS`` reproduces Table 1 of the paper (design points 1-10) with
each ASIC parameter re-targeted to its TPU analogue as documented in
DESIGN.md section 2.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Mapping, Optional, Tuple

import jax.numpy as jnp


class Dataflow(enum.Enum):
    """Systolic dataflow (paper section 2.2, "Dataflow").

    OS: output-stationary -- the C tile is resident in the (wider-bitwidth)
        accumulator while A/B stream through; K is the innermost grid axis.
    WS: weight-stationary -- the B tile is resident ("preloaded into the PEs'
        weight buffer"), A streams, partial sums accumulate into the output.
    BOTH: runtime-selectable (design point 3); the generated callable takes a
        per-call dataflow argument.
    """

    OS = "OS"
    WS = "WS"
    BOTH = "BOTH"


class Activation(enum.Enum):
    """Fused non-linear activation units (paper section 2.1)."""

    NONE = "none"
    RELU = "relu"
    RELU6 = "relu6"
    GELU = "gelu"      # beyond-paper: needed by the LM model zoo
    SILU = "silu"      # beyond-paper: needed by the LM model zoo


# dtype name -> (jnp dtype, bytes). Gemmini is datatype-generic via Scala
# typeclasses; we are datatype-generic over this table.
_DTYPES: Mapping[str, Any] = {
    "int8": jnp.int8,
    "int16": jnp.int16,
    "int32": jnp.int32,
    "bf16": jnp.bfloat16,
    "fp16": jnp.float16,
    "fp32": jnp.float32,
}


def dtype_of(name: str):
    if name not in _DTYPES:
        raise ValueError(f"unknown datatype {name!r}; options: {sorted(_DTYPES)}")
    return _DTYPES[name]


def bytes_of(name: str) -> int:
    return jnp.dtype(dtype_of(name)).itemsize


@dataclasses.dataclass(frozen=True)
class GemminiConfig:
    """One elaborated accelerator instance.

    Attributes:
      dataflow: OS / WS / BOTH (runtime-selectable).
      dim: systolic array dimension analogue. The paper's DIM x DIM PE grid
        maps to the *minimum* MXU-aligned tile granularity: operands are
        zero-padded to multiples of ``dim`` exactly as the paper zero-pads to
        the array size (section 3.3). Must be a multiple of 128 for MXU
        alignment on the lane axis (the paper's 16x16 int8 array has the same
        8b x 16-lane = 128B row granularity as a TPU lane).
      input_dtype / acc_dtype / output_dtype: datatype parameterization.
        Baseline: int8 inputs, int32 accumulation (Table 1).
      scratchpad_bytes: VMEM budget for streamed A/B/D tiles (the banked
        scratchpad). The tiling solver will not produce a schedule whose
        double-buffered working set exceeds this.
      accumulator_bytes: VMEM budget for the resident accumulator tile(s)
        (the paper's separate, wider-bitwidth accumulator SRAM).
      banks: scratchpad banking analogue -- number of concurrently live
        streamed operands the schedule may hold (A, B, D plus extra K-split
        accumulation buffers). >= 2 required for an A/B GEMM.
      pipeline_depth: grid-pipeline buffering depth. 2 = double buffering
        (the paper's fully-pipelined PE double-buffering); 1 = no overlap
        (the "fully combinational" point 6 analogue -- smaller footprint,
        lower throughput).
      max_tile_m/n/k: optional hard caps on the solver's tile search, used by
        the DSE to emulate narrower configurations.
      hbm_bytes: main-memory (HBM / the paper's DRAM behind the DMA) capacity
        the serving stack budgets long-lived state against -- the paged
        KV-cache allocator sizes its page arena from this (see
        ``repro.serving.paged_cache``). Kernel schedules never consult it,
        so it is deliberately absent from every tuner fingerprint.
    """

    dataflow: Dataflow = Dataflow.OS
    dim: int = 128
    input_dtype: str = "int8"
    acc_dtype: str = "int32"
    output_dtype: str = "int8"
    scratchpad_bytes: int = 8 * 1024 * 1024
    accumulator_bytes: int = 4 * 1024 * 1024
    banks: int = 4
    pipeline_depth: int = 2
    max_tile_m: Optional[int] = None
    max_tile_n: Optional[int] = None
    max_tile_k: Optional[int] = None
    hbm_bytes: int = 16 * 1024 * 1024 * 1024

    def __post_init__(self):
        if self.dim % 8 != 0 or self.dim <= 0:
            raise ValueError(f"dim must be a positive multiple of 8, got {self.dim}")
        if self.banks < 2:
            raise ValueError("banks >= 2 required (A and B streams)")
        if self.pipeline_depth not in (1, 2, 3):
            raise ValueError("pipeline_depth in {1,2,3}")
        dtype_of(self.input_dtype), dtype_of(self.acc_dtype), dtype_of(self.output_dtype)
        if self.scratchpad_bytes < 4 * self.dim * self.dim * bytes_of(self.input_dtype):
            raise ValueError("scratchpad too small for even one double-buffered tile pair")

    # -- convenience -------------------------------------------------------
    @property
    def input_jnp(self):
        return dtype_of(self.input_dtype)

    @property
    def acc_jnp(self):
        return dtype_of(self.acc_dtype)

    @property
    def output_jnp(self):
        return dtype_of(self.output_dtype)

    @property
    def is_quantized(self) -> bool:
        return jnp.issubdtype(dtype_of(self.input_dtype), jnp.integer)

    def replace(self, **kw) -> "GemminiConfig":
        return dataclasses.replace(self, **kw)

    def describe(self) -> str:
        return (
            f"Gemmini[{self.dataflow.value} dim={self.dim} "
            f"{self.input_dtype}->{self.acc_dtype}->{self.output_dtype} "
            f"spad={self.scratchpad_bytes//1024}KiB acc={self.accumulator_bytes//1024}KiB "
            f"banks={self.banks} pipe={self.pipeline_depth}]"
        )


# ---------------------------------------------------------------------------
# Table 1 design points, re-targeted to the TPU analogue space.
#
# The ASIC baseline is a 16x16 int8 array with a 64 KiB scratchpad. A 16x16
# int8 systolic array consumes 16B/cycle/edge; one TPU MXU pass consumes a
# 128-lane tile. We scale the *ratios* of Table 1 rather than its absolute
# SRAM sizes: dim doubles where the paper doubles DIM, scratchpad quadruples
# where the paper quadruples it, bitwidths widen identically, banking and
# pipelining map per DESIGN.md section 2. "Bus width" and "host CPU" rows are
# system-level parameters handled by the DSE's analytic DMA model (isa.py)
# and the bench harness, not by the kernel config; they keep baseline kernel
# parameters here.
# ---------------------------------------------------------------------------
_BASE = GemminiConfig()

DESIGN_POINTS: Mapping[int, GemminiConfig] = {
    1: _BASE,                                                     # baseline (OS)
    2: _BASE.replace(dataflow=Dataflow.WS),                       # WS
    3: _BASE.replace(dataflow=Dataflow.BOTH),                     # OS + WS runtime
    4: _BASE.replace(input_dtype="fp32", acc_dtype="fp32",        # 32b in / 32b acc
                     output_dtype="fp32"),
    5: _BASE.replace(dim=256),                                    # 32x32 (2x DIM)
    6: _BASE.replace(pipeline_depth=1),                           # fully combinational
    7: _BASE.replace(scratchpad_bytes=32 * 1024 * 1024),          # 4x scratchpad
    8: _BASE.replace(banks=8),                                    # more banks
    9: _BASE,                                                     # bus width (DMA model)
    10: _BASE,                                                    # host CPU (bench-level)
}

# Which Table-1 rows are kernel-level vs system-level (evaluated where).
SYSTEM_LEVEL_POINTS = {9: "bus_width_64b", 10: "host_cpu_boom"}

# ---------------------------------------------------------------------------
# Paper-native design points (Table 1 at its ORIGINAL scale: 16x16 int8
# array, 64 KiB scratchpad). These drive the analytic ISA/DSE reproduction
# of the paper's own tables -- dims here are PE counts, not MXU tiles, so
# they are never lowered to Pallas. DESIGN_POINTS above are the TPU-scaled
# retargeting used by the kernels.
# ---------------------------------------------------------------------------
_PAPER_BASE = GemminiConfig(
    dim=16, scratchpad_bytes=64 * 1024, accumulator_bytes=16 * 1024,
    banks=5, pipeline_depth=2)

PAPER_DESIGN_POINTS: Mapping[int, GemminiConfig] = {
    1: _PAPER_BASE,                                              # baseline OS
    2: _PAPER_BASE.replace(dataflow=Dataflow.WS),                # WS
    3: _PAPER_BASE.replace(dataflow=Dataflow.BOTH),              # OS + WS
    4: _PAPER_BASE.replace(input_dtype="fp32", acc_dtype="fp32",
                           output_dtype="fp32"),                 # 32b in
    5: _PAPER_BASE.replace(dim=32, accumulator_bytes=64 * 1024), # 32x32
    6: _PAPER_BASE.replace(pipeline_depth=1),                    # combinational
    7: _PAPER_BASE.replace(scratchpad_bytes=256 * 1024,          # 4x spad
                           accumulator_bytes=64 * 1024),         # (paper sec.4
                                                                 # pairs 256K/64K)
    8: _PAPER_BASE.replace(banks=33),                            # more banks
    9: _PAPER_BASE,                                              # narrow bus
    10: _PAPER_BASE,                                             # BOOM host
}
