"""Elaboration: GemminiConfig -> a concrete accelerator instance.

``elaborate(cfg)`` is the analogue of running the Chisel generator: it
produces a :class:`GemminiInstance` holding

  * ``gemm`` / ``matmul`` / ``conv2d``: the engine entry points (dispatching
    to the Pallas kernels on TPU or the XLA path for SPMD dry-runs),
  * ``header``: the "generated header file" of tiling parameters the software
    library compiles against (paper section 2.3),
  * the analytic DMA model used by the DSE.

The model zoo (src/repro/models) takes a GemminiInstance so the paper's
engine is the compute substrate of every assigned architecture.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro.core.config import Activation, Dataflow, GemminiConfig
from repro.core.tiling import TilePlan, plan_gemm
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class GemminiInstance:
    """One elaborated accelerator + its co-designed software parameters."""

    cfg: GemminiConfig
    backend: str = "xla"   # "pallas" on real TPUs; "xla" for SPMD dry-runs;
                           # "interpret" in kernel tests.

    # -- engine entry points ----------------------------------------------
    def gemm(self, a, b, d=None, *, dataflow: Optional[Dataflow] = None,
             shift: int = 0, activation: Activation = Activation.NONE,
             plan: Optional[TilePlan] = None):
        return ops.gemm(a, b, d, cfg=self.cfg, plan=plan, dataflow=dataflow,
                        shift=shift, activation=activation,
                        backend=self.backend)

    def matmul(self, a, b, **kw):
        return ops.matmul(a, b, cfg=self.cfg, backend=self.backend, **kw)

    def conv2d(self, x, w, b=None, **kw):
        return ops.conv2d(x, w, b, cfg=self.cfg, backend=self.backend, **kw)

    # -- the generated "header file" ---------------------------------------
    def header(self, m: int, n: int, k: int, *,
               dataflow: Optional[Dataflow] = None,
               has_bias: bool = False) -> Dict[str, Any]:
        """Tiling parameters for an (m, n, k) GEMM, as the generator emits
        them for the software library."""
        plan = plan_gemm(self.cfg, m, n, k, dataflow=dataflow,
                         has_bias=has_bias)
        return {
            "DIM": self.cfg.dim,
            "TILE_M": plan.tile_m, "TILE_N": plan.tile_n,
            "TILE_K": plan.tile_k, "GRID": plan.grid,
            "SPAD_BYTES": self.cfg.scratchpad_bytes,
            "ACC_BYTES": self.cfg.accumulator_bytes,
            "DATAFLOW": plan.dataflow.value,
            "UTILIZATION": plan.utilization,
            "ARITH_INTENSITY": plan.arithmetic_intensity,
        }

    def plan(self, m: int, n: int, k: int, **kw) -> TilePlan:
        return plan_gemm(self.cfg, m, n, k, **kw)

    def with_backend(self, backend: str) -> "GemminiInstance":
        return dataclasses.replace(self, backend=backend)


def default_engine_backend() -> str:
    """The engine backend for launchers on this host: the Pallas kernels
    (where tile plans -- tuned or greedy -- govern execution) on a TPU,
    the plan-free XLA SPMD path everywhere else."""
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "xla"


@functools.lru_cache(maxsize=64)
def elaborate(cfg: GemminiConfig, backend: str = "xla") -> GemminiInstance:
    """Run the generator: validate the parameterization and build an instance."""
    # Elaboration-time legality checks (the Chisel generator's require()s).
    min_tile = cfg.dim * cfg.dim
    if cfg.accumulator_bytes < min_tile * jnp.dtype(cfg.acc_jnp).itemsize:
        raise ValueError("accumulator cannot hold one output tile")
    if backend not in ("xla", "pallas", "interpret"):
        raise ValueError(f"unknown backend {backend!r}")
    return GemminiInstance(cfg=cfg, backend=backend)
