"""Elaboration: GemminiConfig -> a concrete accelerator instance.

``elaborate(cfg)`` is the analogue of running the Chisel generator: it
produces a :class:`GemminiInstance` holding

  * ``ctx``: the instance's :class:`repro.core.context.ExecutionContext` --
    the mesh-aware dispatch value every op launch goes through (and what
    the model zoo actually consumes; ``gemm``/``matmul``/``conv2d`` here
    are convenience delegates),
  * ``header``: the "generated header file" of tiling parameters the software
    library compiles against (paper section 2.3),
  * the analytic DMA model used by the DSE.

The model zoo (src/repro/models) takes a GemminiInstance *or* a bare
ExecutionContext so the paper's engine is the compute substrate of every
assigned architecture; ``with_mesh`` derives an instance whose kernels run
inside ``shard_map`` with per-device shapes (the jit+GSPMD request path).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro.core.config import Activation, Dataflow, GemminiConfig
from repro.core.context import ExecutionContext
from repro.core.tiling import TilePlan, plan_gemm


@dataclasses.dataclass(frozen=True)
class GemminiInstance:
    """One elaborated accelerator + its co-designed software parameters."""

    cfg: GemminiConfig
    backend: str = "xla"   # "pallas" on real TPUs; "xla" for SPMD dry-runs;
                           # "interpret" in kernel tests.
    mesh: Any = None       # partitioned dispatch: kernels run in shard_map
    axis: Any = "data"     # mesh axis the batch-like dims shard over

    # -- dispatch ----------------------------------------------------------
    @functools.cached_property
    def ctx(self) -> ExecutionContext:
        """The instance's execution context (backend + tune policy +
        partitioning in one frozen value); all op dispatch routes here."""
        return ExecutionContext(cfg=self.cfg, backend=self.backend,
                                mesh=self.mesh, axis=self.axis)

    # -- engine entry points (delegates into ctx) --------------------------
    def gemm(self, a, b, d=None, *, dataflow: Optional[Dataflow] = None,
             shift: int = 0, activation: Activation = Activation.NONE,
             plan: Optional[TilePlan] = None):
        return self.ctx.gemm(a, b, d, plan=plan, dataflow=dataflow,
                             shift=shift, activation=activation)

    def matmul(self, a, b, **kw):
        return self.ctx.matmul(a, b, **kw)

    def conv2d(self, x, w, b=None, **kw):
        return self.ctx.conv2d(x, w, b, **kw)

    # -- the generated "header file" ---------------------------------------
    def header(self, m: int, n: int, k: int, *,
               dataflow: Optional[Dataflow] = None,
               has_bias: bool = False) -> Dict[str, Any]:
        """Tiling parameters for an (m, n, k) GEMM, as the generator emits
        them for the software library."""
        plan = plan_gemm(self.cfg, m, n, k, dataflow=dataflow,
                         has_bias=has_bias)
        return {
            "DIM": self.cfg.dim,
            "TILE_M": plan.tile_m, "TILE_N": plan.tile_n,
            "TILE_K": plan.tile_k, "GRID": plan.grid,
            "SPAD_BYTES": self.cfg.scratchpad_bytes,
            "ACC_BYTES": self.cfg.accumulator_bytes,
            "DATAFLOW": plan.dataflow.value,
            "UTILIZATION": plan.utilization,
            "ARITH_INTENSITY": plan.arithmetic_intensity,
        }

    def plan(self, m: int, n: int, k: int, **kw) -> TilePlan:
        return plan_gemm(self.cfg, m, n, k, **kw)

    def with_backend(self, backend: str) -> "GemminiInstance":
        return dataclasses.replace(self, backend=backend)

    def with_mesh(self, mesh, axis: Any = "data") -> "GemminiInstance":
        """Derive a mesh-aware instance: inside a jit+GSPMD step its
        pallas/interpret kernels run under ``shard_map`` and resolve
        schedules at PER-DEVICE shapes (warm with
        ``tune.warm_model_plans(n_shards=...)``); the xla backend is
        untouched (GSPMD already partitions it)."""
        return dataclasses.replace(self, mesh=mesh, axis=axis)


def default_engine_backend() -> str:
    """The engine backend for launchers on this host: the Pallas kernels
    (where tile plans -- tuned or greedy -- govern execution) on a TPU,
    the plan-free XLA SPMD path everywhere else."""
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "xla"


@functools.lru_cache(maxsize=64)
def elaborate(cfg: GemminiConfig, backend: str = "xla") -> GemminiInstance:
    """Run the generator: validate the parameterization and build an instance."""
    # Elaboration-time legality checks (the Chisel generator's require()s).
    min_tile = cfg.dim * cfg.dim
    if cfg.accumulator_bytes < min_tile * jnp.dtype(cfg.acc_jnp).itemsize:
        raise ValueError("accumulator cannot hold one output tile")
    if backend not in ("xla", "pallas", "interpret"):
        raise ValueError(f"unknown backend {backend!r}")
    return GemminiInstance(cfg=cfg, backend=backend)
