"""Software model of the Gemmini ISA and its decoupled access/execute timing.

The paper programs the accelerator with mvin / mvout / compute instructions
issued to three parallel queues (LOAD / STORE / EXECUTE), with software-
encoded inter-queue dependencies (section 2.3). We model that machine
analytically: given a TilePlan and system parameters (bus width, memory
latency, requests-in-flight), emit the instruction stream a tiled GEMM
produces and compute its steady-state cycle count under the decoupled
queue model.

This is what reproduces the paper's *system-level* findings without RTL:

  * design point 9 (bus width 128b -> 64b): no slowdown when the machine is
    bound by round-trip latency x max-requests-in-flight rather than by
    bus bandwidth ("This limitation turns a bandwidth constraint into a
    memory latency constraint").
  * design point 7 (4x scratchpad): larger tiles -> fewer HBM re-reads, but
    no gain once the EXECUTE queue is the bottleneck (CPU-limited DNNs).
  * design point 5 (2x array dim): mvin moves DIM rows per instruction, so
    doubling DIM doubles effective bandwidth and quadruples compute
    throughput (paper: "2x-4x depending on reuse").
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterator, List, Tuple

from repro.core.config import Dataflow, GemminiConfig, bytes_of
from repro.core.tiling import TilePlan


class Op(enum.Enum):
    MVIN = "mvin"
    MVOUT = "mvout"
    COMPUTE = "compute"
    CONFIG = "config"


@dataclasses.dataclass(frozen=True)
class Instr:
    op: Op
    bytes: int = 0          # data moved (mvin/mvout)
    macs: int = 0           # work (compute)
    queue: str = ""         # LOAD / STORE / EX


@dataclasses.dataclass(frozen=True)
class SystemParams:
    """SoC-level parameters (paper section 2.2, 'System Parameters').

    The mvin unit requests one systolic-dimension row at a time (the paper:
    "requests multiple systolic-dimension matrix rows at a time ...
    increasing the array dimension results in larger blocks of memory
    requested per mvin"), so the latency-bound effective bandwidth is

        inflight * (DIM * input_bytes) / round_trip_latency

    which is what makes design point 9 (bus width) a no-op when the machine
    is latency-bound, and design point 5 (2x DIM) double the effective
    bandwidth -- both of the paper's system-level findings.
    """

    bus_bytes: int = 16            # 128-bit TileLink beat
    mem_latency_cycles: int = 80   # round-trip to LLC/DRAM
    max_inflight: int = 16         # outstanding memory requests
    host_issue_rate: float = 1.0   # instructions/cycle the host can issue
                                   # (Rocket ~1.0; BOOM ~3.0 for this stream)

    def effective_bw(self, request_bytes: int) -> float:
        """bytes/cycle: min(bus limit, latency x in-flight limit)."""
        latency_bw = self.max_inflight * request_bytes / \
            self.mem_latency_cycles
        return min(float(self.bus_bytes), latency_bw)


ROCKET = SystemParams()
BOOM = SystemParams(host_issue_rate=3.0)
NARROW_BUS = SystemParams(bus_bytes=8)   # design point 9


def instruction_stream(plan: TilePlan, cfg: GemminiConfig,
                       has_bias: bool = False) -> Iterator[Instr]:
    """The instruction stream the tiled-GEMM library emits for one GEMM."""
    in_b = bytes_of(cfg.input_dtype)
    acc_b = bytes_of(cfg.acc_dtype)
    out_b = bytes_of(cfg.output_dtype)
    gm, gn, gk = plan.grid
    tm, tn, tk = plan.tile_m, plan.tile_n, plan.tile_k
    yield Instr(Op.CONFIG)
    if plan.dataflow is Dataflow.OS:
        for i in range(gm):
            for j in range(gn):
                if has_bias:
                    yield Instr(Op.MVIN, bytes=tm * tn * acc_b, queue="LOAD")
                for kk in range(gk):
                    yield Instr(Op.MVIN, bytes=tm * tk * in_b, queue="LOAD")
                    yield Instr(Op.MVIN, bytes=tk * tn * in_b, queue="LOAD")
                    yield Instr(Op.COMPUTE, macs=tm * tn * tk, queue="EX")
                yield Instr(Op.MVOUT, bytes=tm * tn * out_b, queue="STORE")
    else:  # WS: B preloaded once per (n, k); A streams; acc read-modify-write
        for j in range(gn):
            for kk in range(gk):
                yield Instr(Op.MVIN, bytes=tk * tn * in_b, queue="LOAD")
                for i in range(gm):
                    yield Instr(Op.MVIN, bytes=tm * tk * in_b, queue="LOAD")
                    yield Instr(Op.COMPUTE, macs=tm * tn * tk, queue="EX")
            for i in range(gm):
                yield Instr(Op.MVOUT, bytes=tm * tn * out_b, queue="STORE")


@dataclasses.dataclass(frozen=True)
class QueueTiming:
    load_cycles: float
    store_cycles: float
    ex_cycles: float
    issue_cycles: float
    n_instrs: int

    @property
    def total_cycles(self) -> float:
        """Decoupled queues overlap; steady state is bound by the slowest."""
        return max(self.load_cycles, self.store_cycles, self.ex_cycles,
                   self.issue_cycles)

    @property
    def bottleneck(self) -> str:
        vals = {"LOAD": self.load_cycles, "STORE": self.store_cycles,
                "EX": self.ex_cycles, "ISSUE": self.issue_cycles}
        return max(vals, key=vals.get)


def simulate(plan: TilePlan, cfg: GemminiConfig, sys: SystemParams,
             has_bias: bool = False) -> QueueTiming:
    """Steady-state cycle model of the decoupled access/execute machine."""
    load_bytes = store_bytes = macs = n = 0
    for ins in instruction_stream(plan, cfg, has_bias):
        n += 1
        if ins.op is Op.MVIN:
            load_bytes += ins.bytes
        elif ins.op is Op.MVOUT:
            store_bytes += ins.bytes
        elif ins.op is Op.COMPUTE:
            macs += ins.macs

    # Memory queues: bounded by min(bus bandwidth, latency-bound bandwidth).
    # mvin granularity: one DIM-row buffer per outstanding request; the row
    # buffer is sized at elaboration for the *baseline* 8-bit lane (DIM
    # bytes), so wider datatypes stream more requests for the same tile --
    # which is exactly why design point 4 (32-bit) loses locality AND
    # bandwidth while design point 5 (2x DIM) gains both.
    req_bytes = cfg.dim
    eff_bw = sys.effective_bw(req_bytes)
    load_cycles = load_bytes / eff_bw
    store_cycles = store_bytes / eff_bw
    # EXECUTE queue: DIM*DIM MACs/cycle (fully pipelined); /2 if depth-1
    # pipeline halves achievable frequency-normalized throughput.
    macs_per_cycle = cfg.dim * cfg.dim * (1.0 if cfg.pipeline_depth > 1 else 0.5)
    ex_cycles = macs / macs_per_cycle
    issue_cycles = n / sys.host_issue_rate
    return QueueTiming(load_cycles, store_cycles, ex_cycles, issue_cycles, n)
