"""The bounded action alphabet the checker interleaves.

Each action is one atomic control-plane transition on a
:class:`~repro.analysis.mc.harness.NullEngine`:

* ``submit``        -- submit the next workload request
* ``prefill``       -- one admission-boundary phase (``control_prefill``:
  shed expired, admit/restore/prefix-match, execute exactly one prefill
  chunk under the budget-1 rule, drain rejections)
* ``decode``        -- one decode-boundary phase (``control_decode``:
  ensure capacity with eviction under pressure, shed expired, commit one
  token per active slot)
* ``preempt``       -- evict the scheduler's canonical victim (the
  youngest-admitted runner) mid-flight
* ``defrag``        -- arena compaction
* ``host_evict``    -- host-pool LRU eviction (capacity pressure as an
  explicit action rather than only a side effect of ``host_put``)
* ``tick``          -- advance the logical clock by 1 (deadline progress)
* ``fault:<kind>``  -- arm a one-shot (p=1, max=1) injector of ``kind``;
  it fires inside whatever phase next visits a matching site, and the
  spent injector retires to ``eng.mc_fired`` so exploration state stays
  finite

``prefill`` and ``decode`` are exactly the two sub-phases
``EngineControlPlane.step`` composes, so every interleaving the checker
drives is a behavior of the real engine loop (plus the adversarial ones
-- back-to-back decodes, preempt-during-prefill -- that a fault or
multi-host scheduler could induce).
"""

from __future__ import annotations

from typing import List

from repro.runtime import faults as rfaults
from repro.analysis.mc.harness import MCConfig, NullEngine


def enabled_actions(eng: NullEngine) -> List[str]:
    cfg: MCConfig = eng.mc_cfg
    s = eng.sched
    acts: List[str] = []
    if len(eng.requests) < len(cfg.prompts):
        acts.append("submit")
    if s.queue or any(r.prefilling for r in s.running.values()):
        acts.append("prefill")
    if any(not r.prefilling for r in s.running.values()):
        acts.append("decode")
    if cfg.allow_preempt and s.running:
        acts.append("preempt")
    if cfg.allow_defrag and eng.alloc.used_pages > 0:
        acts.append("defrag")
    if cfg.kv_offload and eng.alloc._host:
        acts.append("host_evict")
    if cfg.enforce_deadlines and eng.clock.t < cfg.max_ticks:
        acts.append("tick")
    if eng.faults is None and len(eng.mc_fired) < cfg.max_faults:
        acts.extend(f"fault:{k}" for k in cfg.fault_kinds)
    return acts


def _arm_fault(eng: NullEngine, kind_spec: str) -> None:
    """Install a one-shot injector: p=1, full window, max_hits=1. Those
    bounds are what make dropping the injector's draw counters from the
    canonical hash sound -- firing depends only on hits remaining, never
    on how many draws went by."""
    kind, _, site = kind_spec.partition("@")
    site = site or ("*" if kind in ("nan", "inf", "transient")
                    else rfaults.DEFAULT_SITES.get(kind, "*"))
    plan = rfaults.FaultPlan(seed=0, specs=(
        rfaults.FaultSpec(kind=kind, site=site, p=1.0, max_hits=1),))
    eng.faults = rfaults.FaultInjector(plan)


def apply_action(eng: NullEngine, action: str) -> None:
    """Apply one alphabet action. Raises on an action that is not enabled
    in this state (replay of a minimized trace probes enablement first).
    """
    cfg: MCConfig = eng.mc_cfg
    if action == "submit":
        i = len(eng.requests)
        rel = cfg.deadlines[i] if i < len(cfg.deadlines) else None
        eng.submit(
            list(cfg.prompts[i]), cfg.max_new[i],
            deadline=(eng.now() + rel) if rel is not None else None)
    elif action == "prefill":
        eng.control_prefill(admit_new=True)
    elif action == "decode":
        eng.control_decode()
    elif action == "preempt":
        eng.sched.preempt(eng.sched._eviction_victim())
    elif action == "defrag":
        eng.defrag()
    elif action == "host_evict":
        eng.alloc.host_evict_lru()
    elif action == "tick":
        eng.clock.advance(1.0)
    elif action.startswith("fault:"):
        _arm_fault(eng, action[len("fault:"):])
    else:
        raise ValueError(f"unknown mc action {action!r}")
    # retire a spent one-shot injector: its kind is logged, its draw
    # counters leave the state
    if eng.faults is not None and eng.faults.total_injected >= 1:
        eng.mc_fired.append(eng.faults.plan.specs[0].kind)
        eng.faults = None
