"""Control-plane model checker (docs/analysis.md#model-checker).

Explicit-state bounded model checking of the serving control plane: the
REAL :class:`~repro.serving.scheduler.ContinuousScheduler`,
:class:`~repro.serving.paged_cache.PagedKVAllocator`, and
:class:`~repro.serving.engine.EngineControlPlane` recovery logic -- not a
re-model -- driven through every interleaving of a bounded action
alphabet (submit, prefill-chunk commit, decode commit, preempt, defrag,
host-pool LRU eviction, deadline tick, fault arm/fire) over small
configurations (2-4 slots, 8-16 pages, 2-4 requests).

The split that makes this possible is the engine's control/compute seam:
:class:`~repro.analysis.mc.harness.NullEngine` implements the compute
hooks with fabricated deterministic token commits, so a state is pure
Python (deepcopy-able, canonically hashable) and a few microseconds to
step.

* `harness` -- the null executor + the bounded configurations,
* `actions` -- the action alphabet (enablement + application),
* `canon`   -- canonical state hashing (page/seq relabeling) for
  memoization,
* `invariants` -- per-transition safety (GL801-805, GL807) and the
  graph-level wedge/liveness checks (GL804, GL806),
* `explore` -- BFS exploration, counterexample minimization, replay,
* `__main__` -- the CLI + CI gate (`python -m repro.analysis.mc`),
  reporting violations as GL8xx findings through the `analysis/lint`
  findings/baseline machinery (empty baseline policy: a counterexample
  is a bug to fix + a regression to lock, never a baseline entry).
"""

from repro.analysis.mc.harness import (        # noqa: F401
    ALL_CONFIGS, CONFIGS, SELFTEST_CONFIGS, LogicalClock, MCConfig,
    NullEngine, build_engine)
# NOTE: the explore() FUNCTION is deliberately not re-exported here --
# it would shadow the `explore` submodule attribute on this package and
# make `from repro.analysis.mc import explore` ambiguous. Import it from
# the submodule: `from repro.analysis.mc.explore import explore`.
from repro.analysis.mc.explore import (        # noqa: F401
    MCResult, Violation, format_spec, minimize, parse_spec, replay)
