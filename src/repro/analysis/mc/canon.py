"""Canonical state hashing for memoization.

Two explored states must hash equal iff no sequence of future actions can
distinguish them. The hash therefore covers every decision input of the
control plane -- and ONLY decision inputs:

* the allocator, with physical page ids relabeled by a fixed traversal
  order (slot tables in slot order, then prefix index in LRU order, then
  held pages, then the free list in pop order). Page ids are opaque to a
  tensor-free engine, so states differing only in page naming are
  bisimilar; the free list's POP order is decision-relevant (it dictates
  which label the next allocation binds) and is preserved by the
  relabeling.
* per-request progress: state, slot, generated-token COUNT (values are a
  pure function of (rid, count) under the null executor), cache/prefill
  positions, chunk anchor, priority/deadline/submitted_at (admission-sort
  keys), and ``n_preempted`` clamped to {0, 1} -- only the ``== 0``
  distinction feeds any decision (queue ordering), and the raw count
  would make preempt/re-admit cycles an infinite state space.
* ``admitted_seq`` as a RANK over all ever-admitted requests: eviction
  and younger-than comparisons are order-relations, the raw monotone
  counter is not bounded.
* scheduler queue order, the running dict's INSERTION order (it fixes
  decode-commit order, which fixes free-list order on finish), the host
  pool's LRU order, and the logical clock.
* the armed-fault kind (if any) and the retired-fault log. Draw counters
  are excluded -- sound only because mc faults are one-shot p=1
  full-window specs (see ``actions._arm_fault``).

Excluded: metrics, tracer buffers, watchdog, fabricated token values,
timestamps other than ``submitted_at``/``deadline`` -- all write-only
telemetry the control plane never reads back.
"""

from __future__ import annotations

import hashlib
from typing import Tuple

from repro.analysis.mc.harness import NullEngine


def state_tuple(eng: NullEngine) -> Tuple:
    """The canonical (hash-ready) structural view of an engine state."""
    snap = eng.alloc.snapshot()
    relabel: dict = {}

    def lab(p: int) -> int:
        if p not in relabel:
            relabel[p] = len(relabel)
        return relabel[p]

    tables = tuple((slot, tuple(lab(p) for p in pages))
                   for slot, pages in snap["tables"].items())
    prefix = tuple((key, lab(p)) for key, p in snap["prefix"])
    held = tuple(lab(p) for p in snap["held"])
    free = tuple(lab(p) for p in snap["free_pop_order"])
    ref = tuple(sorted((lab(p), r) for p, r in snap["ref"].items()))
    host = tuple(snap["host"])

    seqs = sorted({r.admitted_seq for r in eng.requests
                   if r.admitted_seq >= 0})
    rank = {s: i for i, s in enumerate(seqs)}
    reqs = tuple(
        (r.rid, r.state, r.slot,
         min(r.n_preempted, 1),
         rank.get(r.admitted_seq, -1),
         r.n_generated, r.cache_len,
         # n_chunks is cumulative telemetry (preempt cycles grow it
         # without bound) and never feeds a decision: excluded
         r.prefill_pos, r.prefill_target, r.chunk_anchor,
         bool(r.truncated), r.shed_reason,
         r.priority, r.deadline, r.submitted_at,
         len(r.prompt), r.max_new_tokens,
         tuple(r.prefix_keys) if r.prefix_keys else None)
        for r in eng.requests)

    sched = (tuple(r.rid for r in eng.sched.queue),
             tuple((slot, r.rid) for slot, r in eng.sched.running.items()),
             tuple(r.rid for r in eng.sched.rejected))

    fault = (eng.faults.plan.specs[0].kind
             if eng.faults is not None else None,
             tuple(eng.mc_fired))

    return (tables, prefix, held, free, ref, host, reqs, sched, fault,
            round(eng.clock.t, 9))


def canonical_state(eng: NullEngine) -> str:
    """16-hex-char canonical hash of the state."""
    return hashlib.sha256(
        repr(state_tuple(eng)).encode()).hexdigest()[:16]
