"""Safety invariants checked on every explored transition, plus the
graph-level flags the wedge/liveness checks consume.

The GL8xx table (docs/analysis.md#model-checker):

======  ===================================================================
GL801   allocator ownership invariant broken (``PagedKVAllocator.check()``
        failed after an action -- the property suite's oracle, now run on
        EVERY reachable interleaving)
GL802   token-prefix rewind: a request's committed token stream is not a
        prefix-preserving extension of its pre-action stream
GL803   defrag conservation: compaction changed page accounting (used /
        prefix-index / per-slot table lengths / refcount multiset / host
        pool)
GL804   arena wedge: a reachable state from which no state satisfying
        ``can_admit(page_size) or drained`` is reachable (graph check;
        only sound when exploration is exhaustive)
GL805   terminal request retains resources: a finished/shed request still
        owns a slot table or a host-pool entry, or a mapped slot has no
        running request
GL806   bounded-fairness liveness: a reachable state from which no
        drained state (every submitted request terminal, scheduler idle)
        is reachable within the explored horizon (graph check)
GL807   unhandled exception escaping the control plane under a legal
        action
======  ===================================================================
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.analysis.mc.harness import NullEngine


@dataclasses.dataclass(frozen=True)
class Flags:
    """Per-state predicates for the graph-level checks."""

    can_admit: bool
    drained: bool


def state_flags(eng: NullEngine) -> Flags:
    all_submitted = len(eng.requests) == len(eng.mc_cfg.prompts)
    drained = (all_submitted and not eng.sched.has_work
               and all(r.state in ("finished", "shed")
                       for r in eng.requests))
    return Flags(can_admit=eng.alloc.can_admit(eng.page_size),
                 drained=drained)


def pre_snapshot(eng: NullEngine) -> Dict:
    """The pre-action facts the post-action checks compare against."""
    al = eng.alloc
    return {
        "gen": {r.rid: tuple(int(t) for t in r.generated)
                for r in eng.requests},
        "used": al.used_pages,
        "prefix_pages": al.prefix_index_pages,
        "tables_len": {s: len(p) for s, p in al._tables.items()},
        "ref_multiset": tuple(sorted(al._ref.values())),
        "host": tuple((rid, sp.n_pages) for rid, sp in al._host.items()),
    }


def check_transition(eng: NullEngine, pre: Dict, action: str,
                     exc: Optional[BaseException]
                     ) -> List[Tuple[str, str]]:
    """-> [(code, message)] for every invariant the transition broke."""
    out: List[Tuple[str, str]] = []
    if exc is not None:
        out.append(("GL807", f"unhandled {type(exc).__name__} escaping "
                             f"the control plane on {action!r}: {exc}"))
        return out              # post-state is not meaningful past a raise

    # GL801: the allocator's own ownership oracle
    try:
        eng.alloc.check()
    except AssertionError as e:
        out.append(("GL801", f"allocator invariant broken after "
                             f"{action!r}: {e}"))

    # GL802: committed token streams only ever grow by appending
    for r in eng.requests:
        before = pre["gen"].get(r.rid, ())
        now = tuple(int(t) for t in r.generated)
        if len(now) < len(before) or now[:len(before)] != before:
            out.append(("GL802", f"token-prefix rewind on rid {r.rid} "
                                 f"after {action!r}: {before} -> {now}"))

    # GL803: defrag is accounting-invariant
    if action == "defrag":
        al = eng.alloc
        post = {"used": al.used_pages,
                "prefix_pages": al.prefix_index_pages,
                "tables_len": {s: len(p) for s, p in al._tables.items()},
                "ref_multiset": tuple(sorted(al._ref.values())),
                "host": tuple((rid, sp.n_pages)
                              for rid, sp in al._host.items())}
        for k in post:
            if post[k] != pre[k]:
                out.append(("GL803", f"defrag changed {k}: "
                                     f"{pre[k]} -> {post[k]}"))

    # GL805: terminal requests hold nothing; mapped slots are running
    running_rids = {r.rid for r in eng.sched.running.values()}
    mapped_slots = set(eng.alloc._tables)
    if mapped_slots != set(eng.sched.running):
        out.append(("GL805", f"mapped slots {sorted(mapped_slots)} != "
                             f"running slots "
                             f"{sorted(eng.sched.running)} after "
                             f"{action!r}"))
    for r in eng.requests:
        if r.state not in ("finished", "shed"):
            continue
        if r.rid in running_rids:
            out.append(("GL805", f"terminal rid {r.rid} still running "
                                 f"after {action!r}"))
        if eng.alloc.host_peek(r.rid) is not None:
            out.append(("GL805", f"terminal rid {r.rid} still holds a "
                                 f"host-pool spill after {action!r}"))
        if r.state == "shed" and r.slot != -1:
            out.append(("GL805", f"shed rid {r.rid} kept slot {r.slot} "
                                 f"after {action!r}"))
    return out
