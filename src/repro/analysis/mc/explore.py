"""Explicit-state BFS exploration, counterexample handling, replay.

Exploration is breadth-first over canonical state hashes: the parent
pointers therefore give a SHORTEST action trace to every state, which is
the seed :func:`minimize` shrinks further by greedy deletion (re-replaying
the candidate trace after each removal). Engines are deep-copied per
transition and held only on the frontier; expanded states keep just their
hash, flags, and edges.

The per-transition safety checks (GL801/802/803/805/807) run on every
edge. The graph-level checks run only when exploration is EXHAUSTIVE
(every reachable state expanded, no state/depth cap hit):

* GL804 (arena wedge): every reachable state must reach some state where
  ``can_admit(page_size)`` holds or the workload is drained -- computed
  as backward reachability from the good set over reversed edges.
* GL806 (bounded-fairness liveness): every reachable state must reach a
  drained state (all submitted requests terminal, scheduler idle).

Counterexample replay is deterministic by construction -- the null
engine's only inputs are the config and the action trace -- and
:func:`replay` re-executes a trace to (violation, final state hash), the
pair the exported pytest regression pins.
"""

from __future__ import annotations

import collections
import copy
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

from repro.analysis.mc.actions import apply_action, enabled_actions
from repro.analysis.mc.canon import canonical_state
from repro.analysis.mc.harness import (ALL_CONFIGS, MCConfig, build_engine)
from repro.analysis.mc.invariants import (Flags, check_transition,
                                          pre_snapshot, state_flags)

# codes with a per-transition witness (minimizable by replay); GL804/806
# are graph properties whose BFS trace is already shortest
TRANSITION_CODES = ("GL801", "GL802", "GL803", "GL805", "GL807")


@dataclasses.dataclass(frozen=True)
class Violation:
    code: str
    message: str
    trace: Tuple[str, ...]
    state_hash: str                 # canonical hash of the violating state
    config: str


@dataclasses.dataclass
class MCResult:
    config: str
    states: int
    transitions: int
    memo_hits: int
    terminal_states: int            # drained states reached
    complete: bool                  # exhaustive (no cap hit)
    violations: List[Violation]
    wall_s: float

    def to_dict(self) -> Dict:
        return {"config": self.config, "states": self.states,
                "transitions": self.transitions,
                "memo_hits": self.memo_hits,
                "terminal_states": self.terminal_states,
                "complete": self.complete,
                "violations": [dataclasses.asdict(v)
                               for v in self.violations],
                "wall_s": round(self.wall_s, 3)}


@dataclasses.dataclass
class ReplayResult:
    violation: Optional[Violation]  # first violation of the stop transition
    state_hash: str                 # hash after the last executed action
    executed: int                   # actions executed before stop
    valid: bool                     # False: an action was not enabled
    # one transition can break several invariants at once (e.g. a planted
    # defrag leak is both GL801 and GL803); all of them, in check order
    violations: Tuple[Violation, ...] = ()


def _path_to(parents: Dict[str, Tuple[Optional[str], Optional[str]]],
             h: str) -> Tuple[str, ...]:
    out: List[str] = []
    while True:
        ph, a = parents[h]
        if ph is None:
            break
        out.append(a)
        h = ph
    return tuple(reversed(out))


def explore(cfg: MCConfig, *, max_states: int = 200_000,
            max_depth: Optional[int] = None,
            check_liveness: bool = True,
            max_violations: int = 16) -> MCResult:
    """Exhaust (or cap) the interleaving space of one configuration."""
    t0 = time.perf_counter()
    eng0 = build_engine(cfg)
    h0 = canonical_state(eng0)
    parents: Dict[str, Tuple[Optional[str], Optional[str]]] = {
        h0: (None, None)}
    flags: Dict[str, Flags] = {h0: state_flags(eng0)}
    edges: Dict[str, List[Tuple[str, str]]] = {}
    frontier = collections.deque([(h0, eng0, 0)])
    violations: List[Violation] = []
    transitions = memo_hits = 0
    complete = True

    while frontier:
        h, eng, depth = frontier.popleft()
        if max_depth is not None and depth >= max_depth:
            complete = False
            edges[h] = []
            continue
        outs: List[Tuple[str, str]] = []
        for a in enabled_actions(eng):
            child = copy.deepcopy(eng)
            pre = pre_snapshot(child)
            exc: Optional[BaseException] = None
            try:
                apply_action(child, a)
            except Exception as e:        # noqa: BLE001 - GL807 material
                exc = e
            transitions += 1
            viols = check_transition(child, pre, a, exc)
            if viols:
                tr = _path_to(parents, h) + (a,)
                vh = canonical_state(child) if exc is None else "exception"
                for code, msg in viols:
                    violations.append(Violation(code, msg, tr, vh,
                                                cfg.name))
                continue              # do not explore past a violation
            ch = canonical_state(child)
            outs.append((a, ch))
            if ch in parents:
                memo_hits += 1
            else:
                parents[ch] = (h, a)
                flags[ch] = state_flags(child)
                if len(parents) > max_states:
                    complete = False
                else:
                    frontier.append((ch, child, depth + 1))
        edges[h] = outs
        if len(violations) >= max_violations:
            complete = False
            break

    if complete:
        violations += _graph_checks(cfg, parents, flags, edges,
                                    check_liveness=check_liveness)

    terminal = sum(1 for f in flags.values() if f.drained)
    return MCResult(config=cfg.name, states=len(parents),
                    transitions=transitions, memo_hits=memo_hits,
                    terminal_states=terminal, complete=complete,
                    violations=violations,
                    wall_s=time.perf_counter() - t0)


def _graph_checks(cfg: MCConfig, parents, flags, edges,
                  *, check_liveness: bool) -> List[Violation]:
    """GL804/GL806 over the complete reachability graph: backward
    reachability from the good set; any state outside it is a witness.
    One violation per check, anchored at the shortest-trace witness."""
    rev: Dict[str, List[str]] = collections.defaultdict(list)
    for src, outs in edges.items():
        for _a, dst in outs:
            rev[dst].append(src)

    def backward_reach(good: List[str]) -> set:
        seen = set(good)
        stack = list(good)
        while stack:
            h = stack.pop()
            for p in rev.get(h, ()):
                if p not in seen:
                    seen.add(p)
                    stack.append(p)
        return seen

    def witness(bad: List[str]) -> str:
        return min(bad, key=lambda h: len(_path_to(parents, h)))

    out: List[Violation] = []
    admit_ok = [h for h, f in flags.items() if f.can_admit or f.drained]
    bad = [h for h in parents if h not in backward_reach(admit_ok)]
    if bad:
        w = witness(bad)
        out.append(Violation(
            code="GL804",
            message=(f"arena wedge: {len(bad)} reachable state(s) from "
                     f"which neither can_admit({cfg.page_size}) nor a "
                     f"drained workload is reachable"),
            trace=_path_to(parents, w), state_hash=w, config=cfg.name))

    if check_liveness:
        drained = [h for h, f in flags.items() if f.drained]
        bad = [h for h in parents if h not in backward_reach(drained)]
        if bad:
            w = witness(bad)
            out.append(Violation(
                code="GL806",
                message=(f"liveness: {len(bad)} reachable state(s) from "
                         f"which no drained state (every request "
                         f"finished|shed) is reachable"),
                trace=_path_to(parents, w), state_hash=w,
                config=cfg.name))
    return out


# -- replay / minimization ---------------------------------------------------

def replay(cfg: MCConfig, trace: Tuple[str, ...]) -> ReplayResult:
    """Re-execute an action trace from the initial state; stops at the
    first violating transition. Deterministic: (config, trace) is the
    null engine's entire input."""
    eng = build_engine(cfg)
    for i, a in enumerate(trace):
        if a not in enabled_actions(eng):
            return ReplayResult(None, canonical_state(eng), i, False)
        pre = pre_snapshot(eng)
        exc: Optional[BaseException] = None
        try:
            apply_action(eng, a)
        except Exception as e:            # noqa: BLE001
            exc = e
        viols = check_transition(eng, pre, a, exc)
        if viols:
            vh = canonical_state(eng) if exc is None else "exception"
            vs = tuple(Violation(code, msg, tuple(trace[:i + 1]), vh,
                                 cfg.name) for code, msg in viols)
            return ReplayResult(vs[0], vh, i + 1, True, vs)
    return ReplayResult(None, canonical_state(eng), len(trace), True)


def _reproduces(cfg: MCConfig, trace: Tuple[str, ...], code: str) -> bool:
    return any(v.code == code for v in replay(cfg, trace).violations)


def minimize(cfg: MCConfig, violation: Violation) -> Violation:
    """Greedy-deletion shrink: drop any action whose removal still
    reproduces the violation code, to a fixed point. Graph-check codes
    (GL804/806) keep their BFS trace -- it is already a shortest path,
    and the property is not a single-transition predicate."""
    if violation.code not in TRANSITION_CODES:
        return violation
    trace = list(violation.trace)
    changed = True
    while changed:
        changed = False
        i = 0
        while i < len(trace):
            cand = tuple(trace[:i] + trace[i + 1:])
            if _reproduces(cfg, cand, violation.code):
                del trace[i]
                changed = True
            else:
                i += 1
    r = replay(cfg, tuple(trace))
    match = next(v for v in r.violations if v.code == violation.code)
    return dataclasses.replace(violation, trace=tuple(trace),
                               message=match.message,
                               state_hash=match.state_hash)


# -- counterexample export ---------------------------------------------------

SPEC_PREFIX = "mc:v1;"


def format_spec(config: str, trace: Tuple[str, ...]) -> str:
    """Compact replayable spec: ``mc:v1;config=<name>;trace=a>b>c``."""
    return f"{SPEC_PREFIX}config={config};trace=" + ">".join(trace)


def parse_spec(spec: str) -> Tuple[MCConfig, Tuple[str, ...]]:
    if not spec.startswith(SPEC_PREFIX):
        raise ValueError(f"not an mc spec (want {SPEC_PREFIX!r}...): "
                         f"{spec!r}")
    fields = dict(kv.split("=", 1)
                  for kv in spec[len(SPEC_PREFIX):].split(";") if kv)
    name = fields.get("config", "")
    if name not in ALL_CONFIGS:
        raise ValueError(f"unknown mc config {name!r}; "
                         f"have {sorted(ALL_CONFIGS)}")
    trace = tuple(a for a in fields.get("trace", "").split(">") if a)
    return ALL_CONFIGS[name], trace


def export_pytest(v: Violation) -> str:
    """A self-contained pytest regression pinning (code, trace, state
    hash). While the bug is open the test documents the reproduction;
    the fix flips the assertion to ``res.violation is None`` and keeps
    the trace locked forever (baseline policy: counterexamples become
    regressions, never baseline entries)."""
    fn = v.code.lower() + "_" + v.config.replace("-", "_")
    return f'''"""Auto-generated model-checker counterexample regression.

{v.code} on config {v.config!r}: {v.message}
Replay spec: {format_spec(v.config, v.trace)}
"""

from repro.analysis.mc import explore, harness

TRACE = {v.trace!r}


def test_mc_counterexample_{fn}():
    cfg = harness.ALL_CONFIGS[{v.config!r}]
    res = explore.replay(cfg, TRACE)
    assert res.valid, "trace no longer replays (alphabet drift)"
    assert any(x.code == {v.code!r} for x in res.violations)
    assert res.state_hash == {v.state_hash!r}, "replay is deterministic"
'''


def export_fault_script(v: Violation) -> str:
    """A ``GEMMINI_FAULTS``-style reproduction script: the armed fault
    plan (if the trace fires one) plus the replay invocation."""
    kinds = [a[len("fault:"):] for a in v.trace if a.startswith("fault:")]
    plan = ";".join(f"{k.partition('@')[0]}"
                    f"@{k.partition('@')[2] or '*'}:p=1,max=1"
                    for k in kinds)
    lines = ["#!/bin/sh",
             f"# model-checker counterexample: {v.code} on {v.config}",
             f"# {v.message}",
             f"# action trace: {' > '.join(v.trace)}"]
    if plan:
        lines.append(f'export GEMMINI_FAULTS="seed=0;{plan}"')
    lines.append('exec python -m repro.analysis.mc --replay '
                 f'"{format_spec(v.config, v.trace)}"')
    return "\n".join(lines) + "\n"
