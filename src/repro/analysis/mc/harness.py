"""The model checker's executable harness: a tensor-free serving engine.

:class:`NullEngine` subclasses the real
:class:`~repro.serving.engine.EngineControlPlane` -- the same admission,
chunking, preemption, recovery-ladder, and offload control flow the
production :class:`~repro.serving.engine.ServingEngine` runs -- and
implements the compute hooks with fabricated deterministic token commits
(``token = f(rid, n_generated)``), no device tensors anywhere. A state is
therefore plain Python: deepcopy snapshots it, ``canon`` hashes it, and
one action steps in microseconds.

:class:`MCConfig` bounds one exploration: geometry (slots/pages), the
workload (prompts + generation lengths), feature flags (offload, prefix
cache, deadlines), and the fault alphabet. Shipped configurations live in
:data:`CONFIGS`; :data:`SELFTEST_CONFIGS` carry deliberately planted bugs
(``sabotage=``) the checker must catch -- the mc analogue of the property
suite's ``test_check_catches_refcount_drift`` oracle self-test.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.runtime import faults as rfaults
from repro.serving.engine import EngineControlPlane
from repro.serving.paged_cache import PagedKVAllocator
from repro.serving.scheduler import ContinuousScheduler


class LogicalClock:
    """A clock that advances only by explicit ``tick`` actions, so time is
    part of the explored state, not an ambient side effect. All requests
    submitted between ticks share a timestamp -- which is exactly the
    tie-break scenario the scheduler's rid ordering must make
    deterministic."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float = 1.0) -> float:
        self.t += dt
        return self.t


@dataclasses.dataclass(frozen=True)
class NullModelCfg:
    """The minimal model-config surface the control plane consults."""

    name: str = "null"
    n_meta_tokens: int = 0
    n_codebooks: int = 1
    has_ssm: bool = False
    has_attn: bool = True
    vocab: int = 97


@dataclasses.dataclass(frozen=True)
class MCConfig:
    """One bounded exploration: geometry, workload, features, faults."""

    name: str
    slots: int = 3
    pages: int = 12
    page_size: int = 4
    max_context: int = 16
    # workload: prompt token tuples + per-request generation lengths
    prompts: Tuple[Tuple[int, ...], ...] = ((1, 2, 3, 4, 5, 6),
                                            (1, 2, 3, 4),
                                            (7, 8, 9))
    max_new: Tuple[int, ...] = (2, 2, 2)
    prefill_chunk: int = 4
    # budget 1: prefill_schedule's "first item always lands" rule means
    # exactly one chunk per call -- chunk-commit granularity for the MC
    prefill_token_budget: int = 1
    admission_policy: str = "fifo"
    kv_offload: bool = False
    host_pool_pages: int = 0
    prefix_cache: bool = False
    enforce_deadlines: bool = False
    deadlines: Tuple[Optional[float], ...] = ()   # relative, per request
    max_ticks: int = 0
    allow_preempt: bool = True
    allow_defrag: bool = True
    fault_kinds: Tuple[str, ...] = ()
    max_faults: int = 0
    sabotage: Optional[str] = None                # selftest bug plants


NULL_MODEL = NullModelCfg()


class NullEngine(EngineControlPlane):
    """The control plane over a fabricated-compute executor.

    Construction mirrors ``ServingEngine.__init__`` component wiring
    (allocator geometry, scheduler hooks, offload/prefix flags) minus
    everything device: no params, no jitted steps, no pools. Sampled
    tokens are ``(rid * 7919 + n_generated * 131 + 13) % vocab`` -- a
    deterministic function of visible state, so decision-relevant token
    *counts* evolve exactly as on the real engine while values stay
    reproducible across replays.
    """

    def __init__(self, mc_cfg: MCConfig, model_cfg: NullModelCfg = NULL_MODEL,
                 *, trace=False):
        super().__init__(
            model_cfg, max_slots=mc_cfg.slots, policy="continuous",
            # the empty plan (NOT None: None would consult $GEMMINI_FAULTS
            # and make exploration depend on the environment)
            faults=rfaults.FaultPlan(),
            nan_guard=bool(mc_cfg.fault_kinds),
            max_step_retries=2, retry_backoff_s=0.0,
            assert_invariants=False,       # the checker IS the oracle
            trace=trace, clock=LogicalClock())
        self.mc_cfg = mc_cfg
        self.max_context = mc_cfg.max_context
        self.page_size = mc_cfg.page_size
        self.max_pages_per_seq = -(-mc_cfg.max_context // mc_cfg.page_size)
        self.kv_offload = bool(mc_cfg.kv_offload)
        self.prefix_cache = bool(mc_cfg.prefix_cache)
        self.alloc = PagedKVAllocator(
            mc_cfg.pages, mc_cfg.page_size, self.max_pages_per_seq,
            tracer=self.tracer,
            host_pool_pages=(mc_cfg.host_pool_pages
                             if self.kv_offload else 0))
        self.prefill_pad = mc_cfg.page_size    # attention-only null model
        self.sched = ContinuousScheduler(
            self.alloc, mc_cfg.slots,
            prefill_token_budget=mc_cfg.prefill_token_budget,
            extra_tokens_per_prefill=model_cfg.n_meta_tokens,
            pad_to=self.prefill_pad,
            prefill_chunk=mc_cfg.prefill_chunk,
            admission_policy=mc_cfg.admission_policy,
            enforce_deadlines=mc_cfg.enforce_deadlines,
            clock=self.clock, tracer=self.tracer, metrics=self.metrics,
            offload=self.kv_offload, prefix_cache=self.prefix_cache,
            spill_fn=self._spill, restore_fn=self._restore)
        self.prefill_chunk = self.sched.prefill_chunk
        self._next_token = np.zeros((mc_cfg.slots,), np.int32)
        # one-shot armed faults retire here (kind strings, in firing
        # order): keeps the spent injector out of the state
        self.mc_fired: list = []

    # -- fabricated compute ------------------------------------------------
    def _fab_token(self, req) -> np.ndarray:
        return np.int32((req.rid * 7919 + req.n_generated * 131 + 13)
                        % self.model_cfg.vocab)

    def _null_logits(self):
        return np.zeros((1, 2), np.float32)

    def _dispatch(self, which: str, args: tuple):
        return self._null_logits(), None

    def _dispatch_fallback(self, which: str, args: tuple):
        return self._null_logits(), None

    def _exec_chunk(self, w):
        # Same site/which naming as the real engine, so armed faults hit
        # the identical recovery-ladder control flow (_run_guarded).
        site = "prefill" if w.first else "chunk"
        self._run_guarded(site, site, ())
        if not w.last:
            return None
        return self._fab_token(w.req)

    def _exec_decode(self, active_np: np.ndarray) -> np.ndarray:
        self._run_guarded("decode", "decode", ())
        last = np.zeros((self.max_slots,), np.int32)
        for slot, req in self.sched.running.items():
            if not req.prefilling:
                last[slot] = self._fab_token(req)
        return last

    def _capture_spill(self, req, page_ids):
        return {"rid": req.rid, "n_pages": len(page_ids)}

    def _apply_restore(self, req, slot, spill) -> None:
        pass                                   # nothing device to copy

    # -- sabotage (oracle self-tests) --------------------------------------
    def defrag(self) -> None:
        super().defrag()
        if self.mc_cfg.sabotage == "defrag_leak" and self.alloc._ref:
            # plant: refcount drift after compaction -> GL801 must fire
            p = next(iter(self.alloc._ref))
            self.alloc._ref[p] += 1

    def control_prefill(self, admit_new: bool = True) -> int:
        n = super().control_prefill(admit_new=admit_new)
        if self.mc_cfg.sabotage == "wedge":
            # plant half 1: silently LOSE preempted requests from the
            # queue (no terminal state) -- the lost-request bug class
            self.sched.queue = [r for r in self.sched.queue
                                if r.n_preempted == 0]
        return n

    def control_decode(self) -> None:
        super().control_decode()
        sab = self.mc_cfg.sabotage
        if sab == "rewind":
            # plant: drop below the pre-action commit point (popping just
            # the token this action pushed would still be prefix-monotone)
            # -> GL802 (no-rewind) must fire
            for req in self.sched.running.values():
                if len(req.generated) >= 2:
                    req.generated.pop()
                    req.generated.pop()
                    break
        elif sab == "wedge":
            # plant half 2: leak every free page into the held set; with
            # defrag disabled (it would release holds) the arena wedges.
            # Together the halves make states from which neither
            # can_admit nor a drained workload is ever reachable
            # -> GL804 and GL806 must fire
            self.alloc.hold_pages(self.alloc.free_pages)


def build_engine(cfg: MCConfig) -> NullEngine:
    return NullEngine(cfg)


# -- shipped configurations -------------------------------------------------
# The acceptance configuration: 3 slots / 12 pages / 3 requests, chunked
# prefill, shared-prefix prompts, preemption + defrag in the alphabet.
CONFIGS: Dict[str, MCConfig] = {
    "core-3s12p": MCConfig(
        name="core-3s12p", slots=3, pages=12, page_size=4, max_context=16,
        prompts=((1, 2, 3, 4, 5, 6), (1, 2, 3, 4), (7, 8, 9)),
        max_new=(2, 2, 2), prefill_chunk=4),
    # host offload: spill-on-preempt, restore-vs-recompute, LRU eviction
    "offload-2s8p": MCConfig(
        name="offload-2s8p", slots=2, pages=8, page_size=4, max_context=16,
        prompts=((1, 2, 3, 4, 5), (6, 7, 8)), max_new=(2, 2),
        prefill_chunk=4, kv_offload=True, host_pool_pages=4),
    # copy-on-write prefix cache: publish/match/reclaim under a shared
    # prompt prefix (first page of r0 and r1 is content-identical)
    "prefix-2s8p": MCConfig(
        name="prefix-2s8p", slots=2, pages=8, page_size=4, max_context=16,
        prompts=((1, 2, 3, 4, 9, 9), (1, 2, 3, 4, 5)), max_new=(2, 2),
        prefill_chunk=4, prefix_cache=True, allow_preempt=False),
    # EDF + SLO shedding under an explicitly ticked logical clock; equal
    # deadlines exercise the rid tie-break
    "deadline-2s8p": MCConfig(
        name="deadline-2s8p", slots=2, pages=8, page_size=4, max_context=16,
        prompts=((1, 2, 3), (4, 5, 6)), max_new=(2, 2), prefill_chunk=4,
        admission_policy="deadline", enforce_deadlines=True,
        deadlines=(3.0, 3.0), max_ticks=4, allow_defrag=False),
    # the recovery ladder: one-shot transient + NaN faults interleaved at
    # every point of the schedule
    "faults-2s8p": MCConfig(
        name="faults-2s8p", slots=2, pages=8, page_size=4, max_context=16,
        prompts=((1, 2, 3, 4, 5), (6, 7, 8)), max_new=(2, 2),
        prefill_chunk=4, fault_kinds=("transient", "nan"), max_faults=2,
        allow_defrag=False),
}

# Planted-bug configurations: the checker must FIND these (tests assert
# it does); they never run in CI's gate.
SELFTEST_CONFIGS: Dict[str, MCConfig] = {
    "sabotage-defrag-leak": MCConfig(
        name="sabotage-defrag-leak", slots=2, pages=8, page_size=4,
        max_context=16, prompts=((1, 2, 3),), max_new=(2,),
        prefill_chunk=4, sabotage="defrag_leak"),
    "sabotage-rewind": MCConfig(
        name="sabotage-rewind", slots=2, pages=8, page_size=4,
        max_context=16, prompts=((1, 2, 3),), max_new=(3,),
        prefill_chunk=4, allow_defrag=False, sabotage="rewind"),
    "sabotage-wedge": MCConfig(
        name="sabotage-wedge", slots=2, pages=4, page_size=4,
        max_context=16, prompts=((1, 2, 3), (4, 5, 6)), max_new=(2, 2),
        prefill_chunk=4, allow_defrag=False, sabotage="wedge"),
}

ALL_CONFIGS: Dict[str, MCConfig] = {**CONFIGS, **SELFTEST_CONFIGS}
