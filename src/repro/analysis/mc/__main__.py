"""``python -m repro.analysis.mc`` -- the control-plane model-checking
CI gate.

Explores every shipped bounded configuration (or ``--config`` a subset),
reports violations as GL8xx findings through the lint findings/baseline
machinery, and exits non-zero on any non-baselined finding.

  python -m repro.analysis.mc                          # all configs, text
  python -m repro.analysis.mc --config core-3s12p      # one config
  python -m repro.analysis.mc --format json --out MC.json
  python -m repro.analysis.mc --max-states 50000       # CI budget cap
  python -m repro.analysis.mc --replay "mc:v1;config=...;trace=a>b"
  python -m repro.analysis.mc --export-dir /tmp/ce     # write artifacts

Baseline policy (tools/mc_baseline.json): the file ships EMPTY and is
meant to stay empty -- a counterexample is a bug to fix in-tree plus a
minimized-trace pytest regression, never a suppression (docs/analysis.md).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.lint.findings import (apply_baseline, finding,
                                          load_baseline, to_report,
                                          write_baseline)
from repro.analysis.mc import explore as ex
from repro.analysis.mc.harness import ALL_CONFIGS, CONFIGS


def _default_baseline() -> Path:
    # repo checkout layout: <root>/src/repro/analysis/mc/__main__.py
    root = Path(__file__).resolve().parents[4]
    return root / "tools" / "mc_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.mc",
        description="control-plane bounded model checker "
                    "(docs/analysis.md#model-checker)")
    ap.add_argument("--config", action="append", default=None,
                    metavar="NAME",
                    help="configuration(s) to explore (repeatable; "
                         "default: all shipped configs)")
    ap.add_argument("--list", action="store_true",
                    help="list configurations and exit")
    ap.add_argument("--max-states", type=int, default=200_000,
                    help="state budget per configuration (cap hit => "
                         "run marked incomplete, graph checks skipped)")
    ap.add_argument("--max-depth", type=int, default=None,
                    help="interleaving depth bound (default: none)")
    ap.add_argument("--no-liveness", action="store_true",
                    help="skip the GL806 liveness graph check")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", type=Path, default=None,
                    help="also write the JSON report to this path")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="suppression file (default: "
                         "tools/mc_baseline.json; policy: keep it empty)")
    ap.add_argument("--no-baseline", action="store_true")
    ap.add_argument("--write-baseline", action="store_true")
    ap.add_argument("--replay", type=str, default=None, metavar="SPEC",
                    help="replay one counterexample spec "
                         "(mc:v1;config=...;trace=a>b>c) and exit")
    ap.add_argument("--export-dir", type=Path, default=None,
                    help="write each violation's pytest regression + "
                         "fault-script artifacts here")
    args = ap.parse_args(argv)

    if args.list:
        for name, cfg in ALL_CONFIGS.items():
            tag = " [selftest]" if cfg.sabotage else ""
            print(f"{name}: {cfg.slots} slots, {cfg.pages} pages, "
                  f"{len(cfg.prompts)} requests{tag}")
        return 0

    if args.replay:
        cfg, trace = ex.parse_spec(args.replay)
        r = ex.replay(cfg, trace)
        if not r.valid:
            print(f"trace invalid: action {r.executed} not enabled")
            return 2
        if r.violation is None:
            print(f"clean replay: {r.executed} action(s), final state "
                  f"{r.state_hash}")
            return 0
        v = r.violation
        print(f"{v.code} reproduced after {r.executed} action(s): "
              f"{v.message}\nviolating state hash: {v.state_hash}")
        return 1

    names = args.config or list(CONFIGS)
    unknown = [n for n in names if n not in ALL_CONFIGS]
    if unknown:
        ap.error(f"unknown config(s) {unknown}; have {sorted(ALL_CONFIGS)}")

    results, findings = [], []
    for name in names:
        cfg = ALL_CONFIGS[name]
        res = ex.explore(cfg, max_states=args.max_states,
                         max_depth=args.max_depth,
                         check_liveness=not args.no_liveness)
        res.violations = [ex.minimize(cfg, v) for v in res.violations]
        results.append(res)
        for v in res.violations:
            findings.append(finding(
                v.code, "error", site=f"mc:{v.config}", message=v.message,
                key="|".join(v.trace), trace=list(v.trace),
                state_hash=v.state_hash,
                spec=ex.format_spec(v.config, v.trace)))
        if args.export_dir and res.violations:
            args.export_dir.mkdir(parents=True, exist_ok=True)
            for i, v in enumerate(res.violations):
                stem = f"{v.code.lower()}_{v.config}_{i}"
                (args.export_dir / f"test_{stem}.py").write_text(
                    ex.export_pytest(v))
                (args.export_dir / f"{stem}.sh").write_text(
                    ex.export_fault_script(v))

    baseline_path = args.baseline or _default_baseline()
    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"baselined {len(findings)} finding(s) -> {baseline_path}")
        return 0
    baseline = {} if args.no_baseline else load_baseline(baseline_path)
    new, suppressed = apply_baseline(findings, baseline)

    report = to_report(new, suppressed=suppressed)
    report["runs"] = [r.to_dict() for r in results]
    if args.out:
        args.out.write_text(json.dumps(report, indent=2, default=str)
                            + "\n")
    if args.format == "json":
        print(json.dumps(report, indent=2, default=str))
    else:
        for r in results:
            status = "exhaustive" if r.complete else "CAPPED"
            print(f"{r.config}: {r.states} states, {r.transitions} "
                  f"transitions, {r.memo_hits} memo hits, "
                  f"{r.terminal_states} drained, {status}, "
                  f"{len(r.violations)} violation(s), {r.wall_s:.1f}s")
        for f in new:
            print(f"ERROR   {f.code} {f.site}: {f.message}")
            print(f"        replay: {dict(f.data).get('spec')}")
        c = report["counts"]
        print(f"{c['total']} finding(s) ({c['suppressed']} baselined)")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
