"""Loop-aware HLO cost analysis (flops / bytes / collective bytes).

Why this exists: XLA's ``compiled.cost_analysis()`` counts each while-loop
*body once*, not multiplied by its trip count (verified empirically on this
JAX version: a 10-step ``lax.scan`` of a matmul reports the flops of ONE
matmul). Every model in this repo scans over layers, so the built-in numbers
under-count by ~``n_layers`` and the roofline terms derived from them would
be meaningless. This module re-derives the three roofline inputs from the
*partitioned, compiled* HLO text with per-computation execution multipliers:

  multiplier(entry) = 1
  multiplier(while body/cond) = multiplier(parent) * trip_count
  multiplier(fusion body / called comp) = multiplier(parent)

Trip counts come from the ``backend_config={"known_trip_count":{"n":...}}``
annotation XLA attaches to counted loops, with a fallback parse of the loop
condition (``compare(induction, constant(N)), direction=LT``).

Costs are computed per-op from the HLO text:
  * flops: dot ops from output shape x contracted dims (a MAC = 2 flops);
    convolutions from output x kernel; elementwise/reduce ops approximated
    at 1 flop per output (binary/unary) or per input (reduce) element --
    matching XLA's own convention.
  * bytes: operand + output bytes per op at *fusion granularity* (ops inside
    a fused computation touch VMEM/registers, not HBM; the fusion op's
    operands/results are the HBM traffic). Parameters/constants/tuple
    plumbing are excluded.
  * collective bytes: output-shape bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (all-reduce counted x2:
    its ring lowering is reduce-scatter + all-gather).

This is a *static* analysis of the SPMD-partitioned module: shapes are
per-device, so totals are per-device -- exactly what the roofline formulas
divide by per-chip peaks.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Iterable, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e8m0fnu": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# elementwise-ish opcodes costed at 1 flop / output element
_ELEMENTWISE = frozenset("""
add subtract multiply divide maximum minimum power remainder atan2
and or xor not negate abs sign compare select clamp
exp exponential expm1 exponential-minus-one log log1p log-plus-one tanh sqrt
rsqrt cbrt sin sine cos cosine tan logistic erf floor ceil round
round-nearest-afz round-nearest-even is-finite shift-left
shift-right-arithmetic shift-right-logical rem
""".split())

# pure data movement / plumbing: no flops, no byte accounting at this level.
# while/conditional/call carry tuples are aliased in place by XLA buffer
# assignment (no physical copy; real copies appear as explicit `copy` ops).
_NO_BYTES = frozenset("""
parameter constant tuple get-tuple-element bitcast after-all
opt-barrier partition-id replica-id while conditional call
""".split())

_REDUCES = frozenset(("reduce", "reduce-window"))


def _shape_numel_bytes(shape_str: str) -> Tuple[int, int]:
    """(elements, bytes) of a shape string; tuples are summed."""
    n_tot = b_tot = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        n_tot += n
        b_tot += n * _DTYPE_BYTES[dt]
    return n_tot, b_tot


@dataclasses.dataclass
class Op:
    name: str
    shape: str            # result shape string
    opcode: str
    operands: List[str]   # operand op names (local to the computation)
    attrs: str            # everything after the operand parens


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]         # op name -> result shape string


_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(\([^)]*\)|[a-z0-9_]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s+([\w\-]+)\(")


def _operand_segment(line: str, open_idx: int) -> Tuple[str, str]:
    """Split at the matching close paren: (operand_str, attrs_str)."""
    depth = 0
    for i in range(open_idx, len(line)):
        c = line[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return line[open_idx + 1:i], line[i + 1:]
    return line[open_idx + 1:], ""


_NAME_RE = re.compile(r"%([\w.\-]+)")


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    """Parse an HLO module dump into computations. Returns (comps, entry)."""
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                if line.startswith("ENTRY"):
                    entry = cur.name
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, shape, opcode = m.group(1), m.group(2), m.group(3)
        open_idx = line.index("(", m.end(3) - 1)
        oper_str, attrs = _operand_segment(line, open_idx)
        if opcode == "constant":
            # keep the literal so trip-count fallback can read it
            operands, attrs = [], f"value({oper_str}) {attrs}"
        elif opcode == "parameter":
            # keep the parameter index for fusion byte attribution
            operands, attrs = [], f"index({oper_str.strip()}) {attrs}"
        else:
            operands = _NAME_RE.findall(oper_str)
        op = Op(name, shape, opcode, operands, attrs)
        cur.ops.append(op)
        cur.shapes[name] = shape
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


# ---------------------------------------------------------------------------
# trip counts
# ---------------------------------------------------------------------------
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(op.attrs)
    if m:
        return int(m.group(1))
    # fallback: the condition computation compares the induction variable
    # against a constant upper bound with direction=LT (jax scan lowering).
    mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
    if mc and mc.group(1) in comps:
        cond = comps[mc.group(1)]
        const_val = None
        for o in cond.ops:
            if o.opcode == "constant":
                mm = re.search(r"value\((\d+)\)", o.attrs)
                if mm:
                    const_val = int(mm.group(1))
        if const_val is not None:
            return const_val
    return 1


# ---------------------------------------------------------------------------
# per-op costs
# ---------------------------------------------------------------------------
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_KOUT_RE = re.compile(r"dim_labels=[^,]*_([0-9a-z]*)->")


def _dot_flops(op: Op, comp: Computation) -> float:
    out_n, _ = _shape_numel_bytes(op.shape)
    lhs_shape = comp.shapes.get(op.operands[0]) if op.operands else None
    contract = 1
    m = _DIMS_RE.search(op.attrs)
    if m and lhs_shape:
        dims = [int(d) for d in m.group(1).split(",") if d != ""]
        sm = _SHAPE_RE.search(lhs_shape)
        if sm and sm.group(2):
            ldims = [int(d) for d in sm.group(2).split(",")]
            for d in dims:
                if d < len(ldims):
                    contract *= ldims[d]
    return 2.0 * out_n * contract


def _conv_flops(op: Op, comp: Computation) -> float:
    out_n, _ = _shape_numel_bytes(op.shape)
    if len(op.operands) < 2:
        return 2.0 * out_n
    k_shape = comp.shapes.get(op.operands[1], "")
    sm = _SHAPE_RE.search(k_shape)
    if not sm or not sm.group(2):
        return 2.0 * out_n
    kdims = [int(d) for d in sm.group(2).split(",")]
    knumel = 1
    for d in kdims:
        knumel *= d
    # kernel = spatial x ci x co; flops per output elem = 2 * spatial * ci
    m = _KOUT_RE.search(op.attrs)
    co = 1
    if m:
        labels = m.group(1)
        o_pos = labels.find("o")
        if 0 <= o_pos < len(kdims):
            co = kdims[o_pos]
    return 2.0 * out_n * (knumel // max(co, 1))


def _op_flops(op: Op, comp: Computation) -> float:
    oc = op.opcode
    if oc == "dot":
        return _dot_flops(op, comp)
    if oc == "convolution":
        return _conv_flops(op, comp)
    if oc in _ELEMENTWISE:
        n, _ = _shape_numel_bytes(op.shape)
        return float(n)
    if oc in _REDUCES:
        tot = 0
        for o in op.operands:
            s = comp.shapes.get(o)
            if s:
                n, _ = _shape_numel_bytes(s)
                tot += n
        return float(tot / 2)  # half the operands are init values
    return 0.0


def _op_bytes(op: Op, comp: Computation,
              comps: Optional[Dict[str, "Computation"]] = None) -> float:
    if op.opcode in _NO_BYTES:
        return 0.0
    if op.opcode == "dynamic-update-slice":
        # XLA aliases DUS in place: only the update slice is read+written;
        # the buffer operand is untouched storage, not traffic.
        upd = comp.shapes.get(op.operands[1]) if len(op.operands) > 1 \
            else None
        if upd:
            _, b = _shape_numel_bytes(upd)
            return 2.0 * b
    _, out_b = _shape_numel_bytes(op.shape)
    total = float(out_b)
    sliced: Dict[int, float] = {}
    if op.opcode == "fusion":
        sliced, out_override = _fusion_byte_attribution(op, comps)
        if out_override is not None:
            total = out_override
    for i, o in enumerate(op.operands):
        if i in sliced:
            total += sliced[i]
            continue
        s = comp.shapes.get(o)
        if s:
            _, b = _shape_numel_bytes(s)
            total += b
    return total


_PARAM_IDX_RE = re.compile(r"index\((\d+)\)")


def _fusion_byte_attribution(op: Op,
                             comps: Optional[Dict[str, "Computation"]]
                             ) -> Tuple[Dict[int, float], Optional[float]]:
    """Refined byte accounting for a fusion call site.

    Returns (per-operand-byte overrides, output-byte override or None):

    * operands only dynamic-sliced/gathered inside the body are charged the
      slice bytes, not the whole array (a scan body that dynamic-slices the
      stacked per-layer weights must not be charged n_layers x the stack);
    * operands consumed only as the BUFFER of a dynamic-update-slice are
      charged 0 (XLA aliases DUS in place -- storage, not traffic);
    * if the body root is a DUS (or a tuple of them), the output is charged
      at the update sizes, not the full buffers.
    """
    if comps is None:
        return {}, None
    m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
    if not m or m.group(1) not in comps:
        return {}, None
    body = comps[m.group(1)]
    pname_by_idx: Dict[int, str] = {}
    for o in body.ops:
        if o.opcode == "parameter":
            mi = _PARAM_IDX_RE.search(o.attrs)
            if mi:
                pname_by_idx[int(mi.group(1))] = o.name

    out: Dict[int, float] = {}
    for idx, pname in pname_by_idx.items():
        consumers = [o for o in body.ops if pname in o.operands]
        if not consumers:
            continue
        if all(o.opcode in ("dynamic-slice", "gather", "slice")
               and o.operands and o.operands[0] == pname
               for o in consumers):
            read = 0.0
            for o in consumers:
                _, b = _shape_numel_bytes(o.shape)
                read += b
            out[idx] = read
        elif all(o.opcode == "dynamic-update-slice"
                 and o.operands and o.operands[0] == pname
                 for o in consumers):
            out[idx] = 0.0           # in-place DUS buffer: aliased

    # output override: root DUS writes only the update slice(s)
    root = body.ops[-1] if body.ops else None
    out_override: Optional[float] = None
    if root is not None:
        roots = [root]
        if root.opcode == "tuple":
            roots = [o for o in body.ops if o.name in root.operands]
        if roots and all(o.opcode == "dynamic-update-slice" for o in roots):
            w = 0.0
            for o in roots:
                upd = body.shapes.get(o.operands[1]) \
                    if len(o.operands) > 1 else None
                if upd is None:
                    _, b = _shape_numel_bytes(o.shape)
                else:
                    _, b = _shape_numel_bytes(upd)
                w += b
            out_override = w
    return out, out_override


_CALLEE_RES = (
    ("while", re.compile(r"body=%?([\w.\-]+)")),
    ("while_cond", re.compile(r"condition=%?([\w.\-]+)")),
    ("fusion", re.compile(r"calls=%?([\w.\-]+)")),
    ("call", re.compile(r"to_apply=%?([\w.\-]+)")),
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")


@dataclasses.dataclass
class CostReport:
    flops: float
    bytes: float
    coll_bytes: float                      # all-reduce counted x2
    coll_breakdown: Dict[str, float]
    coll_counts: Dict[str, int]
    n_while: int
    max_trip: int

    def merged(self) -> Dict[str, float]:
        d = dict(flops=self.flops, bytes=self.bytes,
                 coll_bytes=self.coll_bytes)
        d.update({f"coll_{k}": v for k, v in self.coll_breakdown.items()})
        return d


def analyze_hlo(text: str) -> CostReport:
    comps, entry = parse_module(text)
    if not entry:
        raise ValueError("no ENTRY computation found in HLO text")

    # --- propagate execution multipliers through the call graph ----------
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    fusion_body: Dict[str, bool] = {c: False for c in comps}
    mult[entry] = 1.0
    n_while = 0
    max_trip = 1

    # worklist DFS; cycles are impossible in HLO call graphs
    stack = [entry]
    seen_edges = set()
    order: List[str] = []
    while stack:
        cname = stack.pop()
        order.append(cname)
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            callees: List[Tuple[str, float, bool]] = []
            if op.opcode == "while":
                trip = _trip_count(op, comps)
                n_while += 1
                max_trip = max(max_trip, trip)
                mb = re.search(r"body=%?([\w.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                if mb:
                    callees.append((mb.group(1), float(trip), False))
                if mc:
                    callees.append((mc.group(1), float(trip), False))
            elif op.opcode == "fusion":
                mf = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if mf:
                    callees.append((mf.group(1), 1.0, True))
            elif op.opcode in ("call", "custom-call", "async-start"):
                mf = re.search(r"to_apply=%?([\w.\-]+)", op.attrs)
                if mf:
                    callees.append((mf.group(1), 1.0, False))
            elif op.opcode == "conditional":
                mbr = _BRANCHES_RE.search(op.attrs)
                names = []
                if mbr:
                    names = _NAME_RE.findall(mbr.group(1))
                names += _TF_RE.findall(op.attrs)
                for nm in names:
                    callees.append((nm, 1.0, False))
            # reduce/scatter/sort to_apply bodies: per-element lambdas,
            # costed at the call site -- not traversed.
            for callee, factor, is_fusion in callees:
                if callee not in comps:
                    continue
                mult[callee] = mult.get(callee, 0.0) + mult[cname] * factor
                if is_fusion:
                    fusion_body[callee] = True
                edge = (cname, callee)
                if edge not in seen_edges:
                    seen_edges.add(edge)
                    stack.append(callee)

    # --- accumulate costs -------------------------------------------------
    flops = 0.0
    hbm = 0.0
    coll: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = fusion_body.get(cname, False)
        for op in comp.ops:
            flops += m * _op_flops(op, comp)
            if not in_fusion:
                hbm += m * _op_bytes(op, comp, comps)
            base = op.opcode
            for suffix in ("-start", "-done"):
                if base.endswith(suffix):
                    base = base[: -len(suffix)]
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                _, b = _shape_numel_bytes(op.shape)
                coll[base] += m * b
                counts[base] += 1
    total_coll = sum(coll.values()) + coll.get("all-reduce", 0.0)
    return CostReport(flops=flops, bytes=hbm, coll_bytes=total_coll,
                      coll_breakdown={k: v for k, v in coll.items() if v},
                      coll_counts={k: v for k, v in counts.items() if v},
                      n_while=n_while, max_trip=max_trip)
