"""Roofline terms from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), per the assignment formulas:

  compute    = HLO_FLOPs / peak_FLOPs_per_chip
  memory     = HLO_bytes / HBM_bw_per_chip
  collective = collective_bytes / link_bw_per_chip

All three inputs come from :mod:`repro.analysis.hlo`, a loop-aware static
analysis of the SPMD-partitioned compiled module. We do NOT use
``compiled.cost_analysis()`` for the terms because XLA counts while-loop
bodies once instead of x trip_count (verified empirically; every model here
scans over layers, so the builtin numbers under-count by ~n_layers). The
builtin numbers are still recorded as ``xla_flops`` / ``xla_bytes`` for
cross-checking. Shapes in the partitioned module are per-device, so all
terms divide by single-chip peaks.

collective_bytes sums the output-shape bytes of all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute ops (x execution
multiplier). all-reduce is counted x2: its torus lowering is
reduce-scatter + all-gather, each moving the full buffer.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional, Tuple

# --- TPU v5e-class hardware constants (per chip) ---------------------------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
PEAK_OPS_INT8 = 394e12
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s per link (assignment constant)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. "f32[256,8192]{1,0} all-reduce(" and tuple-shaped variants
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind output bytes of every collective in the HLO module.

    ``-done`` ops are skipped (their ``-start`` counterpart was counted).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if f"{m.group(2)}-done(" in line:
            continue
        kind = m.group(2)
        out[kind] += _shape_bytes(m.group(1))
        counts[kind] += 1
    out_tot = dict(out)
    out_tot["_counts"] = counts  # type: ignore
    return out_tot


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float                  # per-device HLO flops
    hbm_bytes: float              # per-device HLO bytes accessed
    coll_bytes: float             # per-device collective bytes (AR x2)
    coll_breakdown: Dict[str, int]
    per_device_hbm_peak: float    # memory_analysis: args+outs+temps
    model_flops: float            # 6ND / 2ND analytic useful flops (global)
    n_chips: int
    xla_flops: float = 0.0        # builtin cost_analysis (loop bodies x1)
    xla_bytes: float = 0.0        # kept for cross-checking only
    min_bytes: float = 0.0        # inherent minimal HBM traffic (global)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW_PER_LINK

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops)."""
        total = self.flops * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def t_ideal(self) -> float:
        """The roofline lower bound for this workload on this machine:
        the larger of (useful compute at peak) and (inherent minimal HBM
        traffic at full bandwidth). Decode steps are intrinsically
        memory-bound -- every parameter and cache byte must be read once
        per token -- so their roof is the memory term, not compute."""
        t_c = self.model_flops / self.n_chips / PEAK_FLOPS_BF16
        t_m = self.min_bytes / self.n_chips / HBM_BW
        return max(t_c, t_m)

    @property
    def roofline_fraction(self) -> float:
        """t_ideal / t_bound: what fraction of the workload's own roofline
        the compiled step achieves."""
        return self.t_ideal / self.t_bound if self.t_bound else 0.0

    def row(self) -> Dict:
        return dict(
            arch=self.arch, shape=self.shape, mesh=self.mesh,
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            hlo_flops=self.flops, hlo_bytes=self.hbm_bytes,
            coll_bytes=self.coll_bytes,
            model_flops=self.model_flops, useful_ratio=self.useful_ratio,
            roofline_fraction=self.roofline_fraction,
            per_device_hbm=self.per_device_hbm_peak,
            xla_flops=self.xla_flops, xla_bytes=self.xla_bytes,
            min_bytes=self.min_bytes, t_ideal=self.t_ideal,
            coll_breakdown={k: v for k, v in self.coll_breakdown.items()
                            if k != "_counts" and v},
        )


def analyze(compiled, lowered_text: Optional[str], *, arch: str, shape: str,
            mesh_name: str, n_chips: int, model_flops: float) -> Roofline:
    from repro.analysis import hlo as hlo_lib
    text = lowered_text if lowered_text is not None else compiled.as_text()
    rep = hlo_lib.analyze_hlo(text)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):      # jax < 0.6 returns one dict per device
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    hbm_peak = float(ma.argument_size_in_bytes + ma.output_size_in_bytes +
                     ma.temp_size_in_bytes) if ma else 0.0
    breakdown = dict(rep.coll_breakdown)
    breakdown["_counts"] = rep.coll_counts  # type: ignore
    rl = Roofline(arch=arch, shape=shape, mesh=mesh_name, flops=rep.flops,
                  hbm_bytes=rep.bytes, coll_bytes=rep.coll_bytes,
                  coll_breakdown=breakdown, per_device_hbm_peak=hbm_peak,
                  model_flops=model_flops, n_chips=n_chips)
    rl.xla_flops = float(ca.get("flops", 0.0))
    rl.xla_bytes = float(ca.get("bytes accessed", 0.0))
    return rl


def model_flops_for(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """Analytic useful FLOPs: 6*N*D train, 2*N*D inference forward,
    2*N per decoded token (D = tokens processed, N = active params)."""
    n = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n * batch * seq
    if shape_kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch          # decode: one token per sequence


def model_min_bytes_for(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """Inherent minimal global HBM traffic per step (the memory roofline).

    decode:  every active parameter (bf16) and every cache byte must be
             read once per token -- the fundamental decode bound.
    prefill: parameters once + activations written once + KV written.
    train:   parameters + opt state (2x fp32) read/written once + the
             residual stream written in fwd and read in bwd.
    These are deliberate LOWER bounds (no rematerialization, perfect fusion
    of everything else), so roofline_fraction never flatters the system.
    """
    n_active = cfg.active_param_count()
    n_stored = cfg.param_count()
    act_bytes = 2.0 * batch * seq * cfg.d_model          # residual, bf16
    kv_bytes = 0.0
    if cfg.has_attn:
        kv_bytes += (2.0 * cfg.n_layers * batch * seq *
                     cfg.n_kv_heads * cfg.head_dim * 2)  # K+V bf16
    if cfg.has_ssm:
        kv_bytes += (cfg.n_layers * batch * cfg.n_ssm_heads *
                     cfg.d_state * cfg.ssm_head_dim * 4)  # fp32 state
    if shape_kind == "decode":
        return 2.0 * n_active + kv_bytes
    if shape_kind == "prefill":
        return 2.0 * n_active + act_bytes + kv_bytes
    # train: params bf16 + grads bf16 + m/v fp32 r+w, fwd act write + bwd read
    opt_bytes = n_stored * (2 + 2 + 4 * 4)
    return opt_bytes + 2.0 * act_bytes * cfg.n_layers
