"""Plan-feasibility predicates for the tuner (the `tune/` hook).

The tuner's lattice enumerators carry their own closed-form fit
heuristics (`schedules._attn_fits` etc.), but they deliberately
force-include the static default even when it does not fit, and their
models omit terms (output write-back blocks, the f32 softmax scratch).
These predicates re-derive feasibility from the *declared kernel
contract* — the exact per-grid-step footprint the VMEM check (GL301/
GL302) proves against — so the tuner can drop a candidate before
paying to measure it, and "lint-clean" and "tuner-feasible" are the
same fact.

All predicates are total and safe to call on any candidate: a contract
that cannot even be built (degenerate geometry) reports infeasible
rather than raising, because the tuner must keep enumerating.
"""

from __future__ import annotations

from repro.core.config import GemminiConfig
from repro.kernels import contracts as kc
from repro.analysis.lint.checks import fits_budgets


def gemm_plan_feasible(cfg: GemminiConfig, plan, *,
                       has_bias: bool = False) -> bool:
    try:
        return (fits_budgets(kc.gemm_os_contract(cfg, plan,
                                                 has_bias=has_bias), cfg)
                and fits_budgets(kc.gemm_ws_contract(cfg, plan,
                                                     has_bias=has_bias),
                                 cfg))
    except Exception:
        return False


def attn_schedule_feasible(cfg: GemminiConfig, sched, *, b: int, h: int,
                           kvh: int, tq: int, tk: int, d: int,
                           dtype="bf16") -> bool:
    try:
        eff = sched.effective(tq, tk)
        c = kc.flash_attention_contract(
            cfg, b=b, h=h, kvh=kvh, tq=tq, tk=tk, d=d,
            block_q=eff.block_q, block_k=eff.block_k, dtype=dtype)
        return fits_budgets(c, cfg)
    except Exception:
        return False


def paged_schedule_feasible(cfg: GemminiConfig, sched, *, b: int, h: int,
                            kvh: int, d: int, max_context: int,
                            dtype="bf16") -> bool:
    try:
        page = sched.effective(max_context).page_size
        mp = -(-max_context // page)
        c = kc.paged_decode_attention_contract(
            cfg, b=b, h=h, kvh=kvh, d=d, page=page, mp=mp,
            n_pages=max(b, 1) * mp, dtype=dtype)
        return fits_budgets(c, cfg)
    except Exception:
        return False


def conv_schedule_feasible(cfg: GemminiConfig, sched, *, n: int, h: int,
                           w: int, ci: int, co: int, kh: int, kw: int,
                           stride: int = 1, padding: int = 0,
                           has_bias: bool = False) -> bool:
    try:
        c = kc.conv2d_implicit_contract(
            cfg, n=n, h=h, w=w, ci=ci, co=co, kh=kh, kw=kw,
            co_tile=sched.effective(co).co_tile, stride=stride,
            padding=padding, has_bias=has_bias)
        return fits_budgets(c, cfg)
    except Exception:
        return False
