"""Contract checks: coverage, write-race freedom, VMEM budget, precision.

Each check takes an instantiated :class:`~repro.kernels.contracts.KernelContract`
(one concrete problem × one schedule) and the :class:`GemminiConfig`
whose budgets it must fit, and yields :class:`Finding`s.

Diagnostic codes (docs/analysis.md):

===== =========================================================
GL101 block index provably out of bounds for the operand
GL102 output operand not provably tiled by the grid (coverage gap)
GL103 index map not affine and not declared data-dependent
GL201 output invariant along a "parallel" grid axis (write race)
GL202 output revisited along an "arbitrary" axis, no declared reduction
GL203 declared reduction accumulates through an input/output alias
      across grid revisits (the seed WS bug class — always unsound)
GL204 declared reduction names a scratch the contract doesn't carry
GL301 streamed per-step blocks x pipeline_depth exceed scratchpad_bytes
GL302 resident blocks + scratch exceed accumulator_bytes
GL401 narrow-dtype dot without a wide accumulator
GL402 scalar-sized operand block not placed in SMEM
===== =========================================================
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Tuple

from repro.core.config import GemminiConfig
from repro.kernels.contracts import KernelContract, OperandSpec
from repro.analysis.lint.affine import Ix, NonAffine, eval_index_map
from repro.analysis.lint.findings import Finding, finding


def _site(c: KernelContract, inst: str = "") -> str:
    # `inst` (the schedule/problem instantiation) deliberately stays OUT
    # of the site: the same defect proven at every schedule in the
    # lattice must fingerprint identically (dedupe + stable baselines).
    # check_contract() records it in the finding data instead.
    del inst
    return f"contract:{c.name}"


def _nb(op: OperandSpec) -> Tuple[int, ...]:
    return tuple(-(-s // b) for s, b in zip(op.shape, op.block))


def _eval(c: KernelContract, op: OperandSpec):
    """-> per-dim Ix tuple, or None if declared data-dependent."""
    if op.data_dependent is not None:
        return None
    if op.index_map is None:
        raise NonAffine(f"{op.name}: no index map and not data-dependent")
    return eval_index_map(op.index_map, c.grid)


# -- coverage ---------------------------------------------------------------

def check_coverage(c: KernelContract, *, inst: str = "") -> List[Finding]:
    out: List[Finding] = []
    site = _site(c, inst)
    axis_names = [a for a, _ in c.grid]
    for op in c.inputs + c.outputs:
        if len(op.shape) != len(op.block):
            out.append(finding(
                "GL101", "error", site,
                f"operand {op.name!r}: block rank {len(op.block)} != "
                f"operand rank {len(op.shape)}", key=f"{op.name}:rank"))
            continue
        try:
            idx = _eval(c, op)
        except NonAffine as e:
            out.append(finding(
                "GL103", "warning", site,
                f"operand {op.name!r}: index map is not affine in the grid "
                f"and the contract does not declare it data-dependent "
                f"({e})", key=op.name))
            continue
        if idx is None:
            continue                      # declared gather: coverage waived
        nbs = _nb(op)
        if len(idx) != len(op.shape):
            out.append(finding(
                "GL101", "error", site,
                f"operand {op.name!r}: index map returns {len(idx)} dims "
                f"for rank-{len(op.shape)} operand", key=f"{op.name}:rank"))
            continue
        is_output = op in c.outputs
        covered_axes: List[str] = []
        for d, (e, nb) in enumerate(zip(idx, nbs)):
            lo, hi = e.range()
            if lo < 0 or hi > nb - 1:
                out.append(finding(
                    "GL101", "error", site,
                    f"operand {op.name!r} dim {d}: block index range "
                    f"[{lo}, {hi}] exceeds [0, {nb - 1}] "
                    f"({nb} blocks of {op.block[d]} over {op.shape[d]})",
                    key=f"{op.name}:{d}"))
            if is_output:
                if not e.covers(nb):
                    out.append(finding(
                        "GL102", "error", site,
                        f"output {op.name!r} dim {d}: grid does not "
                        f"provably write all {nb} blocks (index {e!r})",
                        key=f"{op.name}:{d}"))
                covered_axes.extend(e.support)
        if is_output and len(covered_axes) != len(set(covered_axes)):
            dup = sorted({a for a in covered_axes
                          if covered_axes.count(a) > 1})
            out.append(finding(
                "GL102", "error", site,
                f"output {op.name!r}: grid axes {dup} index more than one "
                f"dim — joint coverage of the block product unproven",
                key=f"{op.name}:joint"))
        _ = axis_names
    return out


# -- write races ------------------------------------------------------------

def check_races(c: KernelContract, *, inst: str = "") -> List[Finding]:
    out: List[Finding] = []
    site = _site(c, inst)
    scratch_names = {s.name for s in c.scratch}
    reds = {}
    for r in c.reductions:
        reds.setdefault(r.out, []).append(r)
    for op in c.outputs:
        try:
            idx = _eval(c, op)
        except NonAffine:
            continue                      # GL103 already raised by coverage
        if idx is None:
            continue
        used = set()
        for e in idx:
            used.update(e.support)
        declared = {a for r in reds.get(op.name, ()) for a in r.axes}
        for ax, (name, size) in enumerate(c.grid):
            if name in used or size <= 1:
                continue
            # grid axis `name` revisits this output block every step
            if c.semantics[ax] == "parallel":
                out.append(finding(
                    "GL201", "error", site,
                    f"output {op.name!r} is invariant along grid axis "
                    f"{name!r} (size {size}) declared \"parallel\": "
                    f"parallel revisits race on the block",
                    key=f"{op.name}:{name}"))
            elif name not in declared:
                out.append(finding(
                    "GL202", "error", site,
                    f"output {op.name!r} is revisited along sequential "
                    f"axis {name!r} (size {size}) with no declared "
                    f"reduction: each revisit overwrites the block",
                    key=f"{op.name}:{name}"))
        for r in reds.get(op.name, ()):
            if r.via == "alias":
                out.append(finding(
                    "GL203", "error", site,
                    f"output {op.name!r} declares serial accumulation "
                    f"through an input/output alias over axes {r.axes}: "
                    f"Pallas does not guarantee read-after-write through "
                    f"an alias across separated grid revisits (the seed "
                    f"WS GEMM bug) — accumulate in VMEM scratch and flush "
                    f"on the final revisit instead",
                    key=f"{op.name}:alias"))
            elif r.via == "scratch":
                if r.scratch not in scratch_names:
                    out.append(finding(
                        "GL204", "error", site,
                        f"reduction on {op.name!r} names scratch "
                        f"{r.scratch!r} but the contract declares only "
                        f"{sorted(scratch_names)}", key=f"{op.name}:scratch"))
            else:
                out.append(finding(
                    "GL204", "error", site,
                    f"reduction on {op.name!r}: unknown mechanism "
                    f"{r.via!r}", key=f"{op.name}:via"))
    return out


# -- VMEM budget ------------------------------------------------------------

def _block_bytes(op: OperandSpec) -> int:
    return math.prod(op.block) * op.dtype[1]


def _streamed(c: KernelContract, op: OperandSpec) -> bool:
    """Does this operand's block change along any sequential axis?"""
    if op.data_dependent is not None:
        return True                       # gathers re-DMA per step
    try:
        idx = _eval(c, op)
    except NonAffine:
        return True
    seq = {name for (name, _), sem in zip(c.grid, c.semantics)
           if sem == "arbitrary"}
    return any(set(e.support) & seq for e in idx)


def check_vmem(c: KernelContract, cfg: GemminiConfig, *,
               inst: str = "") -> List[Finding]:
    out: List[Finding] = []
    site = _site(c, inst)
    streamed = resident_spad = resident_acc = 0
    detail = {"streamed": [], "resident": [], "scratch": []}
    for op in c.inputs + c.outputs:
        if op.memory_space == "smem":
            continue
        nbytes = _block_bytes(op)
        if _streamed(c, op):
            streamed += nbytes
            detail["streamed"].append((op.name, nbytes))
        elif op.budget == "scratchpad":
            resident_spad += nbytes
            detail["resident"].append((op.name, nbytes))
        else:
            resident_acc += nbytes
            detail["resident"].append((op.name, nbytes))
    scratch_bytes = sum(math.prod(s.shape) * s.dtype[1] for s in c.scratch)
    detail["scratch"] = [(s.name, math.prod(s.shape) * s.dtype[1])
                         for s in c.scratch]
    spad_need = cfg.pipeline_depth * streamed + resident_spad
    if spad_need > cfg.scratchpad_bytes:
        out.append(finding(
            "GL301", "error", site,
            f"streamed blocks x pipeline_depth ({cfg.pipeline_depth} x "
            f"{streamed} B) + resident streams ({resident_spad} B) = "
            f"{spad_need} B exceed scratchpad_bytes="
            f"{cfg.scratchpad_bytes}", key="spad",
            streamed=detail["streamed"], resident=detail["resident"]))
    acc_need = resident_acc + scratch_bytes
    if acc_need > cfg.accumulator_bytes:
        out.append(finding(
            "GL302", "error", site,
            f"resident blocks ({resident_acc} B) + VMEM scratch "
            f"({scratch_bytes} B) = {acc_need} B exceed "
            f"accumulator_bytes={cfg.accumulator_bytes}", key="acc",
            scratch=detail["scratch"]))
    return out


def fits_budgets(c: KernelContract, cfg: GemminiConfig) -> bool:
    """True iff the contract's per-step footprint fits both VMEM budgets
    (the tuner's plan-feasibility predicate)."""
    return not check_vmem(c, cfg)


# -- precision --------------------------------------------------------------

def check_precision(c: KernelContract, *, inst: str = "") -> List[Finding]:
    out: List[Finding] = []
    site = _site(c, inst)
    for i, d in enumerate(c.dots):
        narrow = d.lhs[1] < 4 or d.rhs[1] < 4
        wide_acc = d.acc[1] >= 4
        kinds = {d.lhs[0], d.rhs[0]}
        kind_ok = (d.acc[0] == "int") == (kinds == {"int"})
        if narrow and not (wide_acc and kind_ok):
            out.append(finding(
                "GL401", "error", site,
                f"dot {i}: {d.lhs}x{d.rhs} inputs accumulate into {d.acc} "
                f"— narrow operands need a >=32-bit accumulator of the "
                f"matching kind (preferred_element_type)", key=f"dot{i}"))
    for op in c.inputs:
        if (op.memory_space != "smem" and math.prod(op.block) <= 8
                and len(op.block) == 1):
            out.append(finding(
                "GL402", "warning", site,
                f"operand {op.name!r}: scalar-sized block {op.block} "
                f"not placed in SMEM — scalar control operands belong in "
                f"SMEM (memory_space=pltpu.SMEM)", key=op.name))
    return out


# -- all of the above -------------------------------------------------------

def check_contract(c: KernelContract, cfg: GemminiConfig, *,
                   inst: str = "") -> List[Finding]:
    out: List[Finding] = []
    out += check_coverage(c, inst=inst)
    out += check_races(c, inst=inst)
    out += check_vmem(c, cfg, inst=inst)
    out += check_precision(c, inst=inst)
    if inst:
        out = [dataclasses.replace(f, data=f.data + (("instantiation", inst),))
               for f in out]
    return out


def check_all(contracts: Iterable[Tuple[KernelContract, GemminiConfig, str]]
              ) -> List[Finding]:
    out: List[Finding] = []
    for c, cfg, inst in contracts:
        out += check_contract(c, cfg, inst=inst)
    return out
