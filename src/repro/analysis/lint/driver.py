"""Lint driver: instantiate every kernel contract over the tuner's
schedule lattice and run the full static check suite over the repo.

The probe problems below are the smoke-config shapes the serving and
training paths actually launch (small enough to enumerate the whole
lattice in milliseconds, large enough that every grid axis is > 1 so
coverage/race proofs are non-vacuous).  For each (kernel family,
probe), every schedule the tuner would consider
(`tune/schedules.py` / `core/tiling.py`) is checked — this is the same
predicate the tuner's plan-feasibility hook consults, so "the linter
is clean" and "the tuner never measures an infeasible plan" are one
fact.
"""

from __future__ import annotations

import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.config import GemminiConfig
from repro.core import tiling
from repro.tune import schedules
from repro.kernels import contracts as kc
from repro.analysis.lint import checks, source
from repro.analysis.lint.findings import Finding, dedupe

KERNEL_FILES = ("gemm.py", "attention.py", "conv.py", "mamba2.py")

# serving engine's bf16 config + the paper-faithful int8 default
PROBE_CFGS = (
    GemminiConfig(),
    GemminiConfig(input_dtype="bf16", acc_dtype="fp32", output_dtype="bf16"),
)


def _gemm_contracts(cfg: GemminiConfig):
    m = n = k = 512
    for has_bias in (False, True):
        for plan in tiling.enumerate_plans(cfg, m, n, k, has_bias=has_bias,
                                           max_candidates=16):
            inst = (f"m{m}n{n}k{k}t{plan.tile_m}x{plan.tile_n}x"
                    f"{plan.tile_k}{'b' if has_bias else ''}")
            yield (kc.gemm_os_contract(cfg, plan, has_bias=has_bias),
                   cfg, inst)
            yield (kc.gemm_ws_contract(cfg, plan, has_bias=has_bias),
                   cfg, inst)
            yield (kc.accumulator_epilogue_contract(
                cfg, plan, m=plan.m, n=plan.n), cfg, inst)


def _attn_contracts(cfg: GemminiConfig):
    b, h, kvh, tq, tk, d = 2, 8, 2, 1024, 1024, 128
    in_bytes = 2
    for s in schedules.enumerate_attn_schedules(
            cfg, b, h, kvh, tq, tk, d, in_bytes=in_bytes):
        eff = s.effective(tq, tk)
        inst = f"bq{eff.block_q}bk{eff.block_k}"
        yield (kc.flash_attention_contract(
            cfg, b=b, h=h, kvh=kvh, tq=tq, tk=tk, d=d,
            block_q=eff.block_q, block_k=eff.block_k), cfg, inst)
        yield (kc.decode_attention_contract(
            cfg, b=b, h=h, kvh=kvh, s=tk, d=d, block_k=eff.block_k),
            cfg, inst)


def _paged_contracts(cfg: GemminiConfig):
    b, h, kvh, d, max_context = 4, 8, 2, 128, 2048
    for s in schedules.enumerate_paged_schedules(cfg, b, h, kvh, d,
                                                 max_context):
        page = s.effective(max_context).page_size
        mp = -(-max_context // page)
        inst = f"page{page}"
        yield (kc.paged_decode_attention_contract(
            cfg, b=b, h=h, kvh=kvh, d=d, page=page, mp=mp,
            n_pages=b * mp), cfg, inst)
        yield (kc.paged_prefill_attention_contract(
            cfg, h=h, kvh=kvh, tq=512, d=d, page=page, mp=mp,
            n_pages=b * mp, block_q=512), cfg, inst)


def _conv_contracts(cfg: GemminiConfig):
    n, h, w, ci, co, khw = 2, 16, 16, 64, 256, 3
    for s in schedules.enumerate_conv_schedules(cfg, n, h, w, ci, co,
                                                khw, khw, padding=1):
        ct = s.effective(co).co_tile
        for has_bias in (False, True):
            yield (kc.conv2d_implicit_contract(
                cfg, n=n, h=h, w=w, ci=ci, co=co, kh=khw, kw=khw,
                co_tile=ct, padding=1, has_bias=has_bias), cfg,
                f"ct{ct}{'b' if has_bias else ''}")


def _ssd_contracts(cfg: GemminiConfig):
    for rfs in (False, True):
        yield (kc.ssd_contract(
            cfg, bsz=2, h=8, nc=4, q=256, p=64, n=64, ngroups=2,
            return_final_state=rfs), cfg, f"fs{int(rfs)}")


def iter_repo_contracts(cfgs: Sequence[GemminiConfig] = PROBE_CFGS):
    for cfg in cfgs:
        yield from _gemm_contracts(cfg)
        yield from _attn_contracts(cfg)
        yield from _paged_contracts(cfg)
        yield from _conv_contracts(cfg)
        yield from _ssd_contracts(cfg)


def run_contract_checks(cfgs: Sequence[GemminiConfig] = PROBE_CFGS
                        ) -> List[Finding]:
    return dedupe(checks.check_all(iter_repo_contracts(cfgs)))


def _kernels_dir() -> Path:
    import repro.kernels as pkg
    return Path(pkg.__file__).parent


def run_source_checks(kernels_dir: Optional[Path] = None) -> List[Finding]:
    kdir = Path(kernels_dir) if kernels_dir else _kernels_dir()
    out: List[Finding] = []
    for name in KERNEL_FILES:
        p = kdir / name
        if p.exists():
            out += source.check_kernel_file(p)
    out += source.check_shim_ban(sorted(kdir.glob("*.py")))
    return dedupe(out)


_CONTRACT_FAMILIES = (
    ("contracts:gemm", _gemm_contracts),
    ("contracts:attn", _attn_contracts),
    ("contracts:paged", _paged_contracts),
    ("contracts:conv", _conv_contracts),
    ("contracts:ssd", _ssd_contracts),
)


def lint_repo_timed(cfgs: Sequence[GemminiConfig] = PROBE_CFGS,
                    kernels_dir: Optional[Path] = None
                    ) -> Tuple[List[Finding], Dict[str, float]]:
    """:func:`lint_repo` plus per-check wall time: one timing bucket per
    contract family and one for the AST source pass, so the JSON report
    shows where the now-multi-pass CI lint job spends its budget.
    Per-family dedupe is equivalent to the global one -- a fingerprint's
    site names its contract family."""
    timings: Dict[str, float] = {}
    out: List[Finding] = []
    for name, gen in _CONTRACT_FAMILIES:
        t0 = time.perf_counter()
        items = []
        for cfg in cfgs:
            items.extend(gen(cfg))
        out += dedupe(checks.check_all(items))
        timings[name] = time.perf_counter() - t0
    t0 = time.perf_counter()
    out += run_source_checks(kernels_dir)
    timings["source"] = time.perf_counter() - t0
    sev = {"error": 0, "warning": 1, "info": 2}
    out = sorted(out, key=lambda f: (sev[f.severity], f.code, f.site))
    return out, timings


def lint_repo(cfgs: Sequence[GemminiConfig] = PROBE_CFGS,
              kernels_dir: Optional[Path] = None) -> List[Finding]:
    """The full static suite: contract checks over the schedule lattice
    plus the AST rules over the kernel sources."""
    return lint_repo_timed(cfgs, kernels_dir)[0]


# re-export for the feasibility hook's lazy import
fits_budgets = checks.fits_budgets
