"""Structured lint findings + the baseline-suppression file.

A :class:`Finding` is one diagnostic at one site.  Its ``fingerprint``
is stable across runs (code + site + discriminating key, *not* the
human-readable message), so a baseline file can suppress known
findings without pinning message wording or line numbers.

The baseline file (`tools/lint_baseline.json`) is a JSON object
``{"suppress": [{"fingerprint": ..., "code": ..., "site": ...,
"reason": ...}, ...]}``; the extra fields are for humans reading the
diff, only the fingerprint is matched.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Sequence, Tuple

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic.

    code:     stable diagnostic id, e.g. ``GL201`` (docs/analysis.md).
    severity: ``error`` | ``warning`` | ``info``.
    site:     where — ``path/to/file.py::function`` for source findings,
              ``contract:<name>[<instantiation>]`` for contract findings,
              ``engine:<step>`` for jit-audit findings.
    message:  human-readable explanation (not part of the fingerprint).
    key:      extra fingerprint discriminator when one site can carry
              several findings under one code (e.g. the operand name).
    data:     structured detail for the JSON report.
    """

    code: str
    severity: str
    site: str
    message: str
    key: str = ""
    data: Tuple[Tuple[str, object], ...] = ()

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r}")

    @property
    def fingerprint(self) -> str:
        h = hashlib.sha256(
            f"{self.code}|{self.site}|{self.key}".encode()).hexdigest()
        return h[:16]

    def to_dict(self) -> Dict:
        return {
            "code": self.code,
            "severity": self.severity,
            "site": self.site,
            "message": self.message,
            "key": self.key,
            "fingerprint": self.fingerprint,
            "data": dict(self.data),
        }


def finding(code: str, severity: str, site: str, message: str, *,
            key: str = "", **data) -> Finding:
    return Finding(code=code, severity=severity, site=site, message=message,
                   key=key, data=tuple(sorted(data.items())))


def dedupe(findings: Sequence[Finding]) -> List[Finding]:
    """Collapse identical fingerprints (e.g. the same contract violation
    re-proven at every schedule in the lattice): keep the first, count
    the rest in ``data['occurrences']``."""
    by_fp: Dict[str, Finding] = {}
    counts: Dict[str, int] = {}
    for f in findings:
        fp = f.fingerprint
        counts[fp] = counts.get(fp, 0) + 1
        by_fp.setdefault(fp, f)
    out = []
    for fp, f in by_fp.items():
        if counts[fp] > 1:
            f = dataclasses.replace(
                f, data=f.data + (("occurrences", counts[fp]),))
        out.append(f)
    return out


def to_report(findings: Sequence[Finding], *,
              suppressed: Sequence[Finding] = ()) -> Dict:
    sev = {s: sum(1 for f in findings if f.severity == s)
           for s in SEVERITIES}
    return {
        "schema": 1,
        "counts": {**sev, "total": len(findings),
                   "suppressed": len(suppressed)},
        "findings": [f.to_dict() for f in findings],
        "suppressed": [f.to_dict() for f in suppressed],
    }


# -- baseline ---------------------------------------------------------------

def load_baseline(path) -> Dict[str, Dict]:
    """fingerprint -> suppression entry.  Missing file = empty baseline."""
    try:
        with open(path) as fh:
            raw = json.load(fh)
    except FileNotFoundError:
        return {}
    return {e["fingerprint"]: e for e in raw.get("suppress", [])}


def write_baseline(path, findings: Sequence[Finding]) -> None:
    entries = [{"fingerprint": f.fingerprint, "code": f.code,
                "site": f.site, "key": f.key,
                "reason": "baselined (pre-existing)"}
               for f in sorted(findings, key=lambda f: (f.code, f.site))]
    with open(path, "w") as fh:
        json.dump({"suppress": entries}, fh, indent=2)
        fh.write("\n")


def apply_baseline(findings: Sequence[Finding],
                   baseline: Optional[Dict[str, Dict]]
                   ) -> Tuple[List[Finding], List[Finding]]:
    """-> (new, suppressed)."""
    baseline = baseline or {}
    new, suppressed = [], []
    for f in findings:
        (suppressed if f.fingerprint in baseline else new).append(f)
    return new, suppressed
