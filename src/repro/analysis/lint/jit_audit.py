"""Trace-time auditor for the serving engine's jit layer.

The engine compiles one bucket per distinct argument-shape/static-arg
tuple and keeps them in module-level caches (`serving/engine.py`'s
``_JIT_CACHE``).  Buckets are supposed to be *bounded by construction*:
prompt lengths quantize to ``prefill_pad`` multiples, chunk lengths to
the chunk spans the scheduler emits, and the chunk steps' static
``kv_pages`` is capped by ``max_pages_per_seq``.  A bucket census
derived from that geometry is therefore a hard ceiling — a jitted step
whose observed cache size exceeds it means some argument leaks
unquantized shapes into the trace (a compile-time explosion under real
traffic).

The second audit catches post-donation reuse: with donating jits
(``nan_guard`` off), the previous state's buffers are consumed by each
step; any *retained* reference that reports ``.is_deleted()`` would
fault (or silently read garbage on some backends) when next touched.

===== ==================================================================
GL601 observed jit cache size exceeds the static bucket census
GL602 a live engine reference points at a donated (deleted) buffer
===== ==================================================================
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax

from repro.analysis.lint.findings import Finding, finding


def expected_bucket_census(engine) -> Dict[str, int]:
    """Static per-step compile-bucket ceiling from the engine geometry."""
    n_prompt_buckets = max(1, engine.max_context // engine.prefill_pad)
    census = {
        "prefill": n_prompt_buckets,
        "prefill_nl": n_prompt_buckets,
        "decode": 1,
    }
    chunk = getattr(engine, "prefill_chunk", None)
    if chunk:
        # chunk lengths quantize to the scheduler's span padding; the
        # static kv_pages bound adds one bucket per value in
        # [1, max_pages_per_seq] plus the None (= full table) fallback.
        n_chunk_lens = max(1, -(-engine.max_context // chunk))
        kv_page_values = engine.max_pages_per_seq + 1
        census["chunk"] = n_chunk_lens * kv_page_values
        census["chunk_nl"] = n_chunk_lens * kv_page_values
    else:
        census["chunk"] = census["chunk_nl"] = 0
    return census


def _cache_size(fn) -> Optional[int]:
    try:
        return int(fn._cache_size())
    except Exception:
        return None


def audit_jit_buckets(engine) -> List[Finding]:
    out: List[Finding] = []
    census = expected_bucket_census(engine)
    step_sets = [("steps", engine._steps)]
    if getattr(engine, "_fb_steps", None):
        step_sets.append(("fallback", engine._fb_steps))
    for label, steps in step_sets:
        for which, fn in steps.items():
            expect = census.get(which)
            observed = _cache_size(fn)
            if expect is None or observed is None:
                continue
            if observed > expect:
                out.append(finding(
                    "GL601", "error", f"engine:{which}",
                    f"{label}[{which!r}] compiled {observed} buckets; the "
                    f"static census caps it at {expect} (prefill_pad="
                    f"{engine.prefill_pad}, prefill_chunk="
                    f"{getattr(engine, 'prefill_chunk', None)}, "
                    f"max_pages_per_seq={engine.max_pages_per_seq}) — an "
                    f"argument is reaching the trace unquantized",
                    key=label, observed=observed, expected=expect))
    # the engine-tracked bucket keys (recorded at dispatch) are subject
    # to the same ceiling — catches explosions even after a cache clear.
    for which, seen in getattr(engine, "observed_buckets", {}).items():
        expect = census.get(which)
        if expect is not None and len(seen) > expect:
            out.append(finding(
                "GL601", "error", f"engine:{which}",
                f"engine dispatched {len(seen)} distinct {which!r} bucket "
                f"keys; the static census caps it at {expect}: "
                f"{sorted(seen)[:8]}...",
                key="dispatched", observed=len(seen), expected=expect))
    return out


def audit_donation(refs) -> List[Finding]:
    """GL602 over a pytree of possibly-donated arrays.

    ``refs``: dict of name -> pytree (engine state, params, pools...).
    """
    out: List[Finding] = []
    for name, tree in refs.items():
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            deleted = getattr(leaf, "is_deleted", None)
            if callable(deleted) and deleted():
                out.append(finding(
                    "GL602", "error", f"engine:{name}",
                    f"{name}{jax.tree_util.keystr(path)} references a "
                    f"donated (deleted) buffer — it was consumed by a "
                    f"donating jitted step; touching it faults",
                    key=jax.tree_util.keystr(path)))
    return out


def audit_engine(engine) -> List[Finding]:
    """Full trace-time audit of a live ServingEngine."""
    out = audit_jit_buckets(engine)
    out += audit_donation({
        "state": engine.state,
        "params": engine.params,
    })
    return out
