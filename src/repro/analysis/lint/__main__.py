"""``python -m repro.analysis.lint`` — the CI lint gate.

Runs the full static suite (contract checks over the tuner's schedule
lattice + AST source rules), subtracts the baseline, prints findings,
and exits non-zero if any non-baselined finding remains.

  python -m repro.analysis.lint                      # human output
  python -m repro.analysis.lint --format json        # CI artifact
  python -m repro.analysis.lint --write-baseline     # accept current
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.lint import (apply_baseline, lint_repo, load_baseline,
                                 write_baseline)
from repro.analysis.lint.findings import to_report


def _default_baseline() -> Path:
    # repo checkout layout: <root>/src/repro/analysis/lint/__main__.py
    root = Path(__file__).resolve().parents[4]
    return root / "tools" / "lint_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="static kernel-contract + source lint (docs/analysis.md)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="suppression file (default: tools/lint_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, suppressing nothing")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline")
    ap.add_argument("--out", type=Path, default=None,
                    help="also write the JSON report to this path")
    args = ap.parse_args(argv)

    findings = lint_repo()

    baseline_path = args.baseline or _default_baseline()
    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"baselined {len(findings)} finding(s) -> {baseline_path}")
        return 0
    baseline = {} if args.no_baseline else load_baseline(baseline_path)
    new, suppressed = apply_baseline(findings, baseline)

    report = to_report(new, suppressed=suppressed)
    if args.out:
        args.out.write_text(json.dumps(report, indent=2, default=str) + "\n")
    if args.format == "json":
        print(json.dumps(report, indent=2, default=str))
    else:
        for f in new:
            print(f"{f.severity.upper():7s} {f.code} {f.site}: {f.message}")
        c = report["counts"]
        print(f"{c['total']} finding(s) "
              f"({c['error']} error, {c['warning']} warning; "
              f"{c['suppressed']} baselined)")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
