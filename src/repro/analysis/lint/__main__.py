"""``python -m repro.analysis.lint`` — the CI lint gate.

Runs the full static suite (contract checks over the tuner's schedule
lattice + AST source rules), subtracts the baseline, prints findings,
and exits non-zero if any non-baselined finding remains.

  python -m repro.analysis.lint                      # human output
  python -m repro.analysis.lint --format json        # CI artifact
  python -m repro.analysis.lint --select GL201,GL3   # only those codes
  python -m repro.analysis.lint --write-baseline     # accept current
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.lint import (apply_baseline, load_baseline,
                                 write_baseline)
from repro.analysis.lint.driver import lint_repo_timed
from repro.analysis.lint.findings import to_report


def _default_baseline() -> Path:
    # repo checkout layout: <root>/src/repro/analysis/lint/__main__.py
    root = Path(__file__).resolve().parents[4]
    return root / "tools" / "lint_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="static kernel-contract + source lint (docs/analysis.md)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", type=str, default=None, metavar="CODES",
                    help="comma-separated diagnostic-code prefixes "
                         "(e.g. GL201,GL3); findings outside the "
                         "selection are dropped before the baseline")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="suppression file (default: tools/lint_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, suppressing nothing")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline")
    ap.add_argument("--out", type=Path, default=None,
                    help="also write the JSON report to this path")
    args = ap.parse_args(argv)

    findings, timings = lint_repo_timed()
    if args.select:
        sel = tuple(c.strip() for c in args.select.split(",") if c.strip())
        findings = [f for f in findings
                    if any(f.code.startswith(c) for c in sel)]

    baseline_path = args.baseline or _default_baseline()
    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"baselined {len(findings)} finding(s) -> {baseline_path}")
        return 0
    baseline = {} if args.no_baseline else load_baseline(baseline_path)
    new, suppressed = apply_baseline(findings, baseline)

    report = to_report(new, suppressed=suppressed)
    report["timings_s"] = {k: round(v, 4) for k, v in timings.items()}
    if args.out:
        args.out.write_text(json.dumps(report, indent=2, default=str) + "\n")
    if args.format == "json":
        print(json.dumps(report, indent=2, default=str))
    else:
        for f in new:
            print(f"{f.severity.upper():7s} {f.code} {f.site}: {f.message}")
        c = report["counts"]
        print(f"{c['total']} finding(s) "
              f"({c['error']} error, {c['warning']} warning; "
              f"{c['suppressed']} baselined)")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
