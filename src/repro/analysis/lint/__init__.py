"""Static analysis for the kernel and jit layers (docs/analysis.md).

Two halves, one finding stream:

* **Static** (`checks`, `source`, `driver`): every `pallas_call` in
  `src/repro/kernels/` declares a :class:`~repro.kernels.contracts.KernelContract`
  mirroring its grid / BlockSpecs / scratch.  The checker abstractly
  interprets the BlockSpec index maps over the grid (symbolically, via
  `affine`) and, for every schedule in the tuner's lattice
  (`tune/schedules.py`), proves coverage, write-race freedom, VMEM
  budget fit, and the precision contracts.  `source` adds AST-level
  rules over the kernel sources themselves (undeclared `pallas_call`s,
  narrow dots, the deprecated-shim ban).
* **Trace-time** (`jit_audit`): audits a live `ServingEngine` for
  compile-bucket explosions (observed jit cache sizes vs. the static
  bucket census) and post-donation buffer reuse.

Findings are structured (`findings.Finding`), suppressible via a
baseline file (`tools/lint_baseline.json`), and gate CI through
``python -m repro.analysis.lint``.
"""

from repro.analysis.lint.findings import (          # noqa: F401
    Finding, apply_baseline, load_baseline, write_baseline)
from repro.analysis.lint.driver import (            # noqa: F401
    lint_repo, lint_repo_timed, run_contract_checks, run_source_checks)
