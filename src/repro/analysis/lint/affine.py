"""Symbolic domain for abstract interpretation of BlockSpec index maps.

The kernels' index maps are tiny affine functions of the grid
coordinates — sums of ``var``, ``var // c``, ``c * var`` and integer
constants (see `kernels/attention.py`'s ``hh // rep`` GQA sharing).
Calling such a lambda with :class:`Ix` values instead of ints yields a
closed-form :class:`Ix` whose range, variable support, and coverage
over a block axis are decidable exactly:

* **range** — min/max over the grid box (each term is monotone in its
  own variable, so the box extremes are per-term extremes).
* **coverage** — whether the expression provably takes *every* value in
  ``[0, nb)`` as the grid is swept.  Proven for the unit cases the
  kernels actually use: a bare variable, ``var // c`` over a contiguous
  grid axis (floor of a contiguous range is contiguous), and the
  mixed-radix sum ``i * radix + j`` (decode's fused ``b*kvh`` axis).
* **support** — which grid axes the expression depends on; a grid axis
  absent from every output-dim expression is a *revisit* axis (the
  write-race check's raw material).

Maps that are not affine in the grid (the paged kernels' scalar-table
gathers) raise :class:`NonAffine` when evaluated; contracts must
declare those operands ``data_dependent`` instead.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


class NonAffine(Exception):
    """An index map stepped outside the affine fragment."""


@dataclasses.dataclass(frozen=True)
class Term:
    """``coeff * (var // div)`` with ``var`` ranging over ``[0, size)``."""

    var: str
    size: int
    div: int
    coeff: int

    def range(self) -> Tuple[int, int]:
        hi = self.coeff * ((self.size - 1) // self.div)
        return (min(0, hi), max(0, hi))


class Ix:
    """An affine-with-floordiv index expression over grid variables."""

    __slots__ = ("terms", "const")

    def __init__(self, terms: Tuple[Term, ...] = (), const: int = 0):
        # canonical: merged by (var, div), zero coeffs dropped, sorted
        merged: Dict[Tuple[str, int], Term] = {}
        for t in terms:
            key = (t.var, t.div)
            if key in merged:
                prev = merged[key]
                merged[key] = Term(t.var, t.size, t.div, prev.coeff + t.coeff)
            else:
                merged[key] = t
        self.terms = tuple(sorted(
            (t for t in merged.values() if t.coeff != 0),
            key=lambda t: (t.var, t.div)))
        self.const = const

    # -- constructors ------------------------------------------------------
    @staticmethod
    def var(name: str, size: int) -> "Ix":
        if size < 1:
            raise ValueError(f"grid axis {name!r} has size {size}")
        return Ix((Term(name, size, 1, 1),), 0)

    @staticmethod
    def lift(v) -> "Ix":
        if isinstance(v, Ix):
            return v
        if isinstance(v, (int,)) and not isinstance(v, bool):
            return Ix((), v)
        raise NonAffine(f"cannot lift {type(v).__name__} into the affine "
                        f"domain (data-dependent index map?)")

    # -- arithmetic (the fragment the kernels' index maps use) -------------
    def __add__(self, other) -> "Ix":
        o = Ix.lift(other)
        return Ix(self.terms + o.terms, self.const + o.const)

    __radd__ = __add__

    def __sub__(self, other) -> "Ix":
        return self + (Ix.lift(other) * -1)

    def __rsub__(self, other) -> "Ix":
        return Ix.lift(other) + (self * -1)

    def __mul__(self, other) -> "Ix":
        if isinstance(other, Ix):
            if not other.terms:
                other = other.const
            elif not self.terms:
                return other * self.const
            else:
                raise NonAffine("product of two grid variables is not affine")
        if not isinstance(other, int) or isinstance(other, bool):
            raise NonAffine(f"multiply by {type(other).__name__}")
        return Ix(tuple(Term(t.var, t.size, t.div, t.coeff * other)
                        for t in self.terms), self.const * other)

    __rmul__ = __mul__

    def __floordiv__(self, d) -> "Ix":
        if isinstance(d, Ix):
            if d.terms:
                raise NonAffine("division by a grid variable")
            d = d.const
        if not isinstance(d, int) or d <= 0:
            raise NonAffine(f"floordiv by {d!r}")
        if d == 1:
            return self
        if not self.terms:
            return Ix((), self.const // d)
        # only a bare unit variable divides exactly: floor(v/d)
        if (len(self.terms) == 1 and self.const == 0
                and self.terms[0].div == 1 and self.terms[0].coeff == 1):
            t = self.terms[0]
            return Ix((Term(t.var, t.size, d, 1),), 0)
        raise NonAffine("floordiv of a compound affine expression")

    def __neg__(self) -> "Ix":
        return self * -1

    def __mod__(self, other):
        raise NonAffine("mod is outside the affine fragment")

    def __eq__(self, other) -> bool:
        o = Ix.lift(other) if isinstance(other, (Ix, int)) else None
        return (o is not None and self.terms == o.terms
                and self.const == o.const)

    def __hash__(self):
        return hash((self.terms, self.const))

    def __repr__(self):
        parts = [f"{t.coeff}*({t.var}//{t.div})" if t.div > 1
                 else f"{t.coeff}*{t.var}" for t in self.terms]
        parts.append(str(self.const))
        return " + ".join(parts)

    # -- analysis ----------------------------------------------------------
    @property
    def support(self) -> Tuple[str, ...]:
        return tuple(dict.fromkeys(t.var for t in self.terms))

    def range(self) -> Tuple[int, int]:
        lo = self.const + sum(t.range()[0] for t in self.terms)
        hi = self.const + sum(t.range()[1] for t in self.terms)
        return lo, hi

    def covers(self, nb: int) -> bool:
        """Provably takes every value in ``[0, nb)`` over the grid box."""
        if not self.terms:
            return self.const == 0 and nb == 1
        if self.const != 0:
            return False
        if len(self.terms) == 1:
            t = self.terms[0]
            # floor(v/d) over contiguous v in [0, size) hits every integer
            # in [0, (size-1)//d] (monotone, step <= 1).
            return t.coeff == 1 and (t.size - 1) // t.div == nb - 1
        # mixed radix: coeff_k == product of later ranges, all unit divs,
        # e.g. i*gn + j over (gm, gn) covering gm*gn blocks.
        ts = sorted(self.terms, key=lambda t: -abs(t.coeff))
        if any(t.div != 1 for t in ts):
            return False
        radix = 1
        for t in reversed(ts):
            if t.coeff != radix:
                return False
            radix *= t.size
        return radix == nb

    def injective_in(self, axes: Tuple[str, ...]) -> bool:
        """True if distinct values of the listed axes provably give
        distinct expression values (used to prove disjoint writes along
        one operand dim).  Conservative: unit-div, mixed-radix only."""
        ts = [t for t in self.terms if t.var in axes]
        if len(ts) != len(set(t.var for t in ts)):
            return False
        if any(t.div != 1 for t in ts):
            return False
        ts = sorted(ts, key=lambda t: -abs(t.coeff))
        bound = 0
        for t in reversed(ts):
            if abs(t.coeff) <= bound:
                return False
            bound = abs(t.coeff) * (t.size - 1) + bound
        return True


def grid_vars(grid: Tuple[Tuple[str, int], ...]) -> Tuple[Ix, ...]:
    return tuple(Ix.var(name, size) for name, size in grid)


def eval_index_map(index_map, grid: Tuple[Tuple[str, int], ...]
                   ) -> Tuple[Ix, ...]:
    """Run an index-map lambda on symbolic grid coordinates.

    Raises :class:`NonAffine` if the map leaves the affine fragment
    (e.g. reads a prefetched scalar ref).
    """
    try:
        out = index_map(*grid_vars(grid))
    except NonAffine:
        raise
    except Exception as e:
        # e.g. a Python-level table lookup or scalar-ref read applied to a
        # symbolic coordinate: outside the fragment, not a checker crash.
        raise NonAffine(f"index map escaped the affine domain: {e!r}")
    if not isinstance(out, tuple):
        out = (out,)
    return tuple(Ix.lift(v) for v in out)
