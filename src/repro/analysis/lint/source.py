"""AST-level lint over the kernel sources (and the shim ban repo-wide).

These rules look at the *text* of the launch sites — the half of the
contract system the symbolic checker cannot see, because it checks
declared contracts, not the code that must match them:

===== ==================================================================
GL501 a function contains `pl.pallas_call` but carries no
      ``@kernel_contract(...)`` annotation resolving to a registered
      builder (`kernels/contracts.py`)
GL502 a matmul (`dot_general`/`jnp.dot`/`jnp.matmul`/`jnp.einsum`)
      inside a kernel file without ``preferred_element_type``
GL503 a `pallas_call` without ``compiler_params``/``dimension_semantics``
      (Mosaic then serializes every axis — usually a perf bug, and the
      race checker's soundness assumes declared semantics)
GL504 `input_output_aliases` at a launch site whose contract does not
      declare the alias (undeclared in-place update; aliased
      *accumulation* is GL203 at the contract layer)
GL505 a rank-1 scalar-sized BlockSpec without ``memory_space``
      (scalar control operands belong in SMEM)
GL506 the deprecated ``ops.*(backend=...)`` shim machinery
      (``_deprecated_shim`` or a legacy top-level alias in
      `kernels/ops.py`) reintroduced — removed for good in PR 7
===== ==================================================================
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis.lint.findings import Finding, finding

# the PR-5 shims deleted in PR 7; binding these names at ops.py module
# level (rather than the *_impl entries) would resurrect the pre-
# ExecutionContext API.
LEGACY_SHIM_NAMES = frozenset({
    "gemm", "matmul", "conv2d", "flash_attention", "paged_attention",
    "paged_prefill_attention", "ssd",
})


def _attr_chain(node: ast.AST) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _calls(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node, _attr_chain(node.func)


def _kw(call: ast.Call, name: str) -> Optional[ast.keyword]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw
    return None


def _contract_name(fn: ast.FunctionDef) -> Optional[str]:
    for deco in fn.decorator_list:
        if isinstance(deco, ast.Call) \
                and _attr_chain(deco.func).endswith("kernel_contract") \
                and deco.args and isinstance(deco.args[0], ast.Constant):
            return deco.args[0].value
    return None


def check_kernel_file(path, *, registry=None) -> List[Finding]:
    """GL501/502/503/504/505 over one kernel source file."""
    path = Path(path)
    if registry is None:
        from repro.kernels.contracts import CONTRACT_BUILDERS
        registry = CONTRACT_BUILDERS
    tree = ast.parse(path.read_text(), filename=str(path))
    out: List[Finding] = []
    rel = path.name if "src" not in path.parts else \
        str(path.relative_to(next(p for p in path.parents
                                  if p.name == "src")))

    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        site = f"{rel}::{fn.name}"
        pallas_calls = [(c, callee) for c, callee in _calls(fn)
                        if callee.endswith("pallas_call")]
        if not pallas_calls:
            continue
        cname = _contract_name(fn)
        if cname is None:
            out.append(finding(
                "GL501", "error", site,
                f"{fn.name} launches pallas_call without a "
                f"@kernel_contract annotation"))
        elif cname not in registry:
            out.append(finding(
                "GL501", "error", site,
                f"@kernel_contract({cname!r}) does not resolve to a "
                f"registered builder in kernels/contracts.py", key=cname))
        for call, _ in pallas_calls:
            if _kw(call, "compiler_params") is None:
                out.append(finding(
                    "GL503", "warning", site,
                    f"pallas_call at line {call.lineno} has no "
                    f"compiler_params — declare dimension_semantics "
                    f"explicitly (Mosaic serializes undeclared axes, and "
                    f"the race check assumes declared semantics)",
                    key=f"L{call.lineno}"))
            alias_kw = _kw(call, "input_output_aliases")
            if alias_kw is not None:
                declared = False
                if cname is not None and cname in registry:
                    import inspect
                    sig_doc = inspect.getsource(registry[cname])
                    declared = "io_aliases" in sig_doc
                if not declared:
                    out.append(finding(
                        "GL504", "error", site,
                        f"pallas_call at line {call.lineno} uses "
                        f"input_output_aliases but contract "
                        f"{cname or '<none>'} declares no io_aliases — "
                        f"undeclared in-place update (and NEVER sound as "
                        f"an accumulator across grid revisits: GL203)",
                        key=f"L{call.lineno}"))

    for call, callee in _calls(tree):
        if callee.split(".")[-1] in ("dot_general", "dot", "matmul",
                                     "einsum"):
            if _kw(call, "preferred_element_type") is None:
                out.append(finding(
                    "GL502", "error", f"{rel}::L{call.lineno}",
                    f"{callee} at line {call.lineno} has no "
                    f"preferred_element_type — narrow inputs would "
                    f"accumulate at input precision"))
        if callee.endswith("BlockSpec") and call.args:
            blk = call.args[0]
            if isinstance(blk, ast.Tuple) and len(blk.elts) == 1 \
                    and _kw(call, "memory_space") is None:
                out.append(finding(
                    "GL505", "warning", f"{rel}::L{call.lineno}",
                    f"rank-1 BlockSpec at line {call.lineno} without "
                    f"memory_space — scalar control operands belong in "
                    f"SMEM (memory_space=pltpu.SMEM)"))
    return out


def check_shim_ban(paths: Sequence) -> List[Finding]:
    """GL506 across the given source files."""
    out: List[Finding] = []
    for path in paths:
        path = Path(path)
        tree = ast.parse(path.read_text(), filename=str(path))
        rel = path.name if "src" not in path.parts else \
            str(path.relative_to(next(p for p in path.parents
                                      if p.name == "src")))
        for node in ast.walk(tree):
            if isinstance(node, (ast.Name, ast.Attribute)):
                name = node.id if isinstance(node, ast.Name) else node.attr
                if name == "_deprecated_shim":
                    out.append(finding(
                        "GL506", "error", f"{rel}::L{node.lineno}",
                        f"_deprecated_shim at line {node.lineno}: the "
                        f"ops.*(backend=...) deprecation shims were "
                        f"removed in PR 7 — route through "
                        f"ExecutionContext (ctx.<op>) instead"))
        if rel.endswith("kernels/ops.py"):
            for node in tree.body:
                targets = []
                if isinstance(node, ast.Assign):
                    targets = [t.id for t in node.targets
                               if isinstance(t, ast.Name)]
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    targets = [node.name]
                for t in targets:
                    if t in LEGACY_SHIM_NAMES:
                        out.append(finding(
                            "GL506", "error", f"{rel}::{t}",
                            f"top-level {t!r} in kernels/ops.py shadows "
                            f"the removed legacy ops.{t}(backend=...) "
                            f"API — only *_impl entries (dispatched via "
                            f"ExecutionContext) belong here", key=t))
    return out
