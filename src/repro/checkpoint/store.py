"""Fault-tolerant checkpointing: shard-per-host files + manifest.

Layout (tensorstore-free, dependency-light, restart- and reshard-safe):

    <dir>/step_000100/
        manifest.json         tree structure, global shapes/dtypes, mesh info
        host_00000.npz        this host's addressable shards, keyed
                              "<leaf_idx>|<offset,...>" -> ndarray
        _COMMITTED            written last; a checkpoint without it is
                              ignored (atomic-commit marker)

Properties needed at 1000+-node scale:

* **Shard-per-host writes.** Each process serializes only its addressable
  shards -- O(model/hosts) I/O per host, no gather to host 0.
* **Atomic commit.** Writes go to ``step_N.tmp`` and are renamed after the
  ``_COMMITTED`` marker lands, so a mid-save failure never corrupts the
  latest checkpoint.
* **Elastic restore.** ``restore_checkpoint`` rebuilds each global array
  from shard files via ``jax.make_array_from_callback`` against the *target*
  sharding -- which may be a different mesh than the one that saved (pod
  loss: 2x16x16 -> 16x16). Shards are addressed by global offsets, so any
  saved topology restores onto any target topology.
* **Async save.** ``CheckpointManager.save_async`` snapshots device arrays
  to host memory synchronously (cheap) and writes files on a background
  thread, overlapping I/O with the next training steps.
* **Keep-last-k GC** so long runs do not fill the filesystem.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _tree_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out


def _offsets(arr: jax.Array, shard) -> Tuple[int, ...]:
    return tuple(0 if idx.start is None else int(idx.start)
                 for idx in shard.index)


def _numpy_safe(a: np.ndarray) -> np.ndarray:
    """ml_dtypes (bfloat16, fp8) round-trip .npz as raw void; store them as
    a same-width uint view instead (the manifest records the true dtype)."""
    if a.dtype.kind not in "biufc":
        return a.view(np.dtype(f"u{a.dtype.itemsize}"))
    return a


def _restore_dtype(block: np.ndarray, dtype) -> np.ndarray:
    dt = np.dtype(dtype)
    if block.dtype == dt:
        return block
    if block.dtype.itemsize == dt.itemsize and block.dtype.kind in "uV":
        return block.view(dt)
    return block.astype(dt)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra_meta: Optional[Dict] = None) -> str:
    """Synchronous save. Returns the committed directory path.

    Consults the process-global fault injector (``ckpt_io`` specs, site
    ``checkpoint``): an injected write failure raises OSError *before*
    anything touches disk -- exactly the failure class the tmp-dir +
    ``_COMMITTED`` + rename protocol and ``run_with_restarts`` exist to
    absorb, now provokable deterministically."""
    from repro.runtime import faults as _faults
    _inj = _faults.active()
    if _inj is not None and _inj.ckpt_fails():
        raise OSError(f"injected checkpoint-write failure at step {step}")
    host = jax.process_index()
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves = _tree_paths(tree)
    shards_out: Dict[str, np.ndarray] = {}
    manifest_leaves = []
    for li, (path, leaf) in enumerate(leaves):
        arr = leaf
        manifest_leaves.append(dict(
            path=path, shape=list(arr.shape), dtype=str(arr.dtype)))
        if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
            shard_list = arr.addressable_shards
        else:
            arr = jnp.asarray(arr)
            shard_list = arr.addressable_shards
        seen = set()
        for sh in shard_list:
            off = _offsets(arr, sh)
            if off in seen:        # replicated: write one copy per host
                continue
            seen.add(off)
            key = f"{li}|{','.join(map(str, off))}"
            shards_out[key] = _numpy_safe(np.asarray(sh.data))
    np.savez(os.path.join(tmp, f"host_{host:05d}.npz"), **shards_out)

    if host == 0:
        treedef = jax.tree_util.tree_structure(tree)
        manifest = dict(step=step, leaves=manifest_leaves,
                        treedef=str(treedef),
                        n_processes=jax.process_count(),
                        extra=extra_meta or {})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        open(os.path.join(tmp, "_COMMITTED"), "w").close()
    # single-process rename; multi-host: host 0 renames after a barrier
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def read_manifest(ckpt_dir: str, step: int) -> Dict:
    with open(os.path.join(ckpt_dir, f"step_{step:08d}",
                           "manifest.json")) as f:
        return json.load(f)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "_COMMITTED")):
            best = max(best or -1, int(m.group(1)))
    return best


def restore_checkpoint(ckpt_dir: str, step: int, target: Any,
                       shardings: Any) -> Any:
    """Restore onto ``shardings`` (possibly a different mesh than saved).

    ``target``: pytree of ShapeDtypeStructs (or arrays) giving the structure.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(d, "_COMMITTED")):
        raise FileNotFoundError(f"no committed checkpoint at {d}")
    hosts = sorted(f for f in os.listdir(d) if f.startswith("host_"))
    files = [np.load(os.path.join(d, h)) for h in hosts]

    # index: leaf -> [(offsets, host_file, key)]
    index: Dict[int, List[Tuple[Tuple[int, ...], Any, str]]] = {}
    for f in files:
        for key in f.files:
            li_s, off_s = key.split("|")
            off = tuple(int(x) for x in off_s.split(",")) if off_s else ()
            index.setdefault(int(li_s), []).append((off, f, key))

    leaves = _tree_paths(target)
    flat_shardings = [s for _, s in _tree_paths(shardings)]
    out_leaves = []
    for li, (path, leaf) in enumerate(leaves):
        shape, dtype = tuple(leaf.shape), leaf.dtype
        shards = index.get(li, [])
        if not shards:
            raise KeyError(f"leaf {li} ({path}) missing from checkpoint")

        def make(idx, shards=shards, shape=shape, dtype=dtype):
            # paste the saved shards covering `idx` into one ndarray
            starts = tuple(0 if s.start is None else s.start for s in idx)
            stops = tuple(shape[i] if s.stop is None else s.stop
                          for i, s in enumerate(idx))
            out = np.zeros(tuple(b - a for a, b in zip(starts, stops)),
                           dtype)
            for off, f, key in shards:
                block = _restore_dtype(f[key], dtype)
                # intersection of [off, off+block.shape) with [starts, stops)
                lo = tuple(max(o, a) for o, a in zip(off, starts))
                hi = tuple(min(o + s, b)
                           for o, s, b in zip(off, block.shape, stops))
                if any(l >= h for l, h in zip(lo, hi)):
                    continue
                src = tuple(slice(l - o, h - o)
                            for l, o, h in zip(lo, off, hi))
                dst = tuple(slice(l - a, h - a)
                            for l, a, h in zip(lo, starts, hi))
                out[dst] = block[src]
            return out

        out_leaves.append(jax.make_array_from_callback(
            shape, flat_shardings[li], make))
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


class CheckpointManager:
    """Async save + keep-last-k GC around the plain save/restore calls."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any,
                   extra_meta: Optional[Dict] = None):
        """Snapshot to host memory now; write files on a background thread."""
        self.wait()
        host_tree = jax.tree.map(
            lambda x: jax.device_get(x) if isinstance(x, jax.Array) else x,
            tree)
        snapshot = jax.tree.map(np.asarray, host_tree)

        def work():
            save_checkpoint(self.dir, step, snapshot, extra_meta)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree: Any, extra_meta: Optional[Dict] = None):
        self.wait()
        save_checkpoint(self.dir, step, tree, extra_meta)
        self._gc()

    def restore_latest(self, target: Any, shardings: Any,
                       expect_meta: Optional[Dict] = None
                       ) -> Tuple[Optional[int], Any]:
        """Restore the newest committed checkpoint. If ``expect_meta`` is
        given, any key present in both it and the saved manifest's extra
        metadata must match -- refusing to load a checkpoint from a
        different arch/run into this one."""
        self.wait()
        step = latest_step(self.dir)
        if step is None:
            return None, None
        if expect_meta:
            saved = read_manifest(self.dir, step).get("extra", {})
            for k, v in expect_meta.items():
                if k in saved and saved[k] != v:
                    raise ValueError(
                        f"checkpoint at step {step} has {k}={saved[k]!r}, "
                        f"this run expects {v!r} -- refusing to restore")
        return step, restore_checkpoint(self.dir, step, target, shardings)

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.dir))
            if m)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
