"""AdamW with global-norm clipping, built from scratch (no optax).

State layout is ZeRO-1 friendly: ``m``/``v`` mirror the parameter pytree in
fp32 and receive their own (data-axis-extended) shardings from
launch/sharding.py, so the optimizer shards over the DP domain while params
stay DP-replicated -- XLA inserts the reduce-scatter (grad -> shard) and
all-gather (updated param) automatically from the sharding annotations.

Also provides top-k gradient compression hooks (distributed-optimization
trick; see runtime/compression.py) applied before the update when enabled.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Params) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params: Params, grads: Params,
                 state: Dict[str, Any],
                 lr_scale: jnp.ndarray | float = 1.0
                 ) -> Tuple[Params, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One optimizer step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:       # no decay on norms/biases
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
