"""Decoder stack covering all 10 assigned architectures.

One *homogeneous* block definition per family (dense / moe / ssm / hybrid),
scanned over layers with stacked parameters so 60-layer models lower to a
single compiled block body (compile-time tractability for the 512-device
dry-run). Per-layer heterogeneity (gemma local:global interleave, per-layer
rope bases) is expressed as *scanned data* (traced per-layer window size /
rope base arrays), not as distinct block bodies.

Multimodal frontends are stubs per the assignment: ``extra_embeds`` carries
precomputed patch (VLM) or frame (audio) embeddings, concatenated before the
first block.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.generator import GemminiInstance
from repro.models import attention as attn
from repro.models import layers, moe, ssm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# model configuration
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    activation: str = "silu"
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    local_window: Optional[int] = None   # sliding window for "local" layers
    global_period: int = 0               # every Nth layer is global (0 = all global)
    rope_base: float = 10000.0
    rope_base_local: Optional[float] = None
    post_norms: bool = False             # gemma2/3 post-block norms
    qk_norm: bool = False                # gemma3
    embed_scale: bool = False            # gemma: embeddings * sqrt(d)
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    router_weights_before: bool = False  # llama4 style
    capacity_factor: float = 1.25
    expert_padding: int = 16             # pad experts to the EP degree
    # SSM
    d_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    d_conv: int = 4
    ssm_chunk: int = 256
    # multimodal stubs
    modality: str = "none"               # none | vlm | audio
    n_codebooks: int = 1                 # musicgen
    n_meta_tokens: int = 0               # hymba
    dtype: Any = jnp.bfloat16

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attn(self) -> bool:
        return self.family in ("dense", "moe", "hybrid")

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS roofline terms)."""
        d, l = self.d_model, self.n_layers
        n = self.vocab * d * self.n_codebooks          # embed
        if not self.tie_embeddings or self.n_codebooks > 1:
            n += self.vocab * d * self.n_codebooks     # unembed heads
        per_layer = 0
        if self.has_attn:
            per_layer += d * (self.n_heads + 2 * self.n_kv_heads) * \
                self.head_dim + self.n_heads * self.head_dim * d
        if self.has_ssm:
            in_dim = 2 * self.d_inner + 2 * self.ssm_groups * self.d_state \
                + self.n_ssm_heads
            per_layer += d * in_dim + self.d_inner * d
        if self.family == "moe":
            e = self.n_experts
            per_layer += d * e                                   # router
            per_layer += 3 * d * self.moe_d_ff * e               # experts
            if self.n_shared_experts:
                per_layer += 3 * d * self.moe_d_ff * self.n_shared_experts
        elif self.family in ("dense", "hybrid") and self.d_ff:
            per_layer += 3 * d * self.d_ff
        return n + l * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts)."""
        if self.family != "moe":
            return self.param_count()
        d, l, e = self.d_model, self.n_layers, self.n_experts
        full = self.param_count()
        inactive = l * 3 * d * self.moe_d_ff * (e - self.top_k)
        return full - inactive


def layer_windows(cfg: ModelConfig, seq_hint: int) -> np.ndarray:
    """Per-layer sliding-window sizes; 0 encodes 'global' (full attention)."""
    win = np.zeros((cfg.n_layers,), np.int32)
    if cfg.local_window:
        for i in range(cfg.n_layers):
            is_global = (cfg.global_period > 0 and
                         (i + 1) % cfg.global_period == 0)
            win[i] = 0 if is_global else cfg.local_window
    return win


def uniform_window(win_np: np.ndarray) -> Optional[int]:
    """The single static window shared by every layer (0 = global), or None
    when layers disagree (gemma-style local:global interleave). A static
    window lets the layer scan route attention to the Pallas kernel (and
    its tuned schedule) on pallas/interpret engines; mixed-window models
    scan the window as traced data and keep the XLA path."""
    vals = {int(w) for w in win_np}
    return vals.pop() if len(vals) == 1 else None


def layer_rope_bases(cfg: ModelConfig) -> np.ndarray:
    base = np.full((cfg.n_layers,), cfg.rope_base, np.float32)
    if cfg.rope_base_local is not None and cfg.local_window:
        for i in range(cfg.n_layers):
            is_global = (cfg.global_period > 0 and
                         (i + 1) % cfg.global_period == 0)
            if not is_global:
                base[i] = cfg.rope_base_local
    return base


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------
def _block_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": layers.rmsnorm_init(cfg.d_model)}
    if cfg.has_attn:
        p["attn"] = attn.attn_init(ks[0], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim,
                                   qkv_bias=cfg.qkv_bias, dtype=cfg.dtype)
        if cfg.qk_norm:
            p["qnorm"] = layers.rmsnorm_init(cfg.head_dim)
            p["knorm"] = layers.rmsnorm_init(cfg.head_dim)
    if cfg.has_ssm:
        p["mamba"] = ssm.mamba2_init(
            ks[1], cfg.d_model, d_inner=cfg.d_inner,
            n_heads=cfg.n_ssm_heads, d_state=cfg.d_state,
            n_groups=cfg.ssm_groups, d_conv=cfg.d_conv, dtype=cfg.dtype)
    if cfg.family == "moe":
        p["ln2"] = layers.rmsnorm_init(cfg.d_model)
        p["moe"] = moe.moe_init(ks[2], cfg.d_model, cfg.moe_d_ff,
                                cfg.n_experts, ep=cfg.expert_padding,
                                n_shared=cfg.n_shared_experts,
                                dtype=cfg.dtype)
    elif cfg.d_ff and cfg.family != "ssm":
        p["ln2"] = layers.rmsnorm_init(cfg.d_model)
        p["mlp"] = layers.mlp_init(ks[3], cfg.d_model, cfg.d_ff,
                                   dtype=cfg.dtype)
    if cfg.post_norms:
        p["post_ln1"] = layers.rmsnorm_init(cfg.d_model)
        if "ln2" in p:
            p["post_ln2"] = layers.rmsnorm_init(cfg.d_model)
    if cfg.family == "hybrid":
        # per-branch output norms before averaging (hymba)
        p["attn_out_norm"] = layers.rmsnorm_init(cfg.d_model)
        p["ssm_out_norm"] = layers.rmsnorm_init(cfg.d_model)
    return p


# Param names that are engine-backed (d_in, d_out) projection weights.
# MoE expert stacks (4D: layers x experts x d x d_ff) are excluded: their
# per-expert GEMMs run through einsum in models/moe.py, not through
# ctx.gemm, so they never resolve a tile plan. (The MoE router and shared
# MLP do route through the engine and are covered.)
_PROJ_KEYS = frozenset({"wq", "wk", "wv", "wo", "wi", "wg", "router",
                        "in_proj", "out_proj", "unembed", "heads"})


def model_gemm_shapes(cfg: ModelConfig, batch: int, seq: int, *,
                      include_decode: bool = True) -> list:
    """Every (M, N, K, has_bias) GEMM shape the model's projections run.

    Walked from the parameter tree under ``jax.eval_shape`` (no allocation):
    each projection weight's trailing (d_in, d_out) becomes a
    (batch*seq, d_out, d_in) prefill/train GEMM, plus the (batch, d_out,
    d_in) single-token decode GEMM. ``has_bias`` is detected from a sibling
    bias leaf (``wq`` -> ``bq``): biased projections ride the engine's
    native D input (``layers.project``), and the tuner fingerprints them
    separately, so the warm pass must resolve them with the flag or it
    populates entries the request path never hits. Used by
    ``repro.tune.warm_model_plans`` to pre-tune a whole model's schedule
    before the first request arrives.
    """
    import functools
    shapes = jax.eval_shape(functools.partial(init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    ms = [batch * seq] + ([batch] if include_decode else [])
    leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]

    def _names(path):
        return tuple(p.key for p in path
                     if isinstance(p, jax.tree_util.DictKey))

    # Leaf names present under each parent dict, to detect sibling biases.
    siblings: dict = {}
    for path, _ in leaves:
        names = _names(path)
        if names:
            siblings.setdefault(names[:-1], set()).add(names[-1])

    out, seen = [], set()
    for path, leaf in leaves:
        if len(leaf.shape) < 2:
            continue
        names = _names(path)
        name = names[-1] if names else ""
        if "moe" in names and name in ("wi", "wg", "wo"):
            continue                      # einsum expert GEMMs, not engine
        if name in _PROJ_KEYS:
            k_in, n_out = leaf.shape[-2], leaf.shape[-1]
        elif name == "embed" and cfg.tie_embeddings and cfg.n_codebooks == 1:
            k_in, n_out = leaf.shape[-1], leaf.shape[-2]   # unembed: table.T
        else:
            continue
        has_bias = (name.startswith("w")
                    and "b" + name[1:] in siblings.get(names[:-1], ()))
        for m in ms:
            t = (int(m), int(n_out), int(k_in), bool(has_bias))
            if t not in seen:
                seen.add(t)
                out.append(t)
    return out


def model_attention_shapes(cfg: ModelConfig, batch: int, seq: int) -> list:
    """Every (B, Tq, Tk, H, KVH, D, causal, window) flash-attention shape
    the model runs at this (batch, seq): one per distinct per-layer window
    (gemma-style local:global interleaving collapses to two shapes). Used
    by ``repro.tune.warm_model_plans`` so attention schedules resolve from
    the cache on the request path."""
    if not cfg.has_attn:
        return []
    out = []
    for w in sorted({int(w) for w in layer_windows(cfg, seq)}):
        out.append((batch, seq, seq, cfg.n_heads, cfg.n_kv_heads,
                    cfg.head_dim, True, None if w == 0 else w))
    return out


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4 + cfg.n_layers)
    if cfg.n_codebooks > 1:
        embed = jnp.stack([layers.embed_init(k, cfg.vocab, cfg.d_model,
                                             dtype=cfg.dtype)
                           for k in jax.random.split(ks[0], cfg.n_codebooks)])
    else:
        embed = layers.embed_init(ks[0], cfg.vocab, cfg.d_model,
                                  dtype=cfg.dtype)
    # stacked per-layer params: tree_map over per-layer inits
    per_layer = [_block_init(ks[4 + i], cfg) for i in range(cfg.n_layers)]
    blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    p: Params = {"embed": embed, "blocks": blocks,
                 "final_norm": layers.rmsnorm_init(cfg.d_model)}
    if cfg.n_codebooks > 1:
        p["heads"] = jnp.stack([layers.dense_init(k, cfg.d_model, cfg.vocab,
                                                  dtype=cfg.dtype)
                                for k in jax.random.split(ks[1],
                                                          cfg.n_codebooks)])
    elif not cfg.tie_embeddings:
        p["unembed"] = layers.dense_init(ks[1], cfg.d_model, cfg.vocab,
                                         dtype=cfg.dtype)
    if cfg.n_meta_tokens:
        p["meta_tokens"] = (jax.random.normal(
            ks[2], (cfg.n_meta_tokens, cfg.d_model), jnp.float32) * 0.02
        ).astype(cfg.dtype)
    return p


# ---------------------------------------------------------------------------
# block forward (shared by train/prefill and decode)
# ---------------------------------------------------------------------------
def _maybe_qknorm(cfg, bp, q, k):
    if cfg.qk_norm:
        q = layers.rmsnorm(q, bp["qnorm"])
        k = layers.rmsnorm(k, bp["knorm"])
    return q, k


def _attn_branch(engine, cfg, bp, h, positions, window, rope_base,
                 cache=None, cache_pos=None, window_static=None,
                 prefill_start=None, kv_pages=None):
    """window: traced scalar, 0 = global; window_static: the same value as
    a python int when the model is window-uniform (None = unavailable, use
    the traced scalar). Returns (out, new_cache). ``cache`` may be a dense
    :class:`attn.KVCache` (static-batch serving) or a paged
    :class:`attn.PagedKVCache` (the continuous-batching engine).
    ``prefill_start``: traced scalar cache position of a chunked-prefill
    continuation chunk's first token (None = not a continuation chunk);
    selects the scatter-at-offset + cache-and-chunk gather attention path.
    ``kv_pages``: static bound on live block-table entries for that path
    (the serving engine's admission-time prompt footprint)."""
    b, t, _ = h.shape
    p = bp["attn"]
    q = layers.project(engine, h, p["wq"], p.get("bq")).reshape(
        b, t, cfg.n_heads, cfg.head_dim)
    k = layers.project(engine, h, p["wk"], p.get("bk")).reshape(
        b, t, cfg.n_kv_heads, cfg.head_dim)
    v = layers.project(engine, h, p["wv"], p.get("bv")).reshape(
        b, t, cfg.n_kv_heads, cfg.head_dim)
    q, k = _maybe_qknorm(cfg, bp, q, k)
    q = layers.rope(q, positions, base=rope_base)
    k = layers.rope(k, positions, base=rope_base)

    # encode "global" as window > any position: mask kpos > qpos - window
    eff_window = jnp.where(window > 0, window, jnp.int32(2 ** 30))
    win_arg = window_static if window_static is not None else eff_window
    if isinstance(cache, attn.PagedKVCache):
        # The continuation-chunk test must PRECEDE the t == 1 decode test:
        # a final chunk can legally be one token long (recurrent families
        # never pad, so total % chunk == 1 happens), and routing it to the
        # decode branch would read the chunk cache's unset active/trash.
        if prefill_start is not None:
            # chunked-prefill continuation: scatter the chunk's KV at its
            # offset, then attend cache pages + the fresh chunk through the
            # block-table gather path (write first, then attend).
            cache = attn.paged_update_prefill(cache, k, v, cache.tables[0],
                                              start=prefill_start)
            o = attn.paged_prefill_attn_op(engine, q, cache, prefill_start,
                                           window=win_arg,
                                           softcap=cfg.attn_softcap,
                                           kv_pages=kv_pages)
        elif t == 1:
            cache = attn.paged_update_decode(cache, k, v, cache.active,
                                             cache.trash)
            o = attn.paged_attn_op(engine, q, cache, window=win_arg,
                                   softcap=cfg.attn_softcap)
        else:
            # fresh-request prefill: the prompt attends only itself, so the
            # pool is write-only here (scatter into the allocated pages).
            cache = attn.paged_update_prefill(cache, k, v, cache.tables[0])
            o = attn.attn_op(engine, q, k, v, causal=True, window=win_arg,
                             softcap=cfg.attn_softcap)
    elif cache is not None:
        cache = attn.update_cache(cache, k, v, cache_pos)
        if t == 1:
            o = attn.decode_attention(q, cache, cache_pos,
                                      window=eff_window,
                                      softcap=cfg.attn_softcap)
        else:
            # prefill from position 0: attend only the t written positions
            # (the cache tail beyond t is unwritten zeros, and blockwise
            # attention right-aligns queries against the key length).
            o = attn.attn_op(engine, q, cache.k[:, :t], cache.v[:, :t],
                             causal=True, window=win_arg,
                             softcap=cfg.attn_softcap)
    else:
        o = attn.attn_op(engine, q, k, v, causal=True, window=win_arg,
                         softcap=cfg.attn_softcap)
    o = o.reshape(b, t, cfg.n_heads * cfg.head_dim)
    return layers.project(engine, o, p["wo"]), cache


def _block_apply(engine, cfg: ModelConfig, bp: Params, h: jnp.ndarray,
                 positions, window, rope_base,
                 kv_cache=None, ssm_cache=None, cache_pos=None,
                 window_static=None, prefill_start=None, kv_pages=None):
    """One decoder block. Returns (h, kv_cache, ssm_cache)."""
    x = layers.rmsnorm(h, bp["ln1"])
    outs = []
    if cfg.has_attn:
        a_out, kv_cache = _attn_branch(engine, cfg, bp, x, positions, window,
                                       rope_base, kv_cache, cache_pos,
                                       window_static=window_static,
                                       prefill_start=prefill_start,
                                       kv_pages=kv_pages)
        outs.append(("attn", a_out))
    if cfg.has_ssm:
        s_out, ssm_cache = ssm.mamba2_apply(
            engine, bp["mamba"], x, d_inner=cfg.d_inner,
            n_heads=cfg.n_ssm_heads, d_state=cfg.d_state,
            n_groups=cfg.ssm_groups, chunk=cfg.ssm_chunk, cache=ssm_cache)
        outs.append(("ssm", s_out))
    if cfg.family == "hybrid":
        a = layers.rmsnorm(outs[0][1], bp["attn_out_norm"])
        s = layers.rmsnorm(outs[1][1], bp["ssm_out_norm"])
        mixed = 0.5 * (a.astype(jnp.float32) + s.astype(jnp.float32))
        mixed = mixed.astype(h.dtype)
    else:
        mixed = outs[0][1]
    if cfg.post_norms:
        mixed = layers.rmsnorm(mixed, bp["post_ln1"])
    h = h + mixed

    if "moe" in bp:
        x2 = layers.rmsnorm(h, bp["ln2"])
        serving = kv_cache is not None or ssm_cache is not None
        f = moe.moe_apply(engine, bp["moe"], x2, n_experts=cfg.n_experts,
                          top_k=cfg.top_k,
                          capacity_factor=cfg.capacity_factor,
                          activation=cfg.activation,
                          router_weights_before=cfg.router_weights_before,
                          dropless=serving)
        if cfg.post_norms:
            f = layers.rmsnorm(f, bp["post_ln2"])
        h = h + f
    elif "mlp" in bp:
        x2 = layers.rmsnorm(h, bp["ln2"])
        f = layers.mlp_apply(engine, bp["mlp"], x2, activation=cfg.activation)
        if cfg.post_norms:
            f = layers.rmsnorm(f, bp["post_ln2"])
        h = h + f
    return h, kv_cache, ssm_cache


# ---------------------------------------------------------------------------
# embedding frontends (incl. multimodal stubs)
# ---------------------------------------------------------------------------
def embed_inputs(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                 extra_embeds: Optional[jnp.ndarray] = None, *,
                 with_meta: bool = True) -> jnp.ndarray:
    """tokens: (B, T) or (B, T, n_q) for audio. extra_embeds: (B, Ti, D)
    precomputed frontend embeddings (VLM patches / audio conditioning),
    prepended to the token embeddings. ``with_meta=False`` skips the
    hymba meta-token prefix -- chunked prefill prepends it only on the
    first chunk (the meta tokens live at cache positions [0, n_meta))."""
    if cfg.n_codebooks > 1:
        # musicgen: sum the per-codebook embeddings
        h = sum(layers.embed_apply(params["embed"][i], tokens[..., i])
                for i in range(cfg.n_codebooks))
    else:
        h = layers.embed_apply(params["embed"], tokens,
                               scale_by_sqrt_dim=cfg.embed_scale)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    if cfg.n_meta_tokens and with_meta:
        b = h.shape[0]
        meta = jnp.broadcast_to(params["meta_tokens"][None],
                                (b, cfg.n_meta_tokens, cfg.d_model))
        h = jnp.concatenate([meta.astype(h.dtype), h], axis=1)
    return h


def unembed(engine, cfg: ModelConfig, params: Params,
            h: jnp.ndarray) -> jnp.ndarray:
    h = layers.rmsnorm(h, params["final_norm"])
    if cfg.n_codebooks > 1:
        logits = jnp.stack(
            [layers.project(engine, h, params["heads"][i])
             for i in range(cfg.n_codebooks)], axis=-2)  # (B,T,n_q,V)
        return logits.astype(jnp.float32)
    table = params["embed"] if cfg.tie_embeddings else None
    if table is not None:
        return layers.unembed_apply(engine, table, h,
                                    softcap=cfg.final_softcap)
    logits = layers.project(engine, h, params["unembed"]).astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------
def _constrain(x, sharding):
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def forward(engine: GemminiInstance, params: Params, cfg: ModelConfig,
            tokens: jnp.ndarray,
            extra_embeds: Optional[jnp.ndarray] = None, *,
            remat: bool = False,
            residual_sharding=None,
            logits_sharding=None) -> jnp.ndarray:
    """remat: rematerialize each block in backward (train memory policy).
    residual_sharding: NamedSharding for the (B, T, D) layer-scan carry
    (sequence-parallel storage); logits_sharding: vocab-sharded logits."""
    h = embed_inputs(cfg, params, tokens, extra_embeds)
    b, t, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    win_np = layer_windows(cfg, t)
    windows = jnp.asarray(win_np)
    bases = jnp.asarray(layer_rope_bases(cfg))
    h = _constrain(h, residual_sharding)

    def body(h, xs):
        bp, win, base = xs
        # No window_static here: forward() is the TRAIN path (loss_fn
        # differentiates through it) and the Pallas flash kernel has no
        # VJP, so attention must stay on the differentiable XLA route on
        # every backend. The inference paths (prefill_into_cache /
        # paged_prefill) pass the static window and get the kernel.
        h, _, _ = _block_apply(engine, cfg, bp, h, positions, win, base)
        return _constrain(h, residual_sharding), None

    if remat:
        from repro.core import flags
        pol = flags.get("remat_policy")
        if pol == "dots":
            # save MXU outputs, recompute elementwise: spends VMEM/HBM
            # residency to avoid re-running every projection (and its TP
            # collectives) in the backward pass
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.
                dots_with_no_batch_dims_saveable)
        elif pol == "none":
            pass                     # save everything (no recompute)
        else:                        # "full": the minimal-residency baseline
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, (params["blocks"], windows, bases))
    logits = unembed(engine, cfg, params, h)
    return _constrain(logits, logits_sharding)


def loss_fn(engine, params, cfg: ModelConfig, tokens, labels,
            extra_embeds=None, **fwd_kw) -> jnp.ndarray:
    """Next-token cross-entropy; labels == -100 are masked."""
    logits = forward(engine, params, cfg, tokens, extra_embeds, **fwd_kw)
    if extra_embeds is not None:       # prefix positions carry no loss
        logits = logits[:, extra_embeds.shape[1]:]
    if cfg.n_meta_tokens:
        logits = logits[:, cfg.n_meta_tokens:]
    if cfg.n_codebooks > 1:
        logits = logits[:, :-1]                       # (B,T-1,n_q,V)
        tgt = labels[:, 1:]                           # (B,T-1,n_q)
    else:
        logits = logits[:, :-1]
        tgt = labels[:, 1:]
    mask = (tgt >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(tgt, 0)[..., None],
                             axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------
class DecodeState(NamedTuple):
    kv_k: Optional[jnp.ndarray]       # (L, B, S, KVH, D) or None
    kv_v: Optional[jnp.ndarray]
    conv: Optional[jnp.ndarray]       # (L, B, K-1, conv_dim) or None
    ssm: Optional[jnp.ndarray]        # (L, B, H, N, P) or None
    pos: jnp.ndarray                  # scalar int32: next write position


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int,
                      dtype=jnp.bfloat16) -> DecodeState:
    kv_k = kv_v = conv = st = None
    if cfg.has_attn:
        shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        kv_k = jnp.zeros(shape, dtype)
        kv_v = jnp.zeros(shape, dtype)
    if cfg.has_ssm:
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.d_state
        conv = jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1, conv_dim),
                         dtype)
        st = jnp.zeros((cfg.n_layers, batch, cfg.n_ssm_heads, cfg.d_state,
                        cfg.ssm_head_dim), jnp.float32)
    return DecodeState(kv_k, kv_v, conv, st,
                       jnp.zeros((), jnp.int32) + (max_seq - 1))


def prefill_into_cache(engine: GemminiInstance, params: Params,
                       cfg: ModelConfig, tokens: jnp.ndarray,
                       state: DecodeState,
                       extra_embeds: Optional[jnp.ndarray] = None
                       ) -> Tuple[jnp.ndarray, DecodeState]:
    """Forward over the prompt writing KV/SSM caches at positions [0, P).

    tokens: (B, P) [or (B, P, n_q)]. Returns (logits (B, P', V), state with
    ``pos`` = number of cached positions = the next write position).
    """
    h = embed_inputs(cfg, params, tokens, extra_embeds)
    b, t, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    win_np = layer_windows(cfg, t)
    windows = jnp.asarray(win_np)
    static_win = uniform_window(win_np)
    bases = jnp.asarray(layer_rope_bases(cfg))
    write_pos = jnp.zeros((), jnp.int32)

    def body(h, xs):
        bp, win, base, kv_k, kv_v, conv, st = xs
        kvc = attn.KVCache(kv_k, kv_v) if kv_k is not None else None
        # state=None: a FRESH whole-prompt prefill (init_decode_state's
        # zeros carry no history) -- routes the SSD to the fused kernel
        # on pallas/interpret engines (see ssm.SSMCache).
        ssc = ssm.SSMCache(conv, None) if conv is not None else None
        h, kvc, ssc = _block_apply(engine, cfg, bp, h, positions, win, base,
                                   kv_cache=kvc, ssm_cache=ssc,
                                   cache_pos=write_pos,
                                   window_static=static_win)
        new = (kvc.k if kvc else None, kvc.v if kvc else None,
               ssc.conv if ssc else None, ssc.state if ssc else None)
        return h, new

    xs = (params["blocks"], windows, bases, state.kv_k, state.kv_v,
          state.conv, state.ssm)
    h, caches = jax.lax.scan(body, h, xs)
    kv_k, kv_v, conv, st = caches
    logits = unembed(engine, cfg, params, h)
    return logits, DecodeState(kv_k, kv_v, conv, st,
                               jnp.asarray(t, jnp.int32))


def decode_step(engine: GemminiInstance, params: Params, cfg: ModelConfig,
                tokens: jnp.ndarray, state: DecodeState
                ) -> Tuple[jnp.ndarray, DecodeState]:
    """One serving step: tokens (B, 1) [or (B, 1, n_q)] with a KV/SSM cache
    of ``max_seq``; returns logits for the new token and the updated state."""
    if cfg.n_codebooks > 1:
        h = sum(layers.embed_apply(params["embed"][i], tokens[..., i])
                for i in range(cfg.n_codebooks))
    else:
        h = layers.embed_apply(params["embed"], tokens,
                               scale_by_sqrt_dim=cfg.embed_scale)
    b = h.shape[0]
    pos = state.pos
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    windows = jnp.asarray(layer_windows(cfg, 0))
    bases = jnp.asarray(layer_rope_bases(cfg))

    from repro.core import flags
    if flags.get("decode_unroll"):
        win_np = layer_windows(cfg, 0)
        base_np = layer_rope_bases(cfg)
        kv_k, kv_v = state.kv_k, state.kv_v
        conv, st = state.conv, state.ssm
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda p: p[i], params["blocks"])
            kvc = attn.KVCache(kv_k[i], kv_v[i]) \
                if kv_k is not None else None
            ssc = ssm.SSMCache(conv[i], st[i]) if conv is not None else None
            h, kvc, ssc = _block_apply(
                engine, cfg, bp, h, positions,
                jnp.int32(int(win_np[i])), float(base_np[i]),
                kv_cache=kvc, ssm_cache=ssc, cache_pos=pos)
            if kvc is not None:
                kv_k = kv_k.at[i].set(kvc.k.astype(kv_k.dtype))
                kv_v = kv_v.at[i].set(kvc.v.astype(kv_v.dtype))
            if ssc is not None:
                conv = conv.at[i].set(ssc.conv.astype(conv.dtype))
                st = st.at[i].set(ssc.state.astype(st.dtype))
        logits = unembed(engine, cfg, params, h)
        return logits, DecodeState(kv_k, kv_v, conv, st, pos + 1)

    if flags.get("cache_as_carry"):
        # carry the stacked caches; slice layer li in, DUS the update back
        # in place. XLA's in-place dynamic-update-slice fusion keeps the
        # carry aliased, so per layer only the layer's slice moves.
        def body_c(carry, xs):
            h, kv_k, kv_v, conv, st = carry
            bp, win, base, li = xs

            def sl(stack):
                if stack is None:
                    return None
                s = jax.lax.dynamic_index_in_dim(stack, li, 0,
                                                 keepdims=False)
                return s

            def up(stack, new):
                if stack is None:
                    return None
                return jax.lax.dynamic_update_index_in_dim(
                    stack, new.astype(stack.dtype), li, 0)

            kvc = attn.KVCache(sl(kv_k), sl(kv_v)) \
                if kv_k is not None else None
            ssc = ssm.SSMCache(sl(conv), sl(st)) \
                if conv is not None else None
            h, kvc, ssc = _block_apply(engine, cfg, bp, h, positions, win,
                                       base, kv_cache=kvc, ssm_cache=ssc,
                                       cache_pos=pos)
            carry = (h,
                     up(kv_k, kvc.k) if kvc else None,
                     up(kv_v, kvc.v) if kvc else None,
                     up(conv, ssc.conv) if ssc else None,
                     up(st, ssc.state) if ssc else None)
            return carry, None

        xs = (params["blocks"], windows, bases,
              jnp.arange(cfg.n_layers, dtype=jnp.int32))
        (h, kv_k, kv_v, conv, st), _ = jax.lax.scan(
            body_c, (h, state.kv_k, state.kv_v, state.conv, state.ssm), xs)
        logits = unembed(engine, cfg, params, h)
        return logits, DecodeState(kv_k, kv_v, conv, st, pos + 1)

    def body(h, xs):
        bp, win, base, kv_k, kv_v, conv, st = xs
        kvc = attn.KVCache(kv_k, kv_v) if kv_k is not None else None
        ssc = ssm.SSMCache(conv, st) if conv is not None else None
        h, kvc, ssc = _block_apply(engine, cfg, bp, h, positions, win, base,
                                   kv_cache=kvc, ssm_cache=ssc,
                                   cache_pos=pos)
        new = (kvc.k if kvc else None, kvc.v if kvc else None,
               ssc.conv if ssc else None, ssc.state if ssc else None)
        return h, new

    xs = (params["blocks"], windows, bases, state.kv_k, state.kv_v,
          state.conv, state.ssm)
    h, caches = jax.lax.scan(body, h, xs)
    kv_k, kv_v, conv, st = caches
    logits = unembed(engine, cfg, params, h)
    return logits, DecodeState(kv_k, kv_v, conv, st, pos + 1)


# ---------------------------------------------------------------------------
# paged decode (the continuous-batching serving engine's substrate)
# ---------------------------------------------------------------------------
class PagedDecodeState(NamedTuple):
    """Decode-slot state over *paged* KV pools.

    Unlike :class:`DecodeState` (one contiguous (B, S) cache, one shared
    scalar position), slots here are independent requests at independent
    positions: per-layer page pools shared by every slot, per-slot block
    tables mapping logical positions to pool pages, and per-slot lengths.
    The last pool page (id NP) is the reserved trash page retired slots
    spill to; the allocator only ever hands out ids [0, NP).
    """

    kv_k: Optional[jnp.ndarray]       # (L, KVH, NP + 1, page, D) or None
    kv_v: Optional[jnp.ndarray]
    conv: Optional[jnp.ndarray]       # (L, slots, K-1, conv_dim) or None
    ssm: Optional[jnp.ndarray]        # (L, slots, H, N, P) or None
    tables: jnp.ndarray               # (slots, MP) int32 page ids
    lengths: jnp.ndarray              # (slots,) int32 cached tokens per slot


def init_paged_state(cfg: ModelConfig, slots: int, n_pages: int,
                     page_size: int, max_pages: int,
                     dtype=jnp.bfloat16) -> PagedDecodeState:
    kv_k = kv_v = conv = st = None
    if cfg.has_attn:
        shape = (cfg.n_layers, cfg.n_kv_heads, n_pages + 1, page_size,
                 cfg.head_dim)
        kv_k = jnp.zeros(shape, dtype)
        kv_v = jnp.zeros(shape, dtype)
    if cfg.has_ssm:
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.d_state
        conv = jnp.zeros((cfg.n_layers, slots, cfg.d_conv - 1, conv_dim),
                         dtype)
        st = jnp.zeros((cfg.n_layers, slots, cfg.n_ssm_heads, cfg.d_state,
                        cfg.ssm_head_dim), jnp.float32)
    return PagedDecodeState(kv_k, kv_v, conv, st,
                            jnp.zeros((slots, max_pages), jnp.int32),
                            jnp.zeros((slots,), jnp.int32))


def paged_prefill(engine: GemminiInstance, params: Params, cfg: ModelConfig,
                  tokens: jnp.ndarray, state: PagedDecodeState,
                  slot: jnp.ndarray, pages: jnp.ndarray, *,
                  page_size: int, with_logits: bool = True
                  ) -> Tuple[Optional[jnp.ndarray], PagedDecodeState]:
    """Prefill ONE fresh request into the paged pools.

    ``with_logits=False`` skips the unembed projection (used when this is
    the FIRST chunk of a multi-chunk prefill: nothing samples until the
    last chunk).

    tokens: (1, P) [or (1, P, n_q)], P bucket-padded by the engine; slot:
    scalar int32 decode slot; pages: (MP,) int32 pages allocated for the
    request (entries past ceil(T'/page) unused, T' = P + meta tokens).
    Returns (logits (1, T', V), state with the pools and the slot's SSM
    caches written). The caller owns the host-side table/length update
    (``lengths[slot] = true_len + meta``, ``tables[slot] = pages``) --
    bucket-padding positions land in the allocated pages but stay dead
    under the length mask, and the first decode token overwrites the first
    of them. SSM slot caches start from zeros (a fresh request must not
    inherit a retired tenant's recurrent state).
    """
    h = embed_inputs(cfg, params, tokens)
    b, t, _ = h.shape                                  # b == 1
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    win_np = layer_windows(cfg, t)
    windows = jnp.asarray(win_np)
    static_win = uniform_window(win_np)
    bases = jnp.asarray(layer_rope_bases(cfg))
    zero_len = jnp.zeros((1,), jnp.int32)

    def body(h, xs):
        bp, win, base, kv_k, kv_v, conv, st = xs
        kvc = None
        if kv_k is not None:
            kvc = attn.PagedKVCache(kv_k, kv_v, pages[None], zero_len,
                                    page_size)
        ssc = None
        if conv is not None:
            # Fresh request: conv state zeroed, recurrent state spelled
            # None (fresh-prefill marker -- a retired tenant's state must
            # not leak in, and the SSD kernel path starts from zeros).
            c1 = jnp.zeros_like(jax.lax.dynamic_slice_in_dim(conv, slot, 1, 0))
            ssc = ssm.SSMCache(c1, None)
        h, kvc, ssc = _block_apply(engine, cfg, bp, h, positions, win, base,
                                   kv_cache=kvc, ssm_cache=ssc,
                                   window_static=static_win)
        new = (kvc.k if kvc else None, kvc.v if kvc else None,
               jax.lax.dynamic_update_slice_in_dim(
                   conv, ssc.conv.astype(conv.dtype), slot, 0)
               if ssc else None,
               jax.lax.dynamic_update_slice_in_dim(
                   st, ssc.state.astype(st.dtype), slot, 0)
               if ssc else None)
        return h, new

    xs = (params["blocks"], windows, bases, state.kv_k, state.kv_v,
          state.conv, state.ssm)
    h, caches = jax.lax.scan(body, h, xs)
    kv_k, kv_v, conv, st = caches
    logits = unembed(engine, cfg, params, h) if with_logits else None
    return logits, state._replace(kv_k=kv_k, kv_v=kv_v, conv=conv, ssm=st)


def paged_prefill_chunk(engine: GemminiInstance, params: Params,
                        cfg: ModelConfig, tokens: jnp.ndarray,
                        state: PagedDecodeState, slot: jnp.ndarray,
                        pages: jnp.ndarray, start: jnp.ndarray, *,
                        page_size: int, with_logits: bool = True,
                        kv_pages: Optional[int] = None
                        ) -> Tuple[Optional[jnp.ndarray], PagedDecodeState]:
    """Prefill a CONTINUATION chunk of a partially-prefilled request.

    tokens: (1, Tc) [or (1, Tc, n_q)] prompt tokens landing at cache
    positions [start, start + Tc); start: *traced* scalar int32, so one
    compile bucket serves every chunk offset of a given chunk length (the
    first chunk -- which prepends meta tokens and attends only itself --
    goes through :func:`paged_prefill`); pages: (MP,) int32, the slot's
    full block table so far (the chunk's own pages included).

    Differences from the fresh-prefill path, all chunk-resume semantics:
    positions and rope run at [start, start+Tc); attention scatters at the
    offset and then attends cache pages + the fresh chunk via the
    block-table gather (``ops.paged_prefill_attention``); and the slot's
    SSM conv/recurrent state is RESUMED, not zeroed -- the recurrent
    families' exact-length, no-padding discipline extends to chunks (every
    chunk is exact, only the last may be bucket-padded by the engine for
    attention-only families). The caller owns table/length updates exactly
    as for :func:`paged_prefill`.

    ``with_logits=False`` skips the unembed projection and returns
    ``(None, state)`` -- only the LAST chunk's logits are ever sampled, so
    intermediate chunks need not pay the vocab GEMM (one compile bucket
    per (chunk length, with_logits) pair).

    ``kv_pages``: STATIC bound on live block-table entries, derived by the
    engine from the request's admission-time (padded) prompt footprint --
    the gather attention then contracts ``kv_pages * page`` keys instead
    of the full table capacity (one compile bucket per (chunk length,
    kv_pages) pair; ``None`` keeps the capacity-wide gather).
    """
    h = embed_inputs(cfg, params, tokens, with_meta=False)
    b, t, _ = h.shape                                  # b == 1
    positions = jnp.broadcast_to(start + jnp.arange(t)[None], (b, t))
    win_np = layer_windows(cfg, t)
    windows = jnp.asarray(win_np)
    static_win = uniform_window(win_np)
    bases = jnp.asarray(layer_rope_bases(cfg))
    zero_len = jnp.zeros((1,), jnp.int32)

    def body(h, xs):
        bp, win, base, kv_k, kv_v, conv, st = xs
        kvc = None
        if kv_k is not None:
            kvc = attn.PagedKVCache(kv_k, kv_v, pages[None], zero_len,
                                    page_size)
        ssc = None
        if conv is not None:
            c1 = jax.lax.dynamic_slice_in_dim(conv, slot, 1, 0)
            s1 = jax.lax.dynamic_slice_in_dim(st, slot, 1, 0)
            ssc = ssm.SSMCache(c1, s1)
        h, kvc, ssc = _block_apply(engine, cfg, bp, h, positions, win, base,
                                   kv_cache=kvc, ssm_cache=ssc,
                                   window_static=static_win,
                                   prefill_start=start, kv_pages=kv_pages)
        new = (kvc.k if kvc else None, kvc.v if kvc else None,
               jax.lax.dynamic_update_slice_in_dim(
                   conv, ssc.conv.astype(conv.dtype), slot, 0)
               if ssc else None,
               jax.lax.dynamic_update_slice_in_dim(
                   st, ssc.state.astype(st.dtype), slot, 0)
               if ssc else None)
        return h, new

    xs = (params["blocks"], windows, bases, state.kv_k, state.kv_v,
          state.conv, state.ssm)
    h, caches = jax.lax.scan(body, h, xs)
    kv_k, kv_v, conv, st = caches
    logits = unembed(engine, cfg, params, h) if with_logits else None
    return logits, state._replace(kv_k=kv_k, kv_v=kv_v, conv=conv, ssm=st)


def paged_decode_step(engine: GemminiInstance, params: Params,
                      cfg: ModelConfig, tokens: jnp.ndarray,
                      state: PagedDecodeState, active: jnp.ndarray, *,
                      page_size: int
                      ) -> Tuple[jnp.ndarray, PagedDecodeState]:
    """One continuous-batching decode step: every slot advances one token.

    tokens: (slots, 1) [or (slots, 1, n_q)]; active: (slots,) bool -- slots
    that are empty or whose request finished/preempted decode padding
    (static shapes) but write to the trash page and keep frozen lengths,
    so they can never touch pages owned by live requests. Each slot ropes
    and attends at its OWN position (``lengths[slot]``) -- the per-request
    raggedness the static-batch ``decode_step`` cannot express.

    Inactive slots' conv/SSM state is frozen too (the recurrent-state
    analog of the trash page): a slot mid-way through a *chunked* prefill
    sits in the decode batch as padding, and letting the padding token
    advance its recurrent state would corrupt the state the next chunk
    resumes from.
    """
    if cfg.n_codebooks > 1:
        h = sum(layers.embed_apply(params["embed"][i], tokens[..., i])
                for i in range(cfg.n_codebooks))
    else:
        h = layers.embed_apply(params["embed"], tokens,
                               scale_by_sqrt_dim=cfg.embed_scale)
    positions = state.lengths[:, None]                 # (slots, 1)
    win_np = layer_windows(cfg, 0)
    windows = jnp.asarray(win_np)
    static_win = uniform_window(win_np)
    bases = jnp.asarray(layer_rope_bases(cfg))
    trash = state.kv_k.shape[2] - 1 if state.kv_k is not None else 0

    def body(h, xs):
        bp, win, base, kv_k, kv_v, conv, st = xs
        kvc = None
        if kv_k is not None:
            kvc = attn.PagedKVCache(kv_k, kv_v, state.tables, state.lengths,
                                    page_size, active, trash)
        ssc = ssm.SSMCache(conv, st) if conv is not None else None
        h, kvc, ssc = _block_apply(engine, cfg, bp, h, positions, win, base,
                                   kv_cache=kvc, ssm_cache=ssc,
                                   window_static=static_win)
        new = (kvc.k if kvc else None, kvc.v if kvc else None,
               jnp.where(active[:, None, None],
                         ssc.conv.astype(conv.dtype), conv)
               if ssc else None,
               jnp.where(active[:, None, None, None],
                         ssc.state.astype(st.dtype), st)
               if ssc else None)
        return h, new

    xs = (params["blocks"], windows, bases, state.kv_k, state.kv_v,
          state.conv, state.ssm)
    h, caches = jax.lax.scan(body, h, xs)
    kv_k, kv_v, conv, st = caches
    logits = unembed(engine, cfg, params, h)
    lengths = jnp.where(active, state.lengths + 1, state.lengths)
    return logits, state._replace(kv_k=kv_k, kv_v=kv_v, conv=conv, ssm=st,
                                  lengths=lengths)
