"""Mixture-of-Experts FFN: top-k routing with capacity-bounded dispatch.

Covers granite-moe (40 experts, top-8, tiny d_ff=512) and llama4-scout
(16 experts, top-1, + shared expert). Design notes:

* **Dispatch** is scatter/gather based (deterministic, SPMD-friendly): each
  token's top-k choices compute a position-in-expert via a per-expert cumsum
  over the (batch-local) token axis; tokens beyond the expert capacity are
  dropped (standard GShard/Switch semantics, capacity_factor configurable).
  This avoids materializing a (tokens, E, capacity) one-hot and keeps
  HLO FLOPs equal to *active* FLOPs, so the roofline "useful compute" ratio
  stays honest.

* **Expert parallelism**: expert weights are stacked on a leading E axis and
  sharded over the ``model`` mesh axis. When E is not divisible by the EP
  degree (granite: 40 experts on 16-way model axis), experts are padded to
  the next multiple (48) and the router logits of padded experts are masked
  to -inf -- uneven GSPMD shardings are rejected by JAX, and padding is the
  production-standard workaround (documented in DESIGN.md).

* The expert FFNs themselves are batched GEMMs, i.e. they run on the
  Gemmini engine schedule -- the paper's technique applied at the MoE layer.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.generator import GemminiInstance
from repro.models import layers

Params = Dict[str, Any]


def pad_experts(n_experts: int, ep: int) -> int:
    """Number of expert slots after padding to the EP degree."""
    return ((n_experts + ep - 1) // ep) * ep


def _dispatch_grid(b: int, t: int):
    """(groups, (gb, gt) or None) for the grouped-dispatch perf flag.

    gb x gt mirrors the mesh's (data-parallel x model) shard grid so every
    group is device-local. Returns (1, None) when the flag is off, the mesh
    is unavailable, or shapes do not divide.
    """
    import jax.sharding as js
    from repro.core import flags
    if not flags.get("moe_grouped_dispatch"):
        return 1, None
    try:
        mesh = js.get_abstract_mesh()
    except Exception:               # noqa
        return 1, None
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return 1, None
    gb = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            gb *= mesh.shape[ax]
    gt = mesh.shape["model"]
    if gb < 1 or gt < 1 or b % gb or t % gt:
        return 1, None
    return gb * gt, (gb, gt)


def moe_init(key, d: int, d_ff: int, n_experts: int, *, ep: int = 1,
             n_shared: int = 0, d_ff_shared: Optional[int] = None,
             dtype=jnp.bfloat16) -> Params:
    e_pad = pad_experts(n_experts, ep)
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": layers.dense_init(ks[0], d, e_pad, dtype=jnp.float32),
        # stacked gated-FFN expert weights: (E, d, ff) / (E, ff, d)
        "wi": (jax.random.normal(ks[1], (e_pad, d, d_ff), jnp.float32)
               * scale).astype(dtype),
        "wg": (jax.random.normal(ks[2], (e_pad, d, d_ff), jnp.float32)
               * scale).astype(dtype),
        "wo": (jax.random.normal(ks[3], (e_pad, d_ff, d), jnp.float32)
               / jnp.sqrt(d_ff)).astype(dtype),
    }
    if n_shared:
        p["shared"] = layers.mlp_init(ks[4], d, (d_ff_shared or d_ff) * n_shared,
                                      dtype=dtype)
    return p


def moe_apply(engine: GemminiInstance, p: Params, x: jnp.ndarray, *,
              n_experts: int, top_k: int, capacity_factor: float = 1.25,
              activation: str = "silu",
              router_weights_before: bool = False,
              dropless: bool = False) -> jnp.ndarray:
    """x: (B, T, D) -> (B, T, D).

    router_weights_before: llama4 multiplies the (sigmoid) router weight into
    the expert *input*; granite/mixtral scale the expert *output* by the
    softmax weight.
    dropless: capacity = n_tokens (no token ever dropped). Used on serving
    paths, where a dropped token would corrupt a user-visible sequence;
    training keeps the capacity-bounded GShard semantics.
    """
    from repro.core import flags
    b, t, d = x.shape
    e_pad = p["wi"].shape[0]
    n_tok = b * t
    xf = x.reshape(n_tok, d)

    # ---- grouping (perf flag B) -------------------------------------------
    # groups = 1 is the baseline: one GLOBAL dispatch, whose cumsum /
    # scatter-add over the full token axis the partitioner lowers to
    # full-buffer all-reduces (measured 1.46 TB/device on granite train).
    # groups = DP degree keeps every dispatch step local to a data shard;
    # the only cross-shard movement left is the (G, E, cap, d) buffer
    # resharding group-axis -> expert-axis, an all-to-all.
    groups, grid = _dispatch_grid(b, t)
    ntl = n_tok // groups
    if grid is not None:
        # group along the existing (batch-shards x sequence-shards) grid:
        # x is stored (B over data, T over model), so this reshape/transpose
        # is a pure LOCAL relabeling -- every group lives wholly on one
        # device and the cumsum/scatter below never cross shards. (A
        # with_sharding_constraint re-shard was tried first and made things
        # 3x worse: SPMD implemented it as replicate-then-slice.)
        gb, gt = grid
        xg = x.reshape(gb, b // gb, gt, t // gt, d)
        xg = xg.transpose(0, 2, 1, 3, 4).reshape(groups, ntl, d)
    else:
        xg = xf.reshape(groups, ntl, d)

    # ---- routing ---------------------------------------------------------
    logits = layers.project(engine, xg.astype(jnp.float32), p["router"])
    if e_pad != n_experts:  # mask padded expert slots
        pad_mask = jnp.arange(e_pad) >= n_experts
        logits = jnp.where(pad_mask[None, None, :], -jnp.inf, logits)
    gate_w, gate_idx = jax.lax.top_k(logits, top_k)          # (G, ntl, k)
    if top_k == 1:
        weights = jax.nn.sigmoid(gate_w)                     # llama4 style
    else:
        weights = jax.nn.softmax(gate_w, axis=-1)
    weights = weights.astype(x.dtype)

    # ---- capacity + position-in-expert (all per-group-local) --------------
    capacity = ntl if dropless else \
        max(1, int(capacity_factor * ntl * top_k / n_experts))
    flat_idx = gate_idx.reshape(groups, ntl * top_k)
    onehot = jax.nn.one_hot(flat_idx, e_pad, dtype=jnp.int32)  # (G,N*k,E)
    pos_in_expert = (jnp.cumsum(onehot, axis=1) - 1) * onehot
    pos = jnp.sum(pos_in_expert, axis=-1)                    # (G, N*k)
    keep = pos < capacity
    slot = jnp.where(keep, flat_idx * capacity + pos, e_pad * capacity)

    # ---- dispatch: scatter tokens into (G, E*capacity, D) ------------------
    tok_ids = jnp.repeat(jnp.arange(ntl), top_k)             # (ntl*k,)
    xin = jnp.take(xg, tok_ids, axis=1)                      # (G, ntl*k, d)
    if router_weights_before:
        xin = xin * weights.reshape(groups, -1)[..., None].astype(x.dtype)
    # sharding pins for the grouped path: every dispatch-side tensor stays
    # group-local (G over the whole mesh) and every expert-side tensor
    # stays expert-sharded (E over model). with_sharding_constraint
    # transposes to itself, so these pin the BACKWARD cotangents too --
    # without them the partitioner replicates the scatter/gather grads
    # (measured 1.6 TB/device of all-gather in granite's backward).
    if grid is not None:
        import jax.sharding as js
        _mesh = js.get_abstract_mesh()
        _gaxes = tuple(a for a in ("pod", "data", "model")
                       if a in _mesh.axis_names)

        def pin_g(v):       # group-local layout
            return jax.lax.with_sharding_constraint(
                v, js.PartitionSpec(_gaxes, *([None] * (v.ndim - 1))))
    else:
        pin_g = lambda v: v

    buf = pin_g(jnp.zeros((groups, e_pad * capacity + 1, d), x.dtype))
    buf = jax.vmap(lambda bb, ss, xx: bb.at[ss].add(xx, mode="drop"))(
        buf, slot, pin_g(xin.astype(x.dtype)))
    expert_in = buf[:, :-1].reshape(groups, e_pad, capacity, d)
    expert_in = jnp.swapaxes(expert_in, 0, 1)                # (E,G,cap,d)
    expert_in = expert_in.reshape(e_pad, groups * capacity, d)

    # ---- expert FFNs: batched GEMMs on the engine schedule -----------------
    act = {"silu": jax.nn.silu,
           "gelu": lambda v: jax.nn.gelu(v, approximate=True)}[activation]
    h = jnp.einsum("ecd,edf->ecf", expert_in, p["wi"],
                   preferred_element_type=jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", expert_in, p["wg"],
                   preferred_element_type=jnp.float32)
    h = (act(g) * h).astype(x.dtype)
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)

    # ---- combine: gather back + weighted sum -------------------------------
    out = out.reshape(e_pad, groups, capacity, d)
    out = jnp.swapaxes(out, 0, 1).reshape(groups, e_pad * capacity, d)
    safe = jnp.minimum(slot, e_pad * capacity - 1)
    gathered = jax.vmap(lambda oo, ss: oo[ss])(out, safe)    # (G, ntl*k, d)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    if not router_weights_before:
        gathered = gathered * weights.reshape(groups, -1)[..., None]
    y = jax.vmap(lambda acc, tid, g: acc.at[tid].add(g))(
        pin_g(jnp.zeros((groups, ntl, d), x.dtype)),
        jnp.broadcast_to(tok_ids, (groups, ntl * top_k)),
        gathered.astype(x.dtype))
    if grid is not None:            # invert the shard-grid relabeling
        gb, gt = grid
        y = y.reshape(gb, gt, b // gb, t // gt, d)
        y = y.transpose(0, 2, 1, 3, 4).reshape(b, t, d)
    else:
        y = y.reshape(b, t, d)

    # ---- shared expert (llama4) --------------------------------------------
    if "shared" in p:
        y = y + layers.mlp_apply(engine, p["shared"], x,
                                 activation=activation)
    return y


def aux_load_balance_loss(logits: jnp.ndarray, gate_idx: jnp.ndarray,
                          n_experts: int, top_k: int) -> jnp.ndarray:
    """Switch-style auxiliary loss: E * sum(f_e * p_e)."""
    probs = jax.nn.softmax(logits, axis=-1)[..., :n_experts]
    counts = jnp.zeros((n_experts,), jnp.float32).at[
        gate_idx.reshape(-1)].add(1.0)
    f = counts / counts.sum()
    pm = probs.mean(axis=0)
    return n_experts * jnp.sum(f * pm)
