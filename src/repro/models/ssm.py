"""Mamba-2 SSD (state-space duality) mixer + Hymba building blocks.

The chunked SSD algorithm decomposes the linear recurrence into
*intra-chunk GEMMs* (which run on the Gemmini engine schedule -- the paper's
technique applies to them) plus a short *inter-chunk scan* (attention-free,
outside the technique's domain; see DESIGN.md section 5). The XLA
implementation here is also the oracle structure for kernels/mamba2.py.

Shapes follow the Mamba-2 paper: heads H with head-dim P, state size N,
``G`` B/C groups (grouped like GQA).
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.generator import GemminiInstance
from repro.models import layers

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# chunked SSD (train / prefill)
# ---------------------------------------------------------------------------
def ssd_chunked_xla(x, dt, a_log, b, c, *, d_skip=None, chunk: int = 256,
                    initial_state=None):
    """x:(B,T,H,P) dt:(B,T,H) a_log:(H,) b,c:(B,T,G,N) -> y:(B,T,H,P).

    Returns the same result as kernels.ref.ssd_ref (naive recurrence).

    ``initial_state``: (B, H, N, P) f32 recurrent state carried in from a
    previous segment (chunked prefill resumes here); ``None`` starts from
    zeros -- bit-identical to passing explicit zeros, since the carried
    state only enters through the inter-chunk scan's h0.
    """
    bsz, t, h, p = x.shape
    _, _, g, n = b.shape
    hpg = h // g
    q = min(chunk, t)
    pad = (-t) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tt = t + pad
    nc = tt // q

    a = -jnp.exp(a_log.astype(jnp.float32))                      # (H,)
    xf = x.astype(jnp.float32).reshape(bsz, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, q, h)
    bf = b.astype(jnp.float32).reshape(bsz, nc, q, g, n)
    cf = c.astype(jnp.float32).reshape(bsz, nc, q, g, n)
    bf = jnp.repeat(bf, hpg, axis=3)                             # (B,nc,Q,H,N)
    cf = jnp.repeat(cf, hpg, axis=3)

    dta = dtf * a[None, None, None, :]                           # (B,nc,Q,H)
    seg = jnp.cumsum(dta, axis=2)                                # inclusive
    # intra-chunk decay matrix L[i,j] = exp(seg_i - seg_j), i >= j.
    # Double-where: mask BEFORE exp so the i<j branch (positive exponent,
    # overflows) never produces inf -- inf*0 in the backward pass is NaN.
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]           # (B,nc,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    ldec = jnp.where(tri, jnp.exp(jnp.where(tri, li, 0.0)), 0.0)

    # scores_ij = C_i . B_j  (per head) -- a GEMM per chunk
    scores = jnp.einsum("bcihn,bcjhn->bcijh", cf, bf)
    y_diag = jnp.einsum("bcijh,bcjh,bcjhp->bcihp",
                        scores * ldec, dtf, xf)

    # chunk-final states and decays
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)              # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjh,bcjh,bcjhn,bcjhp->bchnp",
                         decay_to_end, dtf, bf, xf)              # (B,nc,H,N,P)
    chunk_decay = jnp.exp(seg[:, :, -1, :])                      # (B,nc,H)

    def scan_fn(h_prev, inp):
        s_c, dec_c, c_c, seg_c = inp
        # contribution of carried-in state to every step of this chunk
        y_off = jnp.einsum("bihn,bhnp,bih->bihp",
                           c_c, h_prev, jnp.exp(seg_c))
        h_next = h_prev * dec_c[:, :, None, None] + s_c
        return h_next, y_off

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32) \
        if initial_state is None else initial_state.astype(jnp.float32)
    inp = (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0),
           jnp.moveaxis(cf, 1, 0), jnp.moveaxis(seg, 1, 0))
    _, y_off = jax.lax.scan(scan_fn, h0, inp)
    y = y_diag + jnp.moveaxis(y_off, 0, 1)                       # (B,nc,Q,H,P)
    y = y.reshape(bsz, tt, h, p)[:, :t]
    if d_skip is not None:
        y = y + d_skip[None, None, :, None] * \
            x.reshape(bsz, tt, h, p)[:, :t].astype(jnp.float32)
    return y.astype(x.dtype)


def ssd_decode_step(state, x_t, dt_t, a_log, b_t, c_t, *, d_skip=None):
    """One-token recurrence. state:(B,H,N,P) x_t:(B,H,P) dt_t:(B,H)
    b_t,c_t:(B,G,N). Returns (y_t, new_state)."""
    bsz, h, n, p = state.shape
    g = b_t.shape[1]
    hpg = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    bh = jnp.repeat(b_t.astype(jnp.float32), hpg, axis=1)        # (B,H,N)
    ch = jnp.repeat(c_t.astype(jnp.float32), hpg, axis=1)
    da = jnp.exp(dt_t.astype(jnp.float32) * a[None, :])          # (B,H)
    state = state * da[..., None, None] + \
        jnp.einsum("bh,bhn,bhp->bhnp", dt_t.astype(jnp.float32), bh,
                   x_t.astype(jnp.float32))
    y = jnp.einsum("bhnp,bhn->bhp", state, ch)
    if d_skip is not None:
        y = y + d_skip[None, :, None] * x_t.astype(jnp.float32)
    return y.astype(x_t.dtype), state


# ---------------------------------------------------------------------------
# full Mamba-2 mixer (in_proj -> conv -> SSD -> gate -> out_proj)
# ---------------------------------------------------------------------------
class SSMCache(NamedTuple):
    conv: jnp.ndarray              # (B, K-1, conv_dim)
    # (B, H, N, P) carried recurrent state, or None for a FRESH prefill
    # (semantically zeros; the None spelling lets dispatch route fresh
    # prefills to the SSD kernel, whose VMEM scan starts from zeros,
    # while a resumed chunk's array state demotes to the xla reference).
    state: Optional[jnp.ndarray]


def mamba2_init(key, d_model: int, *, d_inner: int, n_heads: int,
                d_state: int, n_groups: int = 1, d_conv: int = 4,
                dtype=jnp.bfloat16) -> Params:
    p_dim = d_inner // n_heads
    conv_dim = d_inner + 2 * n_groups * d_state
    ks = jax.random.split(key, 6)
    in_dim = 2 * d_inner + 2 * n_groups * d_state + n_heads  # z,x,B,C,dt
    return {
        "in_proj": layers.dense_init(ks[0], d_model, in_dim, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, conv_dim), jnp.float32)
                   / math.sqrt(d_conv)).astype(dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": layers.rmsnorm_init(d_inner),
        "out_proj": layers.dense_init(ks[2], d_inner, d_model, dtype=dtype),
    }


def _split_in_proj(zxbcdt, d_inner, n_groups, d_state, n_heads):
    splits = [d_inner, 2 * d_inner, 2 * d_inner + n_groups * d_state,
              2 * d_inner + 2 * n_groups * d_state]
    z = zxbcdt[..., :splits[0]]
    x = zxbcdt[..., splits[0]:splits[1]]
    b = zxbcdt[..., splits[1]:splits[2]]
    c = zxbcdt[..., splits[2]:splits[3]]
    dt = zxbcdt[..., splits[3]:]
    return z, x, b, c, dt


def mamba2_apply(engine: GemminiInstance, p: Params, u: jnp.ndarray, *,
                 d_inner: int, n_heads: int, d_state: int, n_groups: int = 1,
                 chunk: int = 256, cache: Optional[SSMCache] = None,
                 ) -> Tuple[jnp.ndarray, Optional[SSMCache]]:
    """u: (B, T, d_model) -> (y, new_cache). T==1 with cache => decode."""
    bsz, t, _ = u.shape
    p_dim = d_inner // n_heads
    zxbcdt = layers.project(engine, u, p["in_proj"])
    z, xin, b, c, dt = _split_in_proj(zxbcdt, d_inner, n_groups, d_state,
                                      n_heads)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])            # (B,T,H)

    xbc = jnp.concatenate([xin, b, c], axis=-1)
    conv_state = cache.conv if cache is not None else None
    xbc, new_conv = layers.causal_conv1d(xbc, p["conv_w"], conv_state)
    xbc = jax.nn.silu(xbc)
    xin = xbc[..., :d_inner]
    b = xbc[..., d_inner:d_inner + n_groups * d_state]
    c = xbc[..., d_inner + n_groups * d_state:]

    xh = xin.reshape(bsz, t, n_heads, p_dim)
    bh = b.reshape(bsz, t, n_groups, d_state)
    ch = c.reshape(bsz, t, n_groups, d_state)

    if cache is not None and t == 1:
        st0 = cache.state
        if st0 is None:                      # 1-token fresh prefill
            st0 = jnp.zeros((bsz, n_heads, d_state, p_dim), jnp.float32)
        y, new_state = ssd_decode_step(
            st0, xh[:, 0], dt[:, 0], p["a_log"], bh[:, 0], ch[:, 0],
            d_skip=p["d_skip"])
        y = y[:, None]                                           # (B,1,H,P)
        new_cache = SSMCache(new_conv, new_state)
    elif cache is not None:
        # Prefill (inference): route through the context so pallas/
        # interpret engines run the chunked SSD kernel with its FUSED
        # epilogue -- d_skip add and the prefill->decode handoff state
        # both emitted in-kernel (no XLA recompute pass). A continuation
        # chunk (cache.state carried in as an array) demotes to the xla
        # reference inside ssd_impl (the kernel's VMEM scan starts from
        # zeros); a fresh prefill spells its zero state as None.
        from repro.core import context
        y, final_state = context.as_context(engine).ssd(
            xh, dt, p["a_log"], bh, ch, d_skip=p["d_skip"], chunk=chunk,
            initial_state=cache.state, return_final_state=True)
        new_cache = SSMCache(new_conv, final_state)
    else:
        # Train/forward route: the SSD kernel has no VJP, so this stays on
        # the differentiable XLA reference on every backend (the same rule
        # transformer.forward applies to attention).
        y = ssd_chunked_xla(xh, dt, p["a_log"], bh, ch,
                            d_skip=p["d_skip"], chunk=chunk)
        new_cache = None

    y = y.reshape(bsz, t, d_inner)
    y = layers.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                       p["norm"])
    return layers.project(engine, y, p["out_proj"]), new_cache


def _final_state(x, dt, a_log, b, c, initial_state=None):
    """Final SSM state after a sequence (for prefill->decode handoff).

    ``initial_state``: state carried in from a previous segment; it decays
    by the whole segment (``exp(seg[-1])``) and adds to the segment's own
    contribution. ``None`` keeps the fresh-prefill result bit-identical
    (the decayed-zeros term is an exact no-op)."""
    bsz, t, h, p = x.shape
    g = b.shape[2]
    hpg = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    dta = dt.astype(jnp.float32) * a[None, None, :]              # (B,T,H)
    seg = jnp.cumsum(dta, axis=1)
    decay_to_end = jnp.exp(seg[:, -1:, :] - seg)                 # (B,T,H)
    bh = jnp.repeat(b.astype(jnp.float32), hpg, axis=2)
    state = jnp.einsum("bth,bth,bthn,bthp->bhnp",
                       decay_to_end, dt.astype(jnp.float32), bh,
                       x.astype(jnp.float32))
    if initial_state is not None:
        state = state + initial_state.astype(jnp.float32) * \
            jnp.exp(seg[:, -1, :])[:, :, None, None]
    return None, state
