"""GQA attention: blockwise (flash-style) XLA path + KV-cache decode.

Features required by the assigned architectures:
  * grouped-query attention (any H/KVH ratio, incl. MQA kv=1 for gemma3-1b)
  * sliding-window "local" layers interleaved with "global" layers
    (gemma2 1:1, gemma3 5:1)
  * logit soft-capping (gemma2)
  * QKV bias (qwen1.5)
  * decode against a (possibly sequence-sharded) KV cache; local layers
    only attend within the window.

The train/prefill path is blockwise with an online-softmax running state so
the 32k-prefill dry-run never materializes an S x S score matrix; this same
schedule is what kernels/attention.py implements in Pallas for TPU.
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.generator import GemminiInstance
from repro.models import layers

Params = Dict[str, Any]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attn_init(key, d: int, n_heads: int, n_kv: int, head_dim: int, *,
              qkv_bias: bool = False, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], d, n_heads * head_dim, dtype=dtype),
        "wk": layers.dense_init(ks[1], d, n_kv * head_dim, dtype=dtype),
        "wv": layers.dense_init(ks[2], d, n_kv * head_dim, dtype=dtype),
        "wo": layers.dense_init(ks[3], n_heads * head_dim, d, dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _qkv(engine, p, x, n_heads, n_kv, head_dim):
    b, t, _ = x.shape
    q = layers.project(engine, x, p["wq"], p.get("bq"))
    k = layers.project(engine, x, p["wk"], p.get("bk"))
    v = layers.project(engine, x, p["wv"], p.get("bv"))
    return (q.reshape(b, t, n_heads, head_dim),
            k.reshape(b, t, n_kv, head_dim),
            v.reshape(b, t, n_kv, head_dim))


# ---------------------------------------------------------------------------
# blockwise attention (train / prefill)
# ---------------------------------------------------------------------------
def blockwise_attention_xla(q, k, v, *, causal: bool = True,
                            window: Optional[int] = None,
                            softcap: Optional[float] = None,
                            scale: Optional[float] = None,
                            block_k: int = 1024,
                            q_offset=None,
                            kv_len=None) -> jnp.ndarray:
    """Online-softmax attention, scanning over KV blocks.

    q: (B, Tq, H, D), k/v: (B, Tk, KVH, D). Memory is O(Tq * block_k).

    ``q_offset``: global position of query row 0 (may be traced). The
    default right-aligns queries against the keys (``Tk - Tq``), which is
    the train/prefill/cache-backed case; chunked prefill passes the chunk's
    start position explicitly. ``kv_len``: number of live keys (may be
    traced); defaults to ``Tk``. Keys at positions >= ``kv_len`` are
    masked, which makes over-allocated gather buffers (paged tables) safe.
    """
    b, tq, h, d = q.shape
    _, tk, kvh, _ = k.shape
    rep = h // kvh
    sc = scale if scale is not None else 1.0 / math.sqrt(d)

    # Clamp the KV block to a 128-multiple of the actual key length:
    # serving-scale contexts (tens to hundreds of keys) would otherwise
    # zero-pad to a full 1024-key block and burn >2x the scores/PV FLOPs
    # on provably-dead keys. Chunked-vs-single-pass bit-exactness is
    # preserved whenever both paths round to the same padded length
    # (equal-length blocks run the identical op sequence; trailing dead
    # keys are exact no-ops under the online-softmax update).
    block_k = min(block_k, -(-max(tk, 1) // 128) * 128)
    nb = -(-tk // block_k)
    pad = nb * block_k - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, block_k, kvh, d)
    vb = v.reshape(b, nb, block_k, kvh, d)

    qf = q.astype(jnp.float32) * sc
    if q_offset is None:
        q_offset = tk - tq                                 # right-aligned
    if kv_len is None:
        kv_len = tk
    qpos = jnp.arange(tq) + q_offset                       # global positions

    def body(carry, inp):
        m, l, acc = carry                                  # (B,H,Tq) ,, (B,H,Tq,D)
        kblk, vblk, bidx = inp                             # (B,block,KVH,D)
        kpos = bidx * block_k + jnp.arange(block_k)
        kh = jnp.repeat(kblk, rep, axis=2)                 # (B,block,H,D)
        vh = jnp.repeat(vblk, rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kh.astype(jnp.float32))
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpos[None, :] <= kv_len - 1                 # in-bounds (padding)
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vh.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, tq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, tq), jnp.float32)
    a0 = jnp.zeros((b, h, tq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)  # (B,Tq,H,D)


# ---------------------------------------------------------------------------
# decode attention against a cache
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S, KVH, D)
    v: jnp.ndarray        # (B, S, KVH, D)


class PagedKVCache(NamedTuple):
    """One layer's paged KV cache: shared page pools + per-slot tables.

    The pools are the serving engine's HBM page arena (one per layer,
    allocated once against the config's HBM budget); ``tables``/``lengths``
    describe every decode slot's view into them. ``page`` rides along as a
    static int so model code never re-derives it from shapes. For decode,
    ``active`` masks live slots and ``trash`` names the reserved spill page
    retired slots write to (see ``paged_update_decode``); prefill ignores
    both.
    """

    k: jnp.ndarray             # (KVH, NP, page, D) page pool
    v: jnp.ndarray             # (KVH, NP, page, D)
    tables: jnp.ndarray        # (B, MP) int32 page ids per slot
    lengths: jnp.ndarray       # (B,) int32 tokens already cached per slot
    page: int                  # static page size (tokens per page)
    active: Optional[jnp.ndarray] = None   # (B,) bool decode-slot liveness
    trash: int = 0                         # reserved spill page id


def decode_attention(q, cache: KVCache, pos, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """One-token attention. q: (B, 1, H, D); pos: scalar current position.

    Works with a sequence-sharded cache: the masked einsum contracts the full
    S axis; XLA inserts the partial-softmax all-reduce.
    """
    from repro.core import flags
    b, tq, h, d = q.shape
    _, s, kvh, _ = cache.k.shape
    rep = h // kvh
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    kpos = jnp.arange(s)
    mask = kpos <= pos
    if window is not None:
        mask = mask & (kpos > pos - window)

    if flags.get("gqa_grouped_decode"):
        # grouped GQA: no repeat -- K/V keep their (B, S, KVH, D) layout,
        # their sequence sharding, AND their bf16 storage dtype end to end
        # (an astype(f32) here makes XLA materialize a full f32 copy of the
        # cache -- measured 12 GB/device/token; instead the dots accumulate
        # in f32 via preferred_element_type, MXU-style). The softmax
        # reduction over the sharded S axis is the only cross-shard
        # communication: an all-reduce of (B, KVH, rep[, D]) scalars.
        qg = (q[:, 0].reshape(b, kvh, rep, d).astype(jnp.float32)
              * sc).astype(cache.k.dtype)
        sl = jnp.einsum("bgrd,bsgd->bgrs", qg, cache.k,
                        preferred_element_type=jnp.float32)
        if softcap is not None:
            sl = softcap * jnp.tanh(sl / softcap)
        sl = jnp.where(mask[None, None, None], sl, _NEG_INF)
        p = jax.nn.softmax(sl, axis=-1)
        out = jnp.einsum("bgrs,bsgd->bgrd", p.astype(cache.v.dtype),
                         cache.v, preferred_element_type=jnp.float32)
        return out.reshape(b, 1, h, d).astype(q.dtype)

    kh = jnp.repeat(cache.k, rep, axis=2)
    vh = jnp.repeat(cache.v, rep, axis=2)
    sl = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * sc,
                    kh.astype(jnp.float32))
    if softcap is not None:
        sl = softcap * jnp.tanh(sl / softcap)
    sl = jnp.where(mask[None, None, None], sl, _NEG_INF)
    p = jax.nn.softmax(sl, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vh.astype(jnp.float32))
    return out.astype(q.dtype)


def update_cache(cache: KVCache, k_new, v_new, pos) -> KVCache:
    """Insert (B, T, KVH, D) at positions [pos, pos+T) of the cache.

    Two lowerings, selected by the ``onehot_cache_update`` flag:

    * dynamic-update-slice (baseline). On a *sequence-sharded* cache the
      SPMD partitioner cannot prove the dynamic write stays within one
      shard, so it all-gathers the whole cache, updates, and re-slices --
      ~2x the cache size in collective bytes PER DECODED TOKEN (measured:
      111.7 GB/device for gemma2-2b @ 500k).
    * one-hot select (optimized): ``where(iota == pos, new, cache)`` is
      elementwise over the sequence axis, so every shard updates locally;
      no collective at all. Costs one full local cache read+write, which
      decode already pays to attend.
    """
    from repro.core import flags
    t = k_new.shape[1]
    if flags.get("onehot_cache_update") and t == 1:
        s = cache.k.shape[1]
        hit = (jax.lax.broadcasted_iota(jnp.int32, (1, s, 1, 1), 1) == pos)
        k = jnp.where(hit, k_new.astype(cache.k.dtype), cache.k)
        v = jnp.where(hit, v_new.astype(cache.v.dtype), cache.v)
        return KVCache(k, v)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, pos, 0, 0))
    return KVCache(k, v)


# ---------------------------------------------------------------------------
# paged KV cache: scatter writes + gather-based decode (the XLA reference
# the Pallas paged kernel must match; kernels/attention.paged_decode_attention
# is the in-kernel-gather TPU lowering)
# ---------------------------------------------------------------------------
def paged_update_decode(cache: PagedKVCache, k_new, v_new,
                        active: jnp.ndarray, trash_page: int) -> PagedKVCache:
    """Write one decode token per slot into its paged position.

    k_new/v_new: (B, 1, KVH, D); slot b's token lands at logical position
    ``lengths[b]`` = pool page ``tables[b, lengths[b]//page]``, offset
    ``lengths[b] % page``. Inactive slots (finished/empty -- ``active``
    False) are redirected to the reserved ``trash_page`` so a retired slot
    can never corrupt pages the allocator has handed to another request,
    and their lengths stay frozen.
    """
    page = cache.page
    mp = cache.tables.shape[1]
    # Clamp before the gather: an inactive slot parked at full capacity
    # would otherwise index column MP (the engine only decodes slots with
    # headroom, but every slot computes its index under the static batch).
    col = jnp.minimum(cache.lengths[:, None] // page, mp - 1)
    pidx = jnp.take_along_axis(cache.tables, col, axis=1)[:, 0]
    pidx = jnp.where(active, pidx, jnp.int32(trash_page))
    off = cache.lengths % page
    kt = jnp.moveaxis(k_new[:, 0], 1, 0).astype(cache.k.dtype)   # (KVH, B, D)
    vt = jnp.moveaxis(v_new[:, 0], 1, 0).astype(cache.v.dtype)
    k = cache.k.at[:, pidx, off].set(kt)
    v = cache.v.at[:, pidx, off].set(vt)
    lengths = jnp.where(active, cache.lengths + 1, cache.lengths)
    return cache._replace(k=k, v=v, lengths=lengths)


def paged_update_prefill(cache: PagedKVCache, k_new, v_new,
                         pages: jnp.ndarray, start=0) -> PagedKVCache:
    """Scatter a prompt (or prompt chunk) KV into the pages allocated for it.

    k_new/v_new: (1, T, KVH, D); ``pages``: (MP,) page ids covering logical
    positions [0, start + T) (entries past ceil((start+T)/page) unused);
    ``start``: logical position of the chunk's first token (0 for a fresh
    whole-prompt prefill; a traced scalar for chunked-prefill continuation
    chunks). Positions past the true prompt length are bucket padding --
    they land in allocated pages but decode's length mask keeps them dead
    forever, and the next decode token overwrites the first of them.
    """
    page = cache.page
    t = k_new.shape[1]
    pos = start + jnp.arange(t)
    pidx = pages[pos // page]
    off = pos % page
    kt = jnp.moveaxis(k_new[0], 1, 0).astype(cache.k.dtype)      # (KVH, T, D)
    vt = jnp.moveaxis(v_new[0], 1, 0).astype(cache.v.dtype)
    return cache._replace(k=cache.k.at[:, pidx, off].set(kt),
                          v=cache.v.at[:, pidx, off].set(vt))


def paged_decode_attention_xla(q, cache: PagedKVCache, *,
                               window: Optional[int] = None,
                               softcap: Optional[float] = None,
                               scale: Optional[float] = None) -> jnp.ndarray:
    """One-token attention over a paged cache, by explicit gather.

    q: (B, 1, H, D); ``cache.lengths`` counts the live tokens *including*
    the current one (write first, then attend). Numerics mirror
    ``decode_attention`` exactly -- same einsums, same staging, same
    mask-then-softmax, including the ``gqa_grouped_decode`` flag branch --
    so a request decoded through the paged path is bit-identical to the
    dense static path under either flag setting (the serve_decode
    example's mismatch gate relies on this).
    """
    from repro.core import flags
    b, tq, h, d = q.shape
    kvh, _, page, _ = cache.k.shape
    rep = h // kvh
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    mp = cache.tables.shape[1]
    s_ctx = mp * page

    # (KVH, B, MP, page, D) -> (B, S_ctx, KVH, D) logical-position order
    def gather(pool):
        g = pool[:, cache.tables]
        return jnp.transpose(g, (1, 2, 3, 0, 4)).reshape(b, s_ctx, kvh, d)

    kpos = jnp.arange(s_ctx)
    pos = (cache.lengths - 1)[:, None]                  # (B, 1)
    mask = kpos[None, :] <= pos
    if window is not None:
        mask = mask & (kpos[None, :] > pos - window)

    if flags.get("gqa_grouped_decode"):
        # The dense path's no-repeat/bf16-storage contraction (see
        # decode_attention): K/V stay at storage dtype, dots accumulate
        # f32 via preferred_element_type.
        kg, vg = gather(cache.k), gather(cache.v)
        qg = (q[:, 0].reshape(b, kvh, rep, d).astype(jnp.float32)
              * sc).astype(kg.dtype)
        sl = jnp.einsum("bgrd,bsgd->bgrs", qg, kg,
                        preferred_element_type=jnp.float32)
        if softcap is not None:
            sl = softcap * jnp.tanh(sl / softcap)
        sl = jnp.where(mask[:, None, None, :], sl, _NEG_INF)
        p = jax.nn.softmax(sl, axis=-1)
        out = jnp.einsum("bgrs,bsgd->bgrd", p.astype(vg.dtype), vg,
                         preferred_element_type=jnp.float32)
        return out.reshape(b, 1, h, d).astype(q.dtype)

    kh = jnp.repeat(gather(cache.k), rep, axis=2)
    vh = jnp.repeat(gather(cache.v), rep, axis=2)
    sl = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * sc,
                    kh.astype(jnp.float32))
    if softcap is not None:
        sl = softcap * jnp.tanh(sl / softcap)
    sl = jnp.where(mask[:, None, None, :], sl, _NEG_INF)
    p = jax.nn.softmax(sl, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vh.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_prefill_attention_xla(q, cache: PagedKVCache, start, *,
                                window: Optional[int] = None,
                                softcap: Optional[float] = None,
                                scale: Optional[float] = None) -> jnp.ndarray:
    """Chunked-prefill attention over a paged cache, by explicit gather.

    q: (1, T, H, D), the fresh chunk's queries at logical positions
    [start, start + T); the chunk's own KV must already be scattered into
    the pool (write first, then attend -- same discipline as decode).
    ``start`` may be traced (one jit bucket serves every chunk offset).

    Numerics mirror the single-pass prefill: the gathered pages are fed to
    :func:`blockwise_attention_xla` with the same KV blocking anchored at
    position 0, so every overlapping (qpos, kpos) pair runs the identical
    online-softmax op sequence whenever both paths round to the same
    padded KV width -- here Tk = table capacity (``MP * page``), in the
    single-pass path Tk = the prompt bucket, and both clamp to a
    128-multiple, so the widths coincide exactly when both round to the
    same multiple (always at <=128-token context, the exact-match gate's
    geometry; at larger geometries with short prompts the two paths can
    pad to different widths and agree only up to float-reassociation
    noise). Trailing gathered pages past the chunk frontier are dead under
    the causal mask, exactly like the reference's pad_k region.
    """
    b, tq, h, d = q.shape
    kvh, _, page, _ = cache.k.shape
    mp = cache.tables.shape[1]
    s_ctx = mp * page

    def gather(pool):
        g = pool[:, cache.tables]
        return jnp.transpose(g, (1, 2, 3, 0, 4)).reshape(b, s_ctx, kvh, d)

    return blockwise_attention_xla(
        q, gather(cache.k), gather(cache.v), causal=True, window=window,
        softcap=softcap, scale=scale, q_offset=start, kv_len=start + tq)


# ---------------------------------------------------------------------------
# routed attention op (the tuned-schedule entry)
# ---------------------------------------------------------------------------
def _route_window(engine, window):
    """Shared routing policy for the op-layer attention entries: returns
    (window, ctx). ``engine`` may be a :class:`GemminiInstance`, a bare
    :class:`ExecutionContext`, or None (the XLA reference context). A
    static int window is normalized (0 encodes "global" -> None) and keeps
    the engine's context; a *traced* per-layer scalar (gemma-style
    local:global interleave scanned as data, 0/2^30 encoding) cannot
    parameterize a Mosaic kernel, so it demotes the context to the XLA
    backend, whose mask arithmetic handles traced scalars."""
    from repro.core import context
    ctx = context.as_context(engine)
    static_window = (window is None or isinstance(window, (int, np.integer)))
    if static_window and window is not None:
        window = int(window) or None
    if not static_window and ctx.backend != "xla":
        ctx = ctx.with_backend("xla")
    return window, ctx


def attn_op(engine, q, k, v, *,
            causal: bool = True, window=None, softcap: Optional[float] = None,
            scale: Optional[float] = None):
    """Model-zoo attention, routed through ``ctx.flash_attention`` so the
    engine's context -- not the call site -- picks the lowering, and the
    Pallas path resolves its tuned ``(block_q, block_k)`` schedule (under
    a mesh'd context: inside shard_map, at per-device shapes).
    ``transformer`` passes a static window whenever the model's layers are
    window-uniform; see :func:`_route_window` for the traced-window rule.
    """
    window, ctx = _route_window(engine, window)
    return ctx.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale)


def paged_attn_op(engine, q, cache: PagedKVCache, *, window=None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None):
    """Paged-decode twin of :func:`attn_op`: routes through
    ``ctx.paged_attention`` (in-kernel gather on pallas/interpret engines,
    explicit gather on xla); a traced per-layer window falls back to the
    gather path, whose masking handles traced scalars."""
    window, ctx = _route_window(engine, window)
    return ctx.paged_attention(q, cache.k, cache.v, cache.tables,
                               cache.lengths, window=window, softcap=softcap,
                               scale=scale)


def paged_prefill_attn_op(engine, q, cache: PagedKVCache, start, *,
                          window=None, softcap: Optional[float] = None,
                          scale: Optional[float] = None,
                          kv_pages: Optional[int] = None):
    """Chunked-prefill twin of :func:`paged_attn_op`: the fresh chunk's
    queries attend cache pages + the chunk itself through
    ``ctx.paged_prefill_attention`` (in-kernel gather on pallas/interpret
    engines, explicit gather on xla); a traced per-layer window falls back
    to the gather path, whose masking handles traced scalars. ``kv_pages``
    is the engine's STATIC admission-time bound on live table entries
    (dead-key MAC elision for short prompts; see
    ``ops.paged_prefill_attention_impl``)."""
    window, ctx = _route_window(engine, window)
    return ctx.paged_prefill_attention(
        q, cache.k, cache.v, cache.tables[0], start, window=window,
        softcap=softcap, scale=scale, kv_pages=kv_pages)


# ---------------------------------------------------------------------------
# full attention block
# ---------------------------------------------------------------------------
def attn_apply(engine: GemminiInstance, p: Params, x: jnp.ndarray, *,
               n_heads: int, n_kv: int, head_dim: int,
               positions: jnp.ndarray,
               window: Optional[int] = None,
               softcap: Optional[float] = None,
               rope_base: float = 10000.0,
               query_scale: Optional[float] = None,
               cache: Optional[KVCache] = None,
               cache_pos: Optional[jnp.ndarray] = None,
               ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Self-attention with optional KV cache (decode when x has T==1)."""
    b, t, _ = x.shape
    q, k, v = _qkv(engine, p, x, n_heads, n_kv, head_dim)
    q = layers.rope(q, positions, base=rope_base)
    k = layers.rope(k, positions, base=rope_base)
    if cache is not None:
        cache = update_cache(cache, k, v, cache_pos)
        if t == 1:
            o = decode_attention(q, cache, cache_pos, window=window,
                                 softcap=softcap, scale=query_scale)
        else:  # chunked prefill into cache
            o = attn_op(engine, q, cache.k[:, :], cache.v[:, :],
                        causal=True, window=window, softcap=softcap,
                        scale=query_scale)
    else:
        o = attn_op(engine, q, k, v, causal=True, window=window,
                    softcap=softcap, scale=query_scale)
    o = o.reshape(b, t, n_heads * head_dim)
    return layers.project(engine, o, p["wo"]), cache
