"""Core layers, built on the Gemmini engine substrate.

Every dense projection routes through ``GemminiInstance.matmul`` so the
paper's generated GEMM engine is the compute substrate of every assigned
architecture (the paper's own thesis: GEMM is the common kernel). In the
dry-run/XLA backend this is a plain dot that XLA partitions; on TPU it is
the Pallas engine kernel.

Pure functional style (no flax): ``init_*`` builds parameter pytrees (nested
dicts of jnp arrays); ``apply`` functions are free of Python state.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.generator import GemminiInstance

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, *, dtype=jnp.bfloat16,
               scale: Optional[float] = None) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, *, dtype=jnp.bfloat16) -> jnp.ndarray:
    # 1/sqrt(d): with gemma-style sqrt(d) embed scaling the residual stream
    # starts O(1), and tied-unembed logits start O(1) (loss ~ ln(vocab)).
    return (jax.random.normal(key, (vocab, d), jnp.float32) /
            math.sqrt(d)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros((d,), dtype)  # gemma-style (1 + scale) parameterization


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
            zero_centered: bool = True) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if zero_centered \
        else scale.astype(jnp.float32)
    return (y * w).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, *, base: float = 10000.0,
         scaling: float = 1.0) -> jnp.ndarray:
    """x: (..., T, H, D); positions: broadcastable to (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freq = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq / scaling  # (...,T,half)
    ang = ang[..., None, :]                                          # (...,T,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# engine-backed projections
# ---------------------------------------------------------------------------
def project(engine, x: jnp.ndarray, w: jnp.ndarray,
            b: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """y = x @ w (+ b) on the Gemmini engine; x: (..., d_in), w: (d_in, d_out).

    ``engine`` is the dispatch value: an elaborated
    :class:`GemminiInstance` or a bare
    :class:`repro.core.context.ExecutionContext` -- both expose
    ``backend`` and ``matmul``, and a mesh'd context runs the engine
    kernel in shard_map at per-device M."""
    if engine.backend == "xla":
        # Float LM path: keep XLA free to fuse/partition; numerics equal to
        # the engine's float datapath (fp32 accumulate). "xla_twin"
        # deliberately does NOT take this shortcut: the degraded-mode twin
        # must round through the same engine datapath as the kernel
        # backends (ctx.matmul lowers it to plain XLA ops anyway).
        y = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        y = y.astype(x.dtype)
        if b is not None:
            y = y + b.astype(y.dtype)
        return y
    # Engine path: the bias rides the engine's native D input (accumulated
    # at acc dtype inside the fused epilogue, Gemmini's D matrix) instead
    # of a separate post-engine add -- and the tile plan resolves with
    # has_bias=True, the same fingerprint warm_model_plans pre-populates.
    return engine.matmul(x, w, d=b)


def mlp_init(key, d: int, d_ff: int, *, dtype=jnp.bfloat16,
             gated: bool = True) -> Params:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d, d_ff, dtype=dtype),
         "wo": dense_init(ks[1], d_ff, d, dtype=dtype)}
    if gated:
        p["wg"] = dense_init(ks[2], d, d_ff, dtype=dtype)
    return p


def mlp_apply(engine: GemminiInstance, p: Params, x: jnp.ndarray, *,
              activation: str = "silu") -> jnp.ndarray:
    act = {"silu": jax.nn.silu, "gelu": lambda v: jax.nn.gelu(v, approximate=True),
           "relu": jax.nn.relu}[activation]
    h = project(engine, x, p["wi"])
    if "wg" in p:
        h = act(project(engine, x, p["wg"])) * h
    else:
        h = act(h)
    return project(engine, h, p["wo"])


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------
def embed_apply(table: jnp.ndarray, tokens: jnp.ndarray, *,
                scale_by_sqrt_dim: bool = False) -> jnp.ndarray:
    y = jnp.take(table, tokens, axis=0)
    if scale_by_sqrt_dim:
        y = (y.astype(jnp.float32) * math.sqrt(table.shape[1])).astype(y.dtype)
    return y


def unembed_apply(engine: GemminiInstance, table: jnp.ndarray,
                  x: jnp.ndarray, *, softcap: Optional[float] = None
                  ) -> jnp.ndarray:
    logits = project(engine, x, table.T)
    logits = logits.astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# ---------------------------------------------------------------------------
# causal depthwise conv1d (mamba2 prefix conv; also the depthwise op the
# paper assigns to the host -- see benchmarks/bench_system_amdahl.py)
# ---------------------------------------------------------------------------
def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, C), w: (K, C) depthwise. Returns (y, new_state).

    state: (B, K-1, C) trailing inputs from the previous segment (decode).
    """
    k = w.shape[0]
    b, t, c = x.shape
    if state is None:
        state = jnp.zeros((b, k - 1, c), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # (B, T+K-1, C)
    y = jnp.zeros((b, t, c), jnp.float32)
    for i in range(k):                                  # K is tiny (4)
        y = y + xp[:, i:i + t, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = xp[:, -(k - 1):, :]
    return y.astype(x.dtype), new_state
