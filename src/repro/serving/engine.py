"""Request-level serving engine: continuous batching over paged KV caches.

``ServingEngine`` binds a model, its parameters, one jitted paged-prefill
and one jitted paged-decode computation, the page allocator, and the
scheduler into the loop a serving binary runs:

    engine = ServingEngine(configs.get_smoke("gemma3-1b"), max_slots=4)
    engine.submit(prompt, max_new_tokens=32)
    report = engine.run()            # drains the queue

Static shapes throughout (XLA/jit discipline): the decode batch is always
``max_slots`` wide -- empty or finished slots decode padding into the trash
page -- and prefill pads prompts up to a page-size multiple so distinct
prompt lengths share compile-cache buckets. The *contents* are fully
dynamic: requests enter and leave slots every iteration, which is exactly
the contention the static batch loop (``policy="static"``: admission
barrier, no slot recycling) cannot express; ``benchmarks/bench_serving.py``
measures the two policies against each other on one request trace.

Chunked prefill (``prefill_chunk``) extends the discipline to long
prompts: fixed-size chunks are their own compile buckets (the traced
``start`` offset keeps one bucket per chunk *length*), mid-prefill slots
ride the decode batch as padding with frozen lengths AND frozen recurrent
state, and only the final chunk samples a token.

Per-request numerics are batch-invariant: projections, norms, and the
paged attention path are row-independent, so a request decoded alongside
arbitrary co-tenants produces bit-identical tokens to the same request
decoded alone through the static reference path (``examples/serve_decode``
gates its exit code on this).

Control-plane / compute split: every *decision* the engine makes --
admission, chunk ordering, preemption, recovery-ladder control flow,
token-commit accounting, spill/restore protocol -- lives on
:class:`EngineControlPlane`, which never touches a device tensor. The
device work (jitted step dispatch, sampling, table sync, DMA copies) is
behind a handful of compute hooks ``ServingEngine`` implements. A null
executor (``repro.analysis.mc.harness.NullEngine``) implements the same
hooks with fabricated deterministic token commits, which is what lets the
model checker exhaust scheduler x allocator x recovery interleavings
without a model; the same seam is where speculative-decoding verify steps
and a sequence-sharded multi-host arena plug in (ROADMAP).
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flags
from repro.core.config import GemminiConfig
from repro.core.context import ExecutionContext
from repro.core.generator import default_engine_backend
from repro.models import transformer as tf
from repro.obs import trace as otrace
from repro.obs.metrics import MetricsRegistry
from repro.runtime import faults as rfaults
from repro.runtime.ft import StepWatchdog
from repro.serving.paged_cache import PagedKVAllocator, arena_pages
from repro.serving.scheduler import ContinuousScheduler, Request, summarize


# Jitted step functions shared across ServingEngine instances: jax.jit
# caches per function object, so per-engine lambdas would recompile every
# prefill/decode bucket on every engine construction (e.g. the
# static-vs-continuous benchmark builds four engines over one model).
# Keyed by everything the closures bake in; both configs are frozen
# dataclasses, so the key is value-hashed, not identity-hashed.
_JIT_CACHE: Dict = {}


def _jitted_steps(engine: ExecutionContext, model_cfg, page_size: int,
                  donate: bool = True):
    """The five jitted model steps, keyed by name.

    ``donate=False`` keeps the state argument alive across a call: the
    NaN/Inf-guard path re-runs the *same pre-call state* on the XLA twin
    after the primary backend produced non-finite logits, which is only
    sound if the primary call did not consume the buffer. Guarded engines
    therefore trade one extra in-flight state copy for an exact degraded
    mode; unguarded engines (the default) keep the donating fast path."""
    key = (engine, model_cfg, page_size, donate)
    if key not in _JIT_CACHE:
        dn = (2,) if donate else ()
        prefill = jax.jit(
            lambda p, tok, st, slot, pages: tf.paged_prefill(
                engine, p, model_cfg, tok, st, slot, pages,
                page_size=page_size),
            donate_argnums=dn)
        # Logits-free twins for intermediate chunks: nothing samples until
        # the last chunk, so they skip the unembed vocab GEMM entirely.
        prefill_nl = jax.jit(
            lambda p, tok, st, slot, pages: tf.paged_prefill(
                engine, p, model_cfg, tok, st, slot, pages,
                page_size=page_size, with_logits=False),
            donate_argnums=dn)
        # Continuation chunks additionally carry the STATIC kv_pages bound
        # (admission-time prompt footprint in pages): one compile bucket
        # per (chunk length, kv_pages) pair, and the gather attention only
        # contracts the table prefix that can ever hold live keys.
        chunk = jax.jit(
            lambda p, tok, st, slot, pages, start, kv_pages:
            tf.paged_prefill_chunk(
                engine, p, model_cfg, tok, st, slot, pages, start,
                page_size=page_size, kv_pages=kv_pages),
            donate_argnums=dn, static_argnums=(6,))
        chunk_nl = jax.jit(
            lambda p, tok, st, slot, pages, start, kv_pages:
            tf.paged_prefill_chunk(
                engine, p, model_cfg, tok, st, slot, pages, start,
                page_size=page_size, with_logits=False, kv_pages=kv_pages),
            donate_argnums=dn, static_argnums=(6,))
        decode = jax.jit(
            lambda p, tok, st, act: tf.paged_decode_step(
                engine, p, model_cfg, tok, st, act, page_size=page_size),
            donate_argnums=dn)
        _JIT_CACHE[key] = {"prefill": prefill, "prefill_nl": prefill_nl,
                           "chunk": chunk, "chunk_nl": chunk_nl,
                           "decode": decode}
    return _JIT_CACHE[key]


def _env_check_default() -> bool:
    """``$GEMMINI_CHECK`` truthiness: the step-boundary allocator-invariant
    knob's environment default (off unless set to 1/true/on/yes)."""
    return os.environ.get("GEMMINI_CHECK", "").strip().lower() in (
        "1", "true", "on", "yes")


class EngineControlPlane:
    """The device-free half of the serving engine.

    Everything that *decides* lives here: submission, the per-iteration
    step structure (shed -> prefill chunks -> decode capacity -> decode),
    token-commit accounting (``_record_token`` and the finish/EOS logic),
    the recovery ladder's control flow (``_run_guarded``: transient retry
    -> NaN guard -> fallback -> quarantine), and the host-offload
    spill/restore protocol. None of it touches a device tensor; the
    compute work is behind the hooks below, which a subclass implements:

    * :meth:`_dispatch` / :meth:`_dispatch_fallback` -- run one model step
      (primary / degraded-mode twin), returning ``(logits, state)``.
    * :meth:`_exec_chunk` -- execute one prefill chunk's compute; returns
      the sampled token for the last chunk, else None.
    * :meth:`_exec_decode` -- execute one decode step's compute; returns
      per-slot sampled tokens.
    * :meth:`_capture_spill` / :meth:`_apply_restore` -- the device<->host
      copies behind the offload accounting.
    * :meth:`_sync_tables` -- push allocator block tables to the device
      (no-op by default: a tensor-free executor has no tables to sync).
    * :meth:`_bucket_key` -- the compile-bucket key of a dispatch, for the
      trace-time jit audit (default: one bucket).

    ``ServingEngine`` implements the hooks against the jitted model steps;
    ``repro.analysis.mc.harness.NullEngine`` implements them with
    fabricated deterministic token commits so the model checker can step
    the REAL scheduling/recovery logic through exhaustive interleavings.

    Subclasses finish construction by setting the geometry and component
    attributes: ``max_context``, ``page_size``, ``max_pages_per_seq``,
    ``prefill_pad``, ``alloc``, ``sched``, ``prefill_chunk``,
    ``_next_token``.
    """

    def __init__(self, model_cfg, *, max_slots: int,
                 policy: str = "continuous",
                 faults=None,
                 nan_guard: Optional[bool] = None,
                 max_step_retries: int = 2,
                 retry_backoff_s: float = 0.0,
                 assert_invariants: Optional[bool] = None,
                 watchdog: Optional[StepWatchdog] = None,
                 trace=None,
                 clock=None):
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {policy!r}")
        self.model_cfg = model_cfg
        self.policy = policy
        self.max_slots = max_slots
        # -- observability (docs/observability.md) -------------------------
        # One monotonic clock for every duration in the engine (wall
        # clocks step under NTP); the tracer and scheduler share it so
        # span timestamps and request timings live in one domain.
        self.clock = clock or time.monotonic
        self.tracer = otrace.as_tracer(trace, clock=self.clock)
        self.metrics = MetricsRegistry()
        # Bail-out cap for run(): overridable so tests can force the hang
        # diagnostics without 100k iterations.
        self.max_run_iters = 100_000
        # -- robustness envelope (docs/serving.md#robustness) --------------
        # faults: None consults $GEMMINI_FAULTS (usually: off); a spec
        # string / FaultPlan / FaultInjector turns deterministic fault
        # injection on for THIS engine only. nan_guard defaults to
        # "on iff faults are on": the guard host-checks every step's
        # logits, and the fault-free fast path must stay byte-identical
        # to PR 5 (donating jits, no per-step isfinite sync).
        self.faults = rfaults.as_injector(faults)
        if self.faults is not None and self.tracer is not None:
            # Fault firings land on this engine's trace (cat="fault"),
            # not just on a globally installed tracer.
            self.faults.tracer = self.tracer
        self.nan_guard = (self.faults is not None) if nan_guard is None \
            else nan_guard
        self.max_step_retries = max_step_retries
        self.retry_backoff_s = retry_backoff_s
        # Debug oracle: run PagedKVAllocator.check() at every step
        # boundary. Off by default (it is O(pages) of pure-Python asserts
        # on the hot loop); None consults $GEMMINI_CHECK so the chaos
        # suite -- and any bug hunt -- can flip it on without code edits.
        self.assert_invariants = _env_check_default() \
            if assert_invariants is None else bool(assert_invariants)
        # per-step-name set of dispatched compile-bucket keys, consumed by
        # the trace-time auditor (repro.analysis.lint.jit_audit): every
        # distinct key is one XLA compilation, and the static census from
        # the page/chunk geometry caps how many may ever exist.
        self.observed_buckets: Dict[str, set] = {}
        self.quarantined: List[str] = []
        self.watchdog = watchdog or StepWatchdog()
        # The tuned schedule the decode path launches, for quarantine on a
        # guard trip (subclasses resolve it when tuning is on).
        self._paged_sched_key: Optional[str] = None
        self._rid = 0
        self.requests: List[Request] = []

    # -- compute hooks (subclass responsibility) ---------------------------
    def _dispatch(self, which: str, args: tuple):
        """Run one primary model step; returns ``(logits, state)``."""
        raise NotImplementedError

    def _dispatch_fallback(self, which: str, args: tuple):
        """Run one degraded-mode (bit-exact twin) model step."""
        raise NotImplementedError

    def _exec_chunk(self, w):
        """Execute one prefill chunk's compute against the device state.
        Must return the sampled token when ``w.last`` (the chunk whose
        final row is the prompt's last true position), else None."""
        raise NotImplementedError

    def _exec_decode(self, active_np: np.ndarray) -> np.ndarray:
        """Execute one decode step's compute; returns sampled tokens
        indexed by slot (inactive slots' entries are ignored)."""
        raise NotImplementedError

    def _capture_spill(self, req: Request, page_ids: List[int]) -> Dict:
        """Device->host copy of a victim's committed pages (plus any
        per-slot recurrent state): the opaque host-pool payload."""
        raise NotImplementedError

    def _apply_restore(self, req: Request, slot: int, spill) -> None:
        """Host->device copy of a spill payload into a fresh slot."""
        raise NotImplementedError

    def _sync_tables(self, slots) -> None:
        """Push the allocator's block tables for ``slots`` to the device
        state. Default: no-op (tensor-free executors keep no tables)."""

    def _bucket_key(self, which: str, args: tuple):
        """The compile-bucket a dispatch lands in (jit-audit census)."""
        return ()

    # -- observability -----------------------------------------------------
    def now(self) -> float:
        """The engine clock (monotonic by default). ``submit(deadline=)``
        timestamps must come from this domain: ``engine.now() + rel_s``,
        never ``time.time() + rel_s``."""
        return self.clock()

    @property
    def counters(self) -> Dict[str, int]:
        """Read-only robustness-counter view over the metrics registry
        (the pre-obs ``engine.counters`` dict shape, kept for callers;
        new code should read ``engine.metrics`` directly)."""
        return {"retries": int(self.metrics.value("retries")),
                "fallbacks": int(self.metrics.value("fallbacks"))}

    def _step_gauges(self) -> None:
        """Per-iteration occupancy gauges (registry + tracer counter
        track): arena pages, live/prefilling slots, queue depth."""
        t = self.clock()
        used = self.alloc.used_pages
        live = sum(1 for r in self.sched.running.values()
                   if not r.prefilling)
        depth = len(self.sched.queue)
        self.metrics.gauge("arena_used_pages").set(used, t)
        self.metrics.gauge("arena_utilization").set(
            self.alloc.utilization, t)
        self.metrics.gauge("live_slots").set(live, t)
        self.metrics.gauge("running_slots").set(
            len(self.sched.running), t)
        self.metrics.gauge("queue_depth").set(depth, t)
        if self.tracer is not None:
            self.tracer.counter("arena_pages", used=used,
                                free=self.alloc.free_pages)
            self.tracer.counter("slots", live=live,
                                running=len(self.sched.running))
            self.tracer.counter("queue_depth", depth=depth)

    # -- submission --------------------------------------------------------
    def _bucket(self, n: int) -> int:
        return -(-max(1, n) // self.prefill_pad) * self.prefill_pad

    def submit(self, prompt, max_new_tokens: int, *,
               eos_id: int = -1, priority: int = 0,
               deadline: Optional[float] = None) -> Request:
        """``priority``/``deadline`` feed the scheduler's admission order
        (no-ops under the default FIFO policy); ``deadline`` is an
        absolute timestamp in the ENGINE clock's domain
        (``engine.now() + rel_s`` -- monotonic by default, not
        ``time.time()``)."""
        prompt = np.asarray(prompt, np.int32)
        need = self._bucket(len(prompt)) + self.model_cfg.n_meta_tokens
        cap = min(self.max_pages_per_seq,
                  self.alloc.n_pages) * self.page_size
        if need > cap:
            raise ValueError(f"prompt of {len(prompt)} tokens can never be "
                             f"admitted (cache capacity {cap} tokens, "
                             f"max_context={self.max_context})")
        req = Request(rid=self._rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      priority=priority, deadline=deadline)
        self._rid += 1
        self.requests.append(req)
        self.sched.submit(req)
        return req

    # -- token commit ------------------------------------------------------
    def _record_token(self, req: Request, tok: np.ndarray,
                      now: float) -> None:
        req.generated.append(tok if tok.ndim else int(tok))
        if req.t_first_token is None:
            req.t_first_token = now
        else:
            req.itl_s.append(now - req.t_last_token)
        req.t_last_token = now
        self._next_token[req.slot] = tok
        if self.tracer is not None:
            self.tracer.instant("token", cat="request",
                                tid=otrace.req_tid(req.rid),
                                n=req.n_generated)
        done = req.n_generated >= req.max_new_tokens
        if self.model_cfg.n_codebooks == 1 and int(tok) == req.eos_id:
            done = True
        if done:
            if self.tracer is not None and req.t_first_token is not None:
                # The request's decode phase as one span: first token
                # (end of prefill) to last.
                self.tracer.complete("decode", req.t_first_token, now,
                                     cat="request",
                                     tid=otrace.req_tid(req.rid),
                                     tokens=req.n_generated)
            self.sched.finish(req)

    # -- KV lifecycle: host offload (scheduler-wired hooks) ----------------
    def _spill(self, req: Request, page_ids: List[int],
               committed: int) -> bool:
        """Host-pool spill of a preemption victim's committed pages. Runs
        BEFORE ``free_slot`` re-issues the pages; the :meth:`_capture_spill`
        hook forces the device->host copy to complete while contents are
        still exclusively owned. Returns False (degrade to recompute) on
        an injected ``offload_io@spill`` fault or when the pool rejects
        the entry."""
        inj = self.faults
        if inj is not None and inj.offload_fails("spill"):
            return False
        if not page_ids:
            return False
        payload = self._capture_spill(req, page_ids)
        ok = self.alloc.host_put(req.rid, len(page_ids), committed, payload)
        if ok:
            self.metrics.counter("offload_spills").inc()
        return ok

    def _restore(self, req: Request, slot: int, committed: int) -> bool:
        """Host-pool restore into a freshly allocated slot (the scheduler
        allocated BEFORE calling, so the target pages exist and are
        exclusive; :meth:`_apply_restore` performs the copies). Returns
        False to degrade the admission to recompute: injected
        ``offload_io@restore`` fault, or a stale/missing spill entry."""
        inj = self.faults
        if inj is not None and inj.offload_fails("restore"):
            self.alloc.host_drop(req.rid)
            return False
        sp = self.alloc.host_take(req.rid)
        if sp is None or sp.tokens != committed:
            return False
        self._apply_restore(req, slot, sp)
        self.metrics.counter("offload_restores").inc()
        return True

    # -- robustness envelope ----------------------------------------------
    def _quarantine(self, site: str) -> None:
        """Bar the tuned schedule behind a guard trip from future
        resolution (PlanCache.quarantine). Only the decode path maps 1:1
        to one tuned schedule (the paged-attention key the page size was
        resolved under); prefill trips still fall back + count, but have
        no single schedule to blame."""
        key = self._paged_sched_key if site == "decode" else None
        if key is None or key in self.quarantined:
            return
        from repro import tune
        tune.get_cache().quarantine(key)
        self.quarantined.append(key)

    def _run_guarded(self, site: str, which: str, args: tuple):
        """One model step under the robustness envelope.

        Order of events: (1) injected transient failures raise *before*
        the call and retry with bounded exponential backoff -- state is
        untouched, so a retry is a plain re-dispatch; (2) the injector may
        poison the returned logits (host-level: compiled functions stay
        byte-identical to the fault-free run); (3) with ``nan_guard`` on,
        non-finite logits trigger one retry of the SAME step on the XLA
        twin from the SAME pre-call state (non-donating jits keep it
        alive), the tuned schedule is quarantined, and the fallback is
        counted in telemetry. A twin that still produces non-finite
        logits means the model itself diverged -- that raises, because
        sampling from NaN logits would silently emit garbage tokens.
        """
        self.observed_buckets.setdefault(which, set()).add(
            self._bucket_key(which, args))
        inj = self.faults
        for attempt in range(self.max_step_retries + 1):
            try:
                if inj is not None:
                    inj.check_transient(site)
                logits, state = self._dispatch(which, args)
                break
            except rfaults.TransientOpError:
                self.metrics.counter("retries", site=site).inc()
                if self.tracer is not None:
                    self.tracer.instant("retry", cat="engine", site=site,
                                        which=which, attempt=attempt + 1)
                if attempt == self.max_step_retries:
                    raise
                if self.retry_backoff_s:
                    time.sleep(self.retry_backoff_s * (2 ** attempt))
        if inj is not None and logits is not None:
            logits = inj.poison(site, logits)
        if self.nan_guard and logits is not None and \
                not bool(np.isfinite(np.asarray(logits)).all()):
            self.metrics.counter("fallbacks", site=site).inc()
            if self.tracer is not None:
                self.tracer.instant("fallback", cat="engine", site=site,
                                    which=which)
            self._quarantine(site)
            logits, state = self._dispatch_fallback(which, args)
            if not bool(np.isfinite(np.asarray(logits)).all()):
                raise FloatingPointError(
                    f"non-finite logits at {site!r} survived the XLA "
                    f"fallback: model divergence, not a kernel fault")
        return logits, state

    # -- execution (control skeletons over the compute hooks) --------------
    def _do_prefill_chunk(self, w) -> None:
        """Execute one scheduler-issued prefill chunk: run the compute
        hook, then commit the accounting (cache_len, prefix publication)
        and -- for the last chunk -- record the sampled token."""
        req, slot = w.req, w.slot
        if req.state != "running" or req.slot != slot:
            # The scheduler finished or preempted this request AFTER
            # emitting the chunk (sole-runner truncation later in the same
            # pass): its pages are freed -- executing the chunk would
            # scatter into a zero table row over pages the allocator may
            # already have re-issued.
            return
        t0 = self.clock()
        tok = self._exec_chunk(w)
        req.cache_len = w.true_end
        req.n_chunks += 1
        self.sched.note_committed(req)
        if self.tracer is not None:
            if w.first and w.last:
                self.tracer.complete("prefill", t0, cat="request",
                                     tid=otrace.req_tid(req.rid), slot=slot,
                                     tokens=w.true_end)
            else:
                self.tracer.complete(
                    f"prefill_chunk[{req.n_chunks - 1}]", t0, cat="request",
                    tid=otrace.req_tid(req.rid), slot=slot, start=w.start,
                    end=w.true_end, last=w.last)
        if w.last:
            self._record_token(req, tok, self.clock())

    def _do_decode(self) -> None:
        active_np = np.zeros((self.max_slots,), bool)
        for slot, req in self.sched.running.items():
            # Mid-prefill slots hold pages but must not decode: inactive
            # slots write the trash page and keep frozen lengths, so a
            # partially-prefilled cache can never be touched.
            active_np[slot] = not req.prefilling
        last = self._exec_decode(active_np)
        now = self.clock()
        for slot, req in list(self.sched.running.items()):
            if req.prefilling:
                continue
            req.cache_len += 1
            self._record_token(req, last[slot], now)

    # The two step phases, exposed individually so the model checker can
    # interleave them as atomic actions; step() composes exactly these, so
    # the checked control flow and the served control flow are one code
    # path (no re-model to drift).
    def control_prefill(self, admit_new: bool = True) -> int:
        """Admission-boundary phase: shed expired deadlines, execute the
        scheduler's prefill chunk queue, drain unservable rejections.
        Returns the number of chunks executed."""
        self.sched.shed_expired()
        ws = self.sched.prefill_schedule(admit_new=admit_new)
        for w in ws:
            self._do_prefill_chunk(w)
        for req in self.sched.rejected:
            # Regrew past the arena while preempted: finish truncated.
            self.sched.finish(req, truncated=True)
        self.sched.rejected = []
        return len(ws)

    def control_decode(self) -> None:
        """Decode-boundary phase: ensure every running slot can take one
        more token (preempting by eviction under pressure), shed expired
        deadlines, decode one token per fully-prefilled running slot."""
        new_pages, _evicted, _trunc = self.sched.ensure_decode_capacity()
        if new_pages:
            self._sync_tables({slot for slot, _ in new_pages})
        self.sched.shed_expired()
        if any(not r.prefilling for r in self.sched.running.values()):
            self._do_decode()

    def step(self) -> None:
        """One scheduler iteration: shed expired deadlines (admission
        boundary), prefill (whole prompts, or chunks interleaved at
        ``prefill_chunk`` granularity), ensure decode capacity (preempting
        by eviction under pressure), shed expired deadlines again (decode
        boundary), decode one token for every fully-prefilled running
        slot. With faults on, the injector runs first: straggler sleeps
        and one iteration's worth of arena pressure (pages withheld for
        the whole step, so the scheduler's can_admit-then-alloc protocol
        stays consistent, then released). With ``assert_invariants`` on
        (``GEMMINI_CHECK``), the allocator's ownership oracle runs at the
        step boundary."""
        t0 = self.clock()
        inj = self.faults
        held = 0
        if inj is not None:
            inj.straggle("step")
            k = inj.arena_pressure()
            if k:
                held = self.alloc.hold_pages(k)
        try:
            admit_new = not (self.policy == "static" and self.sched.running)
            self.control_prefill(admit_new=admit_new)
            self.control_decode()
        finally:
            if held:
                self.alloc.release_held()
            if self.assert_invariants:
                self.alloc.check()
            self._step_gauges()
            if self.tracer is not None:
                self.tracer.complete("step", t0, cat="engine",
                                     tid=otrace.TID_ENGINE)

    def run(self) -> Dict:
        """Drain the queue; returns {summary, requests} telemetry.

        Every submitted request reaches a terminal status before this
        returns: ``finished`` (possibly ``truncated``) or ``shed`` --
        the no-silent-loss invariant the chaos suite asserts."""
        t0 = self.clock()
        iters = 0
        while self.sched.has_work:
            ts = self.clock()
            self.step()
            self.watchdog.observe(self.clock() - ts)
            iters += 1
            if iters > self.max_run_iters:
                raise RuntimeError(
                    "serving loop did not converge\n" + self._hang_report())
        wall = self.clock() - t0
        summary = summarize(self.requests, wall)
        # Deterministic structural metric alongside the wall-clock ones:
        # continuous batching's win IS fewer engine iterations for the same
        # token count (slot recycling), independent of host noise.
        summary["iterations"] = float(iters)
        # Robustness counters (all 0 on a fault-free engine) + step-latency
        # percentiles from the watchdog: the BENCH_serving robustness row.
        # Counters read from the metrics registry (labels aggregated);
        # occupancy gauges contribute their run peaks (*_peak keys).
        summary["retries"] = self.metrics.value("retries")
        summary["fallbacks"] = self.metrics.value("fallbacks")
        summary["injected_faults"] = float(
            self.faults.total_injected if self.faults else 0)
        # KV-lifecycle counters (all 0 with both features off): prefill
        # positions actually computed, positions skipped via CoW prefix
        # hits, and the restore-vs-recompute restart split.
        for k in ("prefill_tokens", "prefix_hit_tokens", "offload_spills",
                  "offload_restores", "restarts_restored",
                  "restarts_recomputed"):
            summary[k] = self.metrics.value(k)
        summary.update(self.metrics.gauge_peaks())
        summary.update(self.watchdog.stats())
        report = {"summary": summary,
                  "requests": [self._req_report(r) for r in self.requests],
                  "quarantined": list(self.quarantined)}
        if self.faults is not None:
            report["faults"] = self.faults.report()
        return report

    def _hang_report(self, last_events: int = 32) -> str:
        """Diagnostic dump for a non-converging serving loop: scheduler
        queues, per-slot request states, allocator occupancy, robustness
        counters, and (when tracing is on) the last trace events -- so a
        hung engine is debuggable from the exception alone."""
        lines = ["-- engine hang diagnostics --"]
        q = [(r.rid, r.state, r.n_preempted, len(r.serve_prompt()))
             for r in self.sched.queue]
        lines.append(f"queue ({len(q)}): "
                     + ", ".join(f"rid={rid}[{st},pre={pre},len={ln}]"
                                 for rid, st, pre, ln in q[:16])
                     + (" ..." if len(q) > 16 else ""))
        for slot in sorted(self.sched.running):
            r = self.sched.running[slot]
            lines.append(
                f"slot {slot}: rid={r.rid} state={r.state} "
                f"cache_len={r.cache_len} prefill={r.prefill_pos}/"
                f"{r.prefill_target} gen={r.n_generated}/"
                f"{r.max_new_tokens} pages={len(self.alloc.slot_pages(slot))}")
        lines.append(
            f"allocator: {self.alloc.used_pages}/{self.alloc.n_pages} pages "
            f"used ({self.alloc.utilization:.0%}), "
            f"{self.alloc.held_pages} held, page_size={self.alloc.page_size}, "
            f"max_pages_per_seq={self.alloc.max_pages_per_seq}")
        lines.append(f"counters: {self.metrics.counters_flat()}")
        if self.tracer is not None:
            tail = self.tracer.tail(last_events)
            lines.append(f"last {len(tail)} trace events "
                         f"({self.tracer.dropped} dropped):")
            for ev in tail:
                lines.append(f"  {ev.get('ts', 0.0):>12.1f}us "
                             f"{ev.get('cat', '?')}/{ev.get('name', '?')} "
                             f"{ev.get('args', '')}")
        else:
            lines.append("tracing disabled (GEMMINI_TRACE / trace= would "
                         "append the last trace events here)")
        return "\n".join(lines)

    def _req_report(self, r: Request) -> Dict:
        itl = np.asarray(r.itl_s) if r.itl_s else None
        return {"rid": r.rid, "prompt_tokens": int(len(r.prompt)),
                "new_tokens": r.n_generated,
                "tokens": np.asarray(r.generated),
                "status": r.state, "shed_reason": r.shed_reason,
                "preempted": r.n_preempted, "truncated": r.truncated,
                "prefill_chunks": r.n_chunks,
                "ttft_s": (r.t_first_token - r.submitted_at)
                if r.t_first_token else None,
                "itl_p50_s": float(np.percentile(itl, 50))
                if itl is not None else None,
                "itl_p95_s": float(np.percentile(itl, 95))
                if itl is not None else None,
                "latency_s": (r.t_finished - r.submitted_at)
                if r.t_finished else None}

    # -- maintenance -------------------------------------------------------
    def defrag(self) -> None:
        """Compact live pages to the arena front (accounting only here;
        ``ServingEngine.defrag`` additionally permutes the device pools)."""
        self.alloc.defrag()


class ServingEngine(EngineControlPlane):
    """Continuous-batching executor for one model on one host.

    Knobs (see docs/serving.md for the policy discussion):

    * ``max_slots`` / ``max_context`` / ``page_size`` / ``n_pages`` --
      decode batch width and paged-arena geometry. ``page_size=None``
      resolves the tuned ``PagedAttnSchedule`` page size when
      ``GEMMINI_TUNE`` is not ``off``, else the static default.
    * ``backend`` -- ``xla`` (gather reference, exact-match contract),
      ``interpret`` (Pallas kernel bodies on CPU), ``pallas`` (TPU).
    * ``prefill_token_budget`` -- prefill cache positions per iteration.
    * ``prefill_chunk`` -- chunked prefill: ``None`` or negative =
      single-pass, ``0`` = auto (one page), else the chunk size in cache
      positions (floored to ``n_meta_tokens + 1``).
    * ``policy`` -- ``continuous``, or ``static`` (admission barrier, no
      slot recycling; the bench baseline). The barrier never blocks an
      in-flight chunked prefill, only new admissions.
    * ``admission_policy`` -- queue order for new admissions: ``fifo``
      (default, unchanged), ``priority`` (highest ``Request.priority``
      first, deadline then age break ties), or ``deadline``
      (earliest-deadline-first). See ``scheduler.ContinuousScheduler``.
    * ``warm_prompt_lens`` -- pre-resolve every tuned schedule the given
      prompt lengths will hit (no-op under ``GEMMINI_TUNE=off``).
    * ``faults`` / ``nan_guard`` / ``max_step_retries`` /
      ``retry_backoff_s`` / ``enforce_deadlines`` -- the robustness
      envelope (docs/serving.md#robustness): deterministic fault
      injection (``faults=None`` consults ``$GEMMINI_FAULTS``; off by
      default), post-step NaN/Inf guard with retry-on-the-XLA-twin +
      schedule quarantine (defaults to on iff faults are on), bounded
      retry-with-backoff for transient step failures, and SLO
      enforcement (shed expired deadlines instead of serving them).
    * ``assert_invariants`` -- debug oracle: run
      ``PagedKVAllocator.check()`` at every step boundary. Off by
      default; ``None`` consults ``$GEMMINI_CHECK``.
    * ``kv_offload`` / ``host_pool_pages`` / ``prefix_cache`` -- the
      page-granular KV lifecycle (docs/serving.md#kv-lifecycle), both off
      by default with bit-exact parity to the classic paths. Offload
      spills a preempted victim's committed pages to a host pool (LRU,
      ``host_pool_pages`` deep; default: the arena size) so restart is a
      DMA restore + resumed chunked prefill instead of a recompute; the
      prefix cache content-hashes full pages at prefill commit and maps
      shared prompt prefixes copy-on-write at admission (attention-only
      families -- an SSM's recurrent state cannot skip chunks).
    * ``watchdog`` -- a :class:`repro.runtime.StepWatchdog` (default: a
      fresh one) observing every engine iteration: straggler flags +
      step-latency percentiles in the run summary, optional heartbeat.
    * ``trace`` -- span tracing (docs/observability.md): ``None``
      consults ``$GEMMINI_TRACE`` (usually: off), ``True``/an int
      capacity/a :class:`repro.obs.trace.Tracer` enable the ring-buffered
      tracer for THIS engine (request lifecycle, step phases, allocator
      events). Off costs one None check per emission site; the disabled
      path is bit-exact against PR-7 (a regression test holds it there).
    * ``clock`` -- the engine's one monotonic clock (default
      ``time.monotonic``): every TTFT/ITL/latency/step duration and
      every trace timestamp derives from it, and ``submit(deadline=)``
      timestamps live in its domain (``engine.now() + rel_s``).
      Injectable for deterministic tests.

    Dispatch is an :class:`ExecutionContext` (``self.engine``): cfg +
    backend + tune policy in one frozen value handed to the jitted model
    steps. A mesh-aware context (``ExecutionContext.with_mesh``) is the
    multi-host path once the page arena itself is sequence-sharded
    (ROADMAP).
    """

    def __init__(self, model_cfg, *, max_slots: int = 4,
                 max_context: int = 2048,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 engine_cfg: Optional[GemminiConfig] = None,
                 backend: Optional[str] = None,
                 params=None, seed: int = 0,
                 temperature: float = 0.0,
                 prefill_token_budget: int = 512,
                 prefill_chunk: Optional[int] = None,
                 policy: str = "continuous",
                 admission_policy: str = "fifo",
                 warm_prompt_lens: Sequence[int] = (),
                 faults=None,
                 nan_guard: Optional[bool] = None,
                 max_step_retries: int = 2,
                 retry_backoff_s: float = 0.0,
                 enforce_deadlines: bool = False,
                 assert_invariants: Optional[bool] = None,
                 kv_offload: bool = False,
                 host_pool_pages: Optional[int] = None,
                 prefix_cache: bool = False,
                 watchdog: Optional[StepWatchdog] = None,
                 trace=None,
                 clock=None):
        super().__init__(model_cfg, max_slots=max_slots, policy=policy,
                         faults=faults, nan_guard=nan_guard,
                         max_step_retries=max_step_retries,
                         retry_backoff_s=retry_backoff_s,
                         assert_invariants=assert_invariants,
                         watchdog=watchdog, trace=trace, clock=clock)
        self.temperature = temperature
        self.max_context = max_context
        cfg = engine_cfg or GemminiConfig(input_dtype="bf16",
                                          acc_dtype="fp32",
                                          output_dtype="bf16")
        self.engine = ExecutionContext(
            cfg=cfg, backend=backend or default_engine_backend())

        # -- page geometry: the tuned schedule is the page size ------------
        if page_size is None:
            if flags.get("tune_mode") != "off" and model_cfg.has_attn:
                from repro import tune
                page_size = tune.resolve_paged_attn_schedule(
                    cfg, max_slots, model_cfg.n_heads, model_cfg.n_kv_heads,
                    model_cfg.head_dim, max_context,
                    dtype=model_cfg.dtype).page_size
            else:
                from repro.tune.schedules import DEFAULT_PAGE_SIZE
                page_size = DEFAULT_PAGE_SIZE
        self.page_size = max(8, min(page_size, max_context))
        self.max_pages_per_seq = -(-max_context // self.page_size)
        if n_pages is None:
            # Budget-derived arena, capped at what the engine can ever hold
            # live: pages belong only to running slots, each at most
            # max_pages_per_seq deep, so anything beyond slots*MP is zero
            # pools that no schedule could touch (a full gemma3 config
            # would otherwise allocate the whole 4096-page cap -- GiBs of
            # zeros -- to serve a 2-request smoke batch).
            n_pages = max(self.max_pages_per_seq,
                          min(max_slots * self.max_pages_per_seq,
                              arena_pages(model_cfg, cfg, self.page_size)))
        # -- KV lifecycle (docs/serving.md#kv-lifecycle) -------------------
        self.kv_offload = bool(kv_offload)
        self.prefix_cache = bool(prefix_cache)
        if self.prefix_cache and model_cfg.has_ssm:
            # A prefix hit skips the chunks below the anchor, but an
            # SSM/hybrid family's recurrent state is a function of every
            # skipped position -- CoW pages cannot carry it.
            raise ValueError("prefix_cache requires an attention-only "
                             f"family; {model_cfg.name!r} has SSM state")
        self.alloc = PagedKVAllocator(
            n_pages, self.page_size, self.max_pages_per_seq,
            tracer=self.tracer,
            host_pool_pages=((host_pool_pages if host_pool_pages is not None
                              else n_pages) if self.kv_offload else 0))
        # Prompt bucketing (compile-cache friendliness): legal only for
        # pure-attention families, where padded positions are provably dead
        # under the causal mask + length mask. An SSM/hybrid model's
        # recurrent scan state WOULD absorb padding tokens, silently
        # diverging from the reference path, so those prefill at exact
        # length (one compile per distinct prompt length).
        self.prefill_pad = 1 if model_cfg.has_ssm else self.page_size
        # Chunked prefill: None or negative = single-pass (classic; the
        # CLI's -1 convention works here too); 0 = auto (one page, the
        # natural page-multiple default); positive values are floored to
        # meta+1 by the scheduler (the first chunk carries the meta-token
        # prefix).
        if prefill_chunk is not None and prefill_chunk < 0:
            prefill_chunk = None
        elif prefill_chunk == 0:
            prefill_chunk = self.page_size
        self.sched = ContinuousScheduler(
            self.alloc, max_slots,
            prefill_token_budget=prefill_token_budget,
            extra_tokens_per_prefill=model_cfg.n_meta_tokens,
            pad_to=self.prefill_pad,
            prefill_chunk=prefill_chunk,
            admission_policy=admission_policy,
            enforce_deadlines=enforce_deadlines,
            clock=self.clock, tracer=self.tracer, metrics=self.metrics,
            offload=self.kv_offload, prefix_cache=self.prefix_cache,
            spill_fn=self._spill, restore_fn=self._restore)
        self.prefill_chunk = self.sched.prefill_chunk
        if policy == "static":
            # Static batching as a degenerate policy: admit only into an
            # EMPTY engine (group barrier, no slot recycling) and ignore
            # the prefill budget -- the whole group prefills at once.
            self.sched.prefill_token_budget = 1 << 30

        # -- model state + jitted steps ------------------------------------
        self._key = jax.random.PRNGKey(seed)
        if params is None:
            self._key, pk = jax.random.split(self._key)
            params = tf.init_params(pk, model_cfg)
        self.params = params
        self.state = tf.init_paged_state(model_cfg, max_slots, n_pages,
                                         self.page_size,
                                         self.max_pages_per_seq,
                                         dtype=model_cfg.dtype)
        mc = model_cfg
        # Guarded engines use non-donating jits (see _jitted_steps: the
        # XLA-twin retry needs the pre-call state buffer alive).
        self._steps = _jitted_steps(self.engine, mc, self.page_size,
                                    donate=not self.nan_guard)
        self._fb_steps = None        # XLA-twin fallbacks, built on demand
        # The tuned schedule the decode path launches, for quarantine on a
        # guard trip: the same key resolve_paged_attn_schedule resolved the
        # page size under. None when tuning is off or the family has no
        # attention (nothing tuned to quarantine).
        if mc.has_attn and flags.get("tune_mode") != "off":
            from repro.tune import schedules as tsched
            self._paged_sched_key = tsched.paged_attn_cache_key(
                cfg, max_slots, mc.n_heads, mc.n_kv_heads, mc.head_dim,
                max_context, window=None, dtype=mc.dtype)

        tok_shape = (max_slots,) if mc.n_codebooks == 1 \
            else (max_slots, mc.n_codebooks)
        self._next_token = np.zeros(tok_shape, np.int32)
        self.warm_stats: Optional[Dict[str, int]] = None
        if warm_prompt_lens and flags.get("tune_mode") != "off":
            self.warm_stats = self.warm(warm_prompt_lens)

    # -- plan warm-up ------------------------------------------------------
    def warm(self, prompt_lens: Sequence[int]) -> Dict[str, int]:
        """Pre-resolve every schedule the engine will launch: prefill GEMM
        and attention shapes per prompt bucket (batch 1), decode GEMMs at
        the slot batch, and the paged-attention page size the pools were
        sized with -- so no request ever tunes on the request path.

        With chunked prefill on, the buckets are *chunk lengths*, not
        prompt buckets: the first chunk prefills like a short fresh prompt
        (self-attention + GEMMs at the chunk length), continuation chunks
        launch only GEMMs -- their attention is the block-table gather
        kernel, whose tuned schedule IS the page size the pools were
        already sized with."""
        from repro import tune
        totals: Dict[str, int] = {}
        # Prefill really runs at bucket + meta tokens (embed_inputs prepends
        # them), so that is the length to warm -- warming the bare bucket
        # would populate fingerprints the request path never hits.
        first, rest = set(), set()
        for p in prompt_lens:
            dummy = Request(rid=-1,
                            prompt=np.zeros((max(1, int(p)),), np.int32),
                            max_new_tokens=0)
            spans = self.sched._chunk_spans(dummy)
            first.add(spans[0][2])
            for (s, _e, pe) in spans[1:]:
                rest.add(pe - s)
        for i, b in enumerate(sorted(first)):
            st = tune.warm_model_plans(
                self.engine.cfg, self.model_cfg, 1, b,
                include_decode=False,
                paged_slots=self.max_slots if i == 0 else 0,
                paged_max_context=self.max_context)
            totals = {k: totals.get(k, 0) + v for k, v in st.items()}
        for b in sorted(rest - first):
            st = tune.warm_model_plans(self.engine.cfg, self.model_cfg, 1, b,
                                       include_decode=False,
                                       include_attention=False)
            totals = {k: totals.get(k, 0) + v for k, v in st.items()}
        st = tune.warm_model_plans(self.engine.cfg, self.model_cfg,
                                   self.max_slots, 1,
                                   include_attention=False)
        totals = {k: totals.get(k, 0) + v for k, v in st.items()}
        return totals

    # -- sampling ----------------------------------------------------------
    def _sample(self, logits: jnp.ndarray) -> np.ndarray:
        """logits: (..., V) -> token ids, greedy unless temperature > 0."""
        if self.temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self._key, k = jax.random.split(self._key)
        return np.asarray(jax.random.categorical(
            k, logits / self.temperature), np.int32)

    # -- device state ------------------------------------------------------
    def _table_row(self, slot: int) -> np.ndarray:
        row = np.zeros((self.max_pages_per_seq,), np.int32)
        pages = self.alloc.slot_pages(slot)
        row[:len(pages)] = pages
        return row

    def _sync_tables(self, slots) -> None:
        tables = self.state.tables
        for slot in slots:
            tables = tables.at[slot].set(jnp.asarray(self._table_row(slot)))
        self.state = self.state._replace(tables=tables)

    # -- KV lifecycle compute hooks ----------------------------------------
    def _capture_spill(self, req: Request, page_ids: List[int]) -> Dict:
        """Device->host copy of a preemption victim's committed pages (plus
        its per-slot recurrent state); ``np.asarray`` forces the copy to
        complete while contents are still exclusively owned."""
        idx = jnp.asarray(np.asarray(page_ids, np.int64))
        st = self.state
        payload: Dict = {}
        if st.kv_k is not None:
            payload["kv_k"] = np.asarray(st.kv_k[:, :, idx])
            payload["kv_v"] = np.asarray(st.kv_v[:, :, idx])
        if st.conv is not None:
            payload["conv"] = np.asarray(st.conv[:, req.slot])
            payload["ssm"] = np.asarray(st.ssm[:, req.slot])
        return payload

    def _apply_restore(self, req: Request, slot: int, spill) -> None:
        """Host->device copy of a spilled victim's pages into the freshly
        allocated slot's pages."""
        pages = self.alloc.slot_pages(slot)[:spill.n_pages]
        idx = jnp.asarray(np.asarray(pages, np.int64))
        st = self.state
        pl = spill.payload
        if st.kv_k is not None:
            st = st._replace(
                kv_k=st.kv_k.at[:, :, idx].set(jnp.asarray(pl["kv_k"])),
                kv_v=st.kv_v.at[:, :, idx].set(jnp.asarray(pl["kv_v"])))
        if st.conv is not None:
            st = st._replace(
                conv=st.conv.at[:, slot].set(jnp.asarray(pl["conv"])),
                ssm=st.ssm.at[:, slot].set(jnp.asarray(pl["ssm"])))
        self.state = st

    # -- robustness envelope (compute side) --------------------------------
    def _fallback_steps(self):
        """The bit-exact XLA twins of the jitted steps (PR 3/4's exactness
        contract is what makes degraded mode *exact*): same model, same
        paged state, same engine datapath for every projection -- only the
        kernel lowerings swap for their plan-free XLA twins
        (``backend="xla_twin"``; the plain ``xla`` backend would also flip
        the model onto the float-LM projection path and the re-run would
        drift off the faulted stream at bf16-rounding level). An engine
        already lowering to XLA (``xla`` or ``xla_twin``) has no tuned
        schedule to blame, so its fallback is a clean re-run of the same
        backend (donate=False variant)."""
        if self._fb_steps is None:
            fb = self.engine.backend if self.engine.impl_backend == "xla" \
                else "xla_twin"
            self._fb_steps = _jitted_steps(
                self.engine.with_backend(fb), self.model_cfg,
                self.page_size, donate=False)
        return self._fb_steps

    def _dispatch(self, which: str, args: tuple):
        return self._steps[which](*args)

    def _dispatch_fallback(self, which: str, args: tuple):
        return self._fallback_steps()[which](*args)

    # -- trace-time audit hooks (repro.analysis.lint.jit_audit) ------------
    @staticmethod
    def _bucket_key(which: str, args: tuple):
        """The compile-bucket a dispatch lands in: the traced token-block
        shape plus any static argument (the chunk steps' kv_pages)."""
        if which in ("prefill", "prefill_nl"):
            return (int(args[1].shape[1]),)
        if which in ("chunk", "chunk_nl"):
            return (int(args[1].shape[1]), args[6])
        return ()                                    # decode: one bucket

    def jit_cache_stats(self) -> Dict[str, int]:
        """Observed compile-bucket counts per jitted step (both the
        primary steps and, once built, the XLA-twin fallbacks)."""
        out: Dict[str, int] = {}
        for label, steps in (("", self._steps),
                             ("fb:", self._fb_steps or {})):
            for which, fn in steps.items():
                try:
                    out[label + which] = int(fn._cache_size())
                except Exception:
                    pass
        return out

    def audit(self):
        """Run the trace-time lint audit against this live engine:
        compile-bucket explosions (GL601) and post-donation buffer reuse
        (GL602).  Returns the findings (empty list = healthy)."""
        from repro.analysis.lint import jit_audit
        return jit_audit.audit_engine(self)

    # -- execution compute hooks -------------------------------------------
    def _exec_chunk(self, w):
        """Execute one prefill chunk's device work.

        Single-span chunks (``first and last``) take the classic
        whole-prompt path unchanged. Otherwise: the first chunk runs the
        fresh ``paged_prefill`` (meta prefix, SSM state reset, self-only
        attention -- positions [0, chunk) see no cache); continuation
        chunks run ``paged_prefill_chunk`` (resume SSM state, attend cache
        pages + chunk at offset ``start``). Only the last chunk samples --
        its final row is the prompt's last true position -- and only then
        does the slot's device length go live, flipping it into the decode
        active set (the device table sync can wait until then: the chunk
        calls carry the table row as an argument, and a mid-prefill slot
        never decodes)."""
        req, slot = w.req, w.slot
        meta = self.model_cfg.n_meta_tokens
        prompt = req.serve_prompt()
        if w.first and w.last:
            toks = prompt
            pad = self._bucket(len(prompt)) - len(prompt)
            if pad:
                toks = np.pad(toks, ((0, pad),) + ((0, 0),)
                              * (toks.ndim - 1))
            row = self._table_row(slot)
            logits, self.state = self._run_guarded(
                "prefill", "prefill",
                (self.params, jnp.asarray(toks[None]), self.state,
                 jnp.int32(slot), jnp.asarray(row)))
            true_len = len(prompt) + meta
            self.state = self.state._replace(
                lengths=self.state.lengths.at[slot].set(true_len))
            self._sync_tables([slot])
            return self._sample(logits[0, true_len - 1])
        toks = prompt[max(0, w.start - meta): w.true_end - meta]
        pad = (w.padded_end - w.true_end)
        if pad:
            toks = np.pad(toks, ((0, pad),) + ((0, 0),) * (toks.ndim - 1))
        row = self._table_row(slot)
        if w.first:
            which = "prefill" if w.last else "prefill_nl"
            logits, self.state = self._run_guarded(
                "prefill", which,
                (self.params, jnp.asarray(toks[None]), self.state,
                 jnp.int32(slot), jnp.asarray(row)))
        else:
            # Static dead-key bound for the gather attention: the scheduler
            # stamps each continuation chunk with the pages the whole
            # (padded) prompt will ever occupy (PrefillChunk.kv_pages) --
            # table entries past it can never hold live keys and need not
            # be contracted.
            which = "chunk" if w.last else "chunk_nl"
            logits, self.state = self._run_guarded(
                "chunk", which,
                (self.params, jnp.asarray(toks[None]), self.state,
                 jnp.int32(slot), jnp.asarray(row), jnp.int32(w.start),
                 w.kv_pages or None))
        if not w.last:
            return None
        self._sync_tables([slot])
        true_len = len(prompt) + meta
        self.state = self.state._replace(
            lengths=self.state.lengths.at[slot].set(true_len))
        return self._sample(logits[0, (true_len - 1) - w.start])

    def _exec_decode(self, active_np: np.ndarray) -> np.ndarray:
        toks = self._next_token[:, None] \
            if self.model_cfg.n_codebooks == 1 \
            else self._next_token[:, None, :]
        logits, self.state = self._run_guarded(
            "decode", "decode",
            (self.params, jnp.asarray(toks), self.state,
             jnp.asarray(active_np)))
        return self._sample(logits[:, -1])

    # -- maintenance -------------------------------------------------------
    def defrag(self) -> None:
        """Compact live pages to the arena front: permute the device pools
        and rewrite every slot's table (see PagedKVAllocator.defrag)."""
        perm = self.alloc.defrag()
        if self.state.kv_k is not None:
            inv = np.argsort(perm)
            idx = jnp.asarray(np.concatenate([inv, [self.alloc.n_pages]]))
            self.state = self.state._replace(
                kv_k=jnp.take(self.state.kv_k, idx, axis=2),
                kv_v=jnp.take(self.state.kv_v, idx, axis=2))
        self._sync_tables(list(self.sched.running))
