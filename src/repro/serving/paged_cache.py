"""Paged KV-cache allocator: fixed-size blocks, per-request block tables.

The host-side half of the serving engine's memory system. Device state (the
page pools) lives in :class:`repro.models.transformer.PagedDecodeState`;
this module owns the *accounting*: which pool pages are free, which belong
to which decode slot, and whether a new request fits under the HBM budget
the :class:`~repro.core.config.GemminiConfig` grants long-lived state
(``hbm_bytes``). Paging exists precisely so that budget is spent on tokens
actually cached, not on max-context-sized contiguous reservations: a
request holds ``ceil(len / page_size)`` pages, never ``max_context``.

Invariants the engine relies on:

* page ids handed out are always in ``[0, n_pages)`` -- id ``n_pages`` is
  the reserved trash page retired decode slots spill to, and the allocator
  never owns it;
* a page belongs to at most one slot (``free`` + per-slot tables partition
  the arena);
* ``free_slot`` makes the freed pages immediately reusable (eviction IS
  the preemption mechanism: the scheduler frees a victim's pages and
  re-queues it for recompute).

With a :class:`repro.obs.trace.Tracer` attached (``tracer``; the engine
wires its own in), every accounting transition — alloc / grow / extend /
free / hold / release / defrag — lands as a ``cat="alloc"`` instant on
the allocator track, stamped with the arena occupancy after the
transition.  ``tracer=None`` (the default) costs one None check.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-max(0, n_tokens) // page_size)


@dataclasses.dataclass
class PagedKVAllocator:
    """Free-list page allocator over one page arena shared by all layers."""

    n_pages: int
    page_size: int
    max_pages_per_seq: int
    tracer: Any = dataclasses.field(default=None, repr=False, compare=False)

    def _trace(self, event: str, **args) -> None:
        if self.tracer is None:
            return
        from repro.obs import trace as otrace
        self.tracer.instant(event, cat="alloc", tid=otrace.TID_ALLOC,
                            free=len(self._free), used=self.used_pages,
                            held=len(self._held), **args)

    def __post_init__(self):
        if self.n_pages < 1:
            raise ValueError("paged cache needs at least one page; raise "
                             "hbm_bytes or shrink the model/page size")
        # LIFO free list: a just-freed page is the next handed out, so tests
        # can observe reuse deterministically and the hot arena stays small.
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}      # slot -> page ids
        self._held: List[int] = []                   # withheld (see hold_pages)

    # -- capacity accounting ----------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_pages / self.n_pages

    @property
    def capacity_tokens(self) -> int:
        return self.n_pages * self.page_size

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._tables.get(slot, ()))

    def can_admit(self, n_tokens: int) -> bool:
        need = pages_for(n_tokens, self.page_size)
        return need <= len(self._free) and need <= self.max_pages_per_seq

    # -- alloc / free ------------------------------------------------------
    def alloc_slot(self, slot: int, n_tokens: int) -> Optional[List[int]]:
        """Pages covering positions [0, n_tokens) for a fresh request, or
        None when the arena (or the per-request table) cannot hold it."""
        if slot in self._tables:
            raise ValueError(f"slot {slot} already holds pages; free first")
        need = pages_for(n_tokens, self.page_size)
        if need > self.max_pages_per_seq or need > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(need)]
        self._tables[slot] = pages
        self._trace("alloc", slot=slot, pages=need)
        return list(pages)

    def grow_slot(self, slot: int, n_tokens: int) -> Optional[List[int]]:
        """Extend a slot's table to cover logical positions [0, n_tokens):
        allocates the missing pages (chunked prefill's per-chunk commitment
        point). Returns the newly allocated page ids ([] when the slot
        already covers them), or None when the arena or the per-sequence
        cap cannot hold them -- in which case NOTHING is allocated, so the
        scheduler can evict and retry atomically."""
        pages = self._tables.get(slot)
        if pages is None:
            raise ValueError(f"slot {slot} holds no pages")
        need = pages_for(n_tokens, self.page_size) - len(pages)
        if need <= 0:
            return []
        if len(pages) + need > self.max_pages_per_seq or need > len(self._free):
            return None
        new = [self._free.pop() for _ in range(need)]
        pages.extend(new)
        self._trace("extend", slot=slot, pages=need)
        return new

    def extend_slot(self, slot: int) -> Optional[int]:
        """One more page for a growing request (decode crossed a page
        boundary); None when the arena is exhausted or the request is at
        ``max_pages_per_seq`` (its context limit)."""
        pages = self._tables.get(slot)
        if pages is None:
            raise ValueError(f"slot {slot} holds no pages")
        if len(pages) >= self.max_pages_per_seq or not self._free:
            return None
        pid = self._free.pop()
        pages.append(pid)
        self._trace("extend", slot=slot, pages=1)
        return pid

    def free_slot(self, slot: int) -> int:
        """Return the slot's pages to the arena; returns how many."""
        pages = self._tables.pop(slot, [])
        self._free.extend(reversed(pages))
        if pages:
            self._trace("evict", slot=slot, pages=len(pages))
        return len(pages)

    # -- pressure / reservation -------------------------------------------
    @property
    def held_pages(self) -> int:
        return len(self._held)

    def hold_pages(self, k: int) -> int:
        """Withhold up to ``k`` free pages from allocation; returns how
        many were actually held (bounded by the free list).

        Held pages count as used -- ``can_admit``/``alloc_slot``/
        ``grow_slot``/``extend_slot`` cannot see them -- which is how the
        fault injector applies *consistent* arena-exhaustion pressure for
        one scheduler iteration: pressure applied mid-iteration (e.g. by
        failing individual allocations) would break the scheduler's
        can_admit-then-alloc commitment protocol. Calls stack; pair with
        :meth:`release_held`.
        """
        k = max(0, min(k, len(self._free)))
        for _ in range(k):
            self._held.append(self._free.pop())
        if k:
            self._trace("hold", pages=k)
        return k

    def release_held(self) -> int:
        """Return every held page to the free list; returns how many."""
        n = len(self._held)
        self._free.extend(reversed(self._held))
        self._held = []
        if n:
            self._trace("release_held", pages=n)
        return n

    # -- defrag ------------------------------------------------------------
    def defrag(self) -> np.ndarray:
        """Compact live pages to the front of the arena.

        Returns the length-``n_pages`` permutation ``perm`` with
        ``perm[old_id] = new_id`` (identity for already-compact arenas);
        the caller must apply it to the device pools
        (``pool[:, :, perm_inverse]``, see ``ServingEngine.defrag``) and
        this allocator rewrites its tables in place. Paging makes defrag
        unnecessary for correctness -- it exists so a long-lived engine can
        shrink its arena (checkpoint/offload the contiguous free tail).

        Held pages (:meth:`hold_pages`) are released first: defrag rebuilds
        the free list wholesale, and a hold surviving it would alias pages
        the rebuild already re-issued. Holds are per-iteration pressure;
        the injector simply re-applies them on the next step.
        """
        self.release_held()
        live = [p for slot in sorted(self._tables)
                for p in self._tables[slot]]
        perm = np.full((self.n_pages,), -1, np.int64)
        for new_id, old_id in enumerate(live):
            perm[old_id] = new_id
        nxt = len(live)
        for old_id in range(self.n_pages):
            if perm[old_id] < 0:
                perm[old_id] = nxt
                nxt += 1
        for slot, pages in self._tables.items():
            self._tables[slot] = [int(perm[p]) for p in pages]
        self._free = list(range(self.n_pages - 1, len(live) - 1, -1))
        self._trace("defrag", live=len(live))
        return perm


def arena_pages(model_cfg, engine_cfg, page_size: int, *,
                budget_fraction: float = 0.5,
                max_pages: int = 4096) -> int:
    """Size the page arena against the config's HBM budget.

    One page costs ``L * 2 (K and V) * page * KVH * D * dtype_bytes``
    across the layer-stacked pools; ``budget_fraction`` of
    ``engine_cfg.hbm_bytes`` goes to the arena (the rest stays for weights
    and activations). ``max_pages`` caps the arena for smoke/CPU runs.
    """
    import jax.numpy as jnp
    dtype_bytes = jnp.dtype(model_cfg.dtype).itemsize
    page_bytes = (model_cfg.n_layers * 2 * page_size * model_cfg.n_kv_heads
                  * model_cfg.head_dim * dtype_bytes)
    budget = int(engine_cfg.hbm_bytes * budget_fraction)
    return max(1, min(max_pages, budget // max(1, page_bytes)))
