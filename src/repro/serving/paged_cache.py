"""Paged KV-cache allocator: fixed-size blocks, per-request block tables.

The host-side half of the serving engine's memory system. Device state (the
page pools) lives in :class:`repro.models.transformer.PagedDecodeState`;
this module owns the *accounting*: which pool pages are free, which belong
to which decode slot, and whether a new request fits under the HBM budget
the :class:`~repro.core.config.GemminiConfig` grants long-lived state
(``hbm_bytes``). Paging exists precisely so that budget is spent on tokens
actually cached, not on max-context-sized contiguous reservations: a
request holds ``ceil(len / page_size)`` pages, never ``max_context``.

Invariants the engine relies on:

* page ids handed out are always in ``[0, n_pages)`` -- id ``n_pages`` is
  the reserved trash page retired decode slots spill to, and the allocator
  never owns it;
* every page is owned by exactly one of {the free list, the mapped set,
  the held set}; a *mapped* page is referenced by one or more slot tables
  and/or the prefix index, with ``refcount == total references >= 1``
  (copy-on-write prefix sharing is the only way a page lands in more than
  one table);
* ``free_slot`` decrefs the slot's pages and makes the unreferenced ones
  immediately reusable (eviction IS the preemption mechanism: the
  scheduler frees a victim's pages and re-queues it for recompute); pages
  still referenced -- shared CoW mappings or prefix-index entries --
  survive the eviction, which is what makes hot prefixes cheap to restart.

Two page-lifecycle extensions (both off unless the engine opts in):

* **prefix index** (:meth:`publish_prefix` / :meth:`match_prefix` /
  :meth:`alloc_slot_shared`): full pages are content-hash-indexed at
  prefill commit; a new request whose prompt chain-hashes to indexed
  pages maps them copy-on-write instead of recomputing them. Index-only
  pages (refcount 1, no table) are *reclaimable*: capacity checks count
  them as available and allocation evicts them LRU-first when the free
  list runs short, so the cache can never wedge admission.
* **host pool** (:meth:`host_put` / :meth:`host_peek` / :meth:`host_take`):
  a preempted victim's committed pages spill to a bounded host-memory
  pool (the engine owns the device->host copies; this class owns the
  accounting and LRU bound), so restart is a DMA restore plus a resumed
  chunk instead of a full re-prefill. A full pool evicts its LRU spill --
  degrading that victim to today's recompute path, never failing.

With a :class:`repro.obs.trace.Tracer` attached (``tracer``; the engine
wires its own in), every accounting transition — alloc / grow / extend /
free / hold / release / defrag / cow / publish / spill / restore — lands
as a ``cat="alloc"`` instant on the allocator track, stamped with the
arena occupancy after the transition.  ``tracer=None`` (the default) costs
one None check.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-max(0, n_tokens) // page_size)


@dataclasses.dataclass
class HostSpill:
    """One preempted request's committed pages, parked in host memory.

    ``payload`` is opaque to the allocator: the engine stores whatever
    host arrays reconstruct the device state (KV page contents, recurrent
    slot state), keyed however it likes. ``tokens`` is the committed
    cache-position count the payload covers -- the restart anchor."""

    rid: int
    n_pages: int
    tokens: int
    payload: Dict[str, Any] = dataclasses.field(repr=False)


@dataclasses.dataclass
class PagedKVAllocator:
    """Free-list page allocator over one page arena shared by all layers.

    ``host_pool_pages``: capacity (in pages) of the host spill pool for
    preempted-victim offload; 0 (the default) disables offload entirely
    (:meth:`host_put` refuses every spill)."""

    n_pages: int
    page_size: int
    max_pages_per_seq: int
    tracer: Any = dataclasses.field(default=None, repr=False, compare=False)
    host_pool_pages: int = 0

    def _trace(self, event: str, **args) -> None:
        if self.tracer is None:
            return
        from repro.obs import trace as otrace
        self.tracer.instant(event, cat="alloc", tid=otrace.TID_ALLOC,
                            free=len(self._free), used=self.used_pages,
                            held=len(self._held), **args)

    def __post_init__(self):
        if self.n_pages < 1:
            raise ValueError("paged cache needs at least one page; raise "
                             "hbm_bytes or shrink the model/page size")
        # LIFO free list: a just-freed page is the next handed out, so tests
        # can observe reuse deterministically and the hot arena stays small.
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}      # slot -> page ids
        self._held: List[int] = []                   # withheld (see hold_pages)
        # refcounts for mapped pages: #tables referencing the page, plus 1
        # if the prefix index holds it. Free/held pages have no entry.
        self._ref: Dict[int, int] = {}
        # content-hash prefix index: chain key -> physical page, insertion
        # order = LRU (match/publish refresh via move_to_end), plus the
        # page -> key reverse map so defrag and reclaim stay O(live).
        self._prefix: "collections.OrderedDict[bytes, int]" = \
            collections.OrderedDict()
        self._page_key: Dict[int, bytes] = {}
        # host offload pool: rid -> HostSpill, insertion order = LRU.
        self._host: "collections.OrderedDict[int, HostSpill]" = \
            collections.OrderedDict()

    # -- capacity accounting ----------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_pages / self.n_pages

    @property
    def capacity_tokens(self) -> int:
        return self.n_pages * self.page_size

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._tables.get(slot, ()))

    def _reclaimable(self) -> int:
        """Index-only pages (refcount 1, no table): evictable on demand,
        so capacity checks count them as available."""
        return sum(1 for p in self._prefix.values() if self._ref.get(p) == 1)

    def _reclaim(self, need_free: int) -> int:
        """Evict LRU index-only prefix pages until the free list holds at
        least ``need_free`` pages (or nothing reclaimable remains).
        Returns how many were reclaimed. Pages some table still references
        (refcount > 1) are never touched -- eviction refuses to split a
        shared physical page."""
        n = 0
        if len(self._free) >= need_free:
            return 0
        for key in list(self._prefix):             # OrderedDict: LRU first
            if len(self._free) >= need_free:
                break
            p = self._prefix[key]
            if self._ref.get(p) != 1:
                continue                           # mapped by a table too
            del self._prefix[key]
            del self._page_key[p]
            del self._ref[p]
            self._free.append(p)
            n += 1
        if n:
            self._trace("reclaim", pages=n)
        return n

    def can_admit(self, n_tokens: int) -> bool:
        need = pages_for(n_tokens, self.page_size)
        return (need <= len(self._free) + self._reclaimable()
                and need <= self.max_pages_per_seq)

    # -- alloc / free ------------------------------------------------------
    def _take_free(self, k: int) -> List[int]:
        """Pop ``k`` free pages (refcounted at 1). Caller checked capacity."""
        pages = [self._free.pop() for _ in range(k)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def alloc_slot(self, slot: int, n_tokens: int) -> Optional[List[int]]:
        """Pages covering positions [0, n_tokens) for a fresh request, or
        None when the arena (or the per-request table) cannot hold it."""
        if slot in self._tables:
            raise ValueError(f"slot {slot} already holds pages; free first")
        need = pages_for(n_tokens, self.page_size)
        self._reclaim(need)
        if need > self.max_pages_per_seq or need > len(self._free):
            return None
        pages = self._take_free(need)
        self._tables[slot] = pages
        self._trace("alloc", slot=slot, pages=need)
        return list(pages)

    def alloc_slot_shared(self, slot: int, n_tokens: int,
                          shared: Sequence[int]) -> Optional[List[int]]:
        """:meth:`alloc_slot`, but the first ``len(shared)`` pages are
        existing physical pages (a :meth:`match_prefix` run) mapped
        copy-on-write: increfed, not popped. Atomic -- on failure nothing
        is allocated and no refcount moves. The caller must never write
        the shared prefix pages through this slot (its fresh writes start
        at ``len(shared) * page_size``)."""
        if slot in self._tables:
            raise ValueError(f"slot {slot} already holds pages; free first")
        need = pages_for(n_tokens, self.page_size)
        fresh = need - len(shared)
        if fresh < 0:
            raise ValueError(f"{len(shared)} shared pages exceed the "
                             f"{need}-page footprint of {n_tokens} tokens")
        # Incref the shared run FIRST: a cache-only hit page (refcount 1)
        # must not be evicted by the reclaim scan below.
        for p in shared:
            self._ref[p] = self._ref.get(p, 0) + 1
        self._reclaim(fresh)
        if need > self.max_pages_per_seq or fresh > len(self._free):
            for p in shared:                        # undo: nothing happened
                self._ref[p] -= 1
            return None
        pages = list(shared) + self._take_free(fresh)
        self._tables[slot] = pages
        self._trace("cow", slot=slot, shared=len(shared), fresh=fresh)
        return list(pages)

    def grow_slot(self, slot: int, n_tokens: int) -> Optional[List[int]]:
        """Extend a slot's table to cover logical positions [0, n_tokens):
        allocates the missing pages (chunked prefill's per-chunk commitment
        point). Returns the newly allocated page ids ([] when the slot
        already covers them), or None when the arena or the per-sequence
        cap cannot hold them -- in which case NOTHING is allocated, so the
        scheduler can evict and retry atomically."""
        pages = self._tables.get(slot)
        if pages is None:
            raise ValueError(f"slot {slot} holds no pages")
        need = pages_for(n_tokens, self.page_size) - len(pages)
        if need <= 0:
            return []
        self._reclaim(need)
        if len(pages) + need > self.max_pages_per_seq or need > len(self._free):
            return None
        new = self._take_free(need)
        pages.extend(new)
        self._trace("extend", slot=slot, pages=need)
        return new

    def extend_slot(self, slot: int) -> Optional[int]:
        """One more page for a growing request (decode crossed a page
        boundary); None when the arena is exhausted or the request is at
        ``max_pages_per_seq`` (its context limit)."""
        pages = self._tables.get(slot)
        if pages is None:
            raise ValueError(f"slot {slot} holds no pages")
        if len(pages) >= self.max_pages_per_seq:
            return None
        self._reclaim(1)
        if not self._free:
            return None
        pid = self._take_free(1)[0]
        pages.append(pid)
        self._trace("extend", slot=slot, pages=1)
        return pid

    def free_slot(self, slot: int) -> int:
        """Release the slot's table: decref every page, return the
        now-unreferenced ones to the arena. Pages another table or the
        prefix index still references survive (CoW sharing / cache-only
        retention). Returns the table length."""
        pages = self._tables.pop(slot, [])
        freed: List[int] = []
        for p in reversed(pages):                  # keep LIFO reuse order
            r = self._ref.get(p, 1) - 1
            if r <= 0:
                self._ref.pop(p, None)
                freed.append(p)
            else:
                self._ref[p] = r
        self._free.extend(freed)
        if pages:
            self._trace("evict", slot=slot, pages=len(pages),
                        retained=len(pages) - len(freed))
        return len(pages)

    # -- prefix index (content-hash CoW sharing) ---------------------------
    def match_prefix(self, keys: Sequence[bytes]) -> List[int]:
        """The longest index-hit run for a chain-key sequence: physical
        pages a new request can map copy-on-write instead of recomputing.
        Hits are LRU-refreshed (a hot prefix stays resident)."""
        out: List[int] = []
        for k in keys:
            p = self._prefix.get(k)
            if p is None:
                break
            out.append(p)
        for k in keys[:len(out)]:
            self._prefix.move_to_end(k)
        return out

    def publish_prefix(self, key: bytes, page: int) -> bool:
        """Index a committed full page under its content chain key
        (increfs it: the index is an owner, so eviction can never free an
        indexed page out from under a future match). Re-publishing an
        existing key refreshes its LRU slot; a page already indexed under
        another key -- or a key another physical page already claimed --
        is left alone (first publication wins). Returns True when this
        call newly indexed the page."""
        if self._prefix.get(key) is not None:
            self._prefix.move_to_end(key)
            return False
        if page in self._page_key:
            return False
        if self._ref.get(page, 0) < 1:
            raise ValueError(f"page {page} is not mapped; only committed "
                             f"slot pages can be published")
        self._prefix[key] = page
        self._page_key[page] = key
        self._ref[page] += 1
        self._trace("publish", page=page, index=len(self._prefix))
        return True

    @property
    def prefix_index_pages(self) -> int:
        return len(self._prefix)

    # -- host offload pool -------------------------------------------------
    @property
    def host_used_pages(self) -> int:
        return sum(s.n_pages for s in self._host.values())

    def host_put(self, rid: int, n_pages: int, tokens: int,
                 payload: Dict[str, Any]) -> bool:
        """Park a preempted victim's committed pages in the host pool.
        Evicts LRU spills to fit (those victims degrade to recompute);
        refuses -- returning False, caller falls back to recompute -- when
        the pool is disabled or the spill alone exceeds its capacity."""
        if self.host_pool_pages <= 0 or n_pages > self.host_pool_pages:
            return False
        self._host.pop(rid, None)                  # re-spill replaces
        while self.host_used_pages + n_pages > self.host_pool_pages:
            self.host_evict_lru()
        self._host[rid] = HostSpill(rid=rid, n_pages=n_pages, tokens=tokens,
                                    payload=payload)
        self._trace("spill", rid=rid, pages=n_pages,
                    host_used=self.host_used_pages)
        return True

    def host_evict_lru(self) -> Optional[int]:
        """Evict the least-recently-spilled host-pool entry (its victim
        degrades to recompute on restart). ``host_put`` calls this under
        capacity pressure; the model checker calls it directly as an
        explicit action. Returns the evicted rid, or None on an empty
        pool."""
        if not self._host:
            return None
        old_rid, old = self._host.popitem(last=False)
        self._trace("spill_evict", rid=old_rid, pages=old.n_pages)
        return old_rid

    def host_peek(self, rid: int) -> Optional[HostSpill]:
        return self._host.get(rid)

    def host_take(self, rid: int) -> Optional[HostSpill]:
        """Pop a spill for restore (the payload moves back to the device;
        the pool entry is consumed either way)."""
        sp = self._host.pop(rid, None)
        if sp is not None:
            self._trace("restore", rid=rid, pages=sp.n_pages,
                        host_used=self.host_used_pages)
        return sp

    def host_drop(self, rid: int) -> None:
        """Discard a spill (restore failed / victim finished elsewhere)."""
        self._host.pop(rid, None)

    # -- pressure / reservation -------------------------------------------
    @property
    def held_pages(self) -> int:
        return len(self._held)

    def hold_pages(self, k: int) -> int:
        """Withhold up to ``k`` free pages from allocation; returns how
        many were actually held (bounded by the free list).

        Held pages count as used -- ``can_admit``/``alloc_slot``/
        ``grow_slot``/``extend_slot`` cannot see them -- which is how the
        fault injector applies *consistent* arena-exhaustion pressure for
        one scheduler iteration: pressure applied mid-iteration (e.g. by
        failing individual allocations) would break the scheduler's
        can_admit-then-alloc commitment protocol. Calls stack; pair with
        :meth:`release_held`. Holds come from the free list only --
        reclaimable prefix pages stay where they are, so pressure cannot
        silently flush the prefix cache.
        """
        k = max(0, min(k, len(self._free)))
        for _ in range(k):
            self._held.append(self._free.pop())
        if k:
            self._trace("hold", pages=k)
        return k

    def release_held(self) -> int:
        """Return every held page to the free list; returns how many."""
        n = len(self._held)
        self._free.extend(reversed(self._held))
        self._held = []
        if n:
            self._trace("release_held", pages=n)
        return n

    # -- defrag ------------------------------------------------------------
    def defrag(self) -> np.ndarray:
        """Compact live pages to the front of the arena.

        Returns the length-``n_pages`` permutation ``perm`` with
        ``perm[old_id] = new_id`` (identity for already-compact arenas);
        the caller must apply it to the device pools
        (``pool[:, :, perm_inverse]``, see ``ServingEngine.defrag``) and
        this allocator rewrites its tables -- and the prefix index, whose
        entries are live pages too -- in place. A physical page shared by
        several tables moves exactly once (one perm slot), so CoW aliases
        survive compaction intact. Paging makes defrag unnecessary for
        correctness -- it exists so a long-lived engine can shrink its
        arena (checkpoint/offload the contiguous free tail).

        Held pages (:meth:`hold_pages`) are released first: defrag rebuilds
        the free list wholesale, and a hold surviving it would alias pages
        the rebuild already re-issued. Holds are per-iteration pressure;
        the injector simply re-applies them on the next step.
        """
        self.release_held()
        live: List[int] = []
        seen: set = set()
        for slot in sorted(self._tables):
            for p in self._tables[slot]:
                if p not in seen:                  # shared pages move once
                    seen.add(p)
                    live.append(p)
        for p in self._prefix.values():
            if p not in seen:                      # index-only residents
                seen.add(p)
                live.append(p)
        perm = np.full((self.n_pages,), -1, np.int64)
        for new_id, old_id in enumerate(live):
            perm[old_id] = new_id
        nxt = len(live)
        for old_id in range(self.n_pages):
            if perm[old_id] < 0:
                perm[old_id] = nxt
                nxt += 1
        for slot, pages in self._tables.items():
            self._tables[slot] = [int(perm[p]) for p in pages]
        self._prefix = collections.OrderedDict(
            (k, int(perm[p])) for k, p in self._prefix.items())
        self._page_key = {int(perm[p]): k for p, k in self._page_key.items()}
        self._ref = {int(perm[p]): r for p, r in self._ref.items()}
        self._free = list(range(self.n_pages - 1, len(live) - 1, -1))
        self._trace("defrag", live=len(live))
        return perm

    # -- invariant audit ---------------------------------------------------
    def check(self) -> None:
        """Assert the ownership-partition invariants (the property suite's
        oracle; cheap enough to call after every op in tests):

        * free list / mapped set / held set partition ``[0, n_pages)``
          exactly (no page lost, duplicated, or resurrected);
        * a slot table never maps the same physical page twice (sharing is
          *across* tables, never within one);
        * every mapped page's refcount equals its reference count
          (#tables holding it + 1 if indexed) and is >= 1;
        * the prefix index and its reverse map agree bijectively;
        * free-page accounting is exact.
        """
        fset = set(self._free)
        assert len(fset) == len(self._free), "free-list duplicates"
        held = set(self._held)
        assert len(held) == len(self._held), "held-list duplicates"
        counts: collections.Counter = collections.Counter()
        for slot, pages in self._tables.items():
            assert len(pages) == len(set(pages)), \
                f"slot {slot} maps a page twice"
            counts.update(pages)
        for p in self._prefix.values():
            counts[p] += 1
        mapped = set(counts)
        assert not (fset & mapped), "free pages still mapped"
        assert not (fset & held), "free pages also held"
        assert not (held & mapped), "held pages still mapped"
        assert fset | mapped | held == set(range(self.n_pages)), \
            "arena pages lost"
        assert dict(counts) == self._ref, "refcount drift"
        assert all(r >= 1 for r in self._ref.values()), "mapped page ref<1"
        assert len(self._prefix) == len(self._page_key) and all(
            self._prefix[k] == p for p, k in self._page_key.items()), \
            "prefix index / reverse map disagree"
        assert len(self._free) == self.n_pages - len(mapped) - len(held), \
            "free-page accounting drift"
        assert self.host_used_pages <= max(0, self.host_pool_pages), \
            "host pool over capacity"

    def snapshot(self) -> Dict[str, Any]:
        """Order-faithful structural view of the allocator, for canonical
        state hashing (repro.analysis.mc.canon): slot tables in slot
        order, refcounts, the prefix index in LRU order, held pages, the
        free list in POP order (LIFO: the next page issued comes first),
        and host-pool entries in LRU order. Physical page ids appear
        as-is; the canonicalizer relabels them by traversal order so two
        states differing only by page naming hash identically."""
        return {
            "tables": {s: list(p) for s, p in sorted(self._tables.items())},
            "ref": dict(self._ref),
            "prefix": [(k, p) for k, p in self._prefix.items()],
            "held": list(self._held),
            "free_pop_order": list(reversed(self._free)),
            "host": [(rid, sp.n_pages, sp.tokens)
                     for rid, sp in self._host.items()],
        }


def arena_pages(model_cfg, engine_cfg, page_size: int, *,
                budget_fraction: float = 0.5,
                max_pages: int = 4096) -> int:
    """Size the page arena against the config's HBM budget.

    One page costs ``L * 2 (K and V) * page * KVH * D * dtype_bytes``
    across the layer-stacked pools; ``budget_fraction`` of
    ``engine_cfg.hbm_bytes`` goes to the arena (the rest stays for weights
    and activations). ``max_pages`` caps the arena for smoke/CPU runs.
    """
    import jax.numpy as jnp
    dtype_bytes = jnp.dtype(model_cfg.dtype).itemsize
    page_bytes = (model_cfg.n_layers * 2 * page_size * model_cfg.n_kv_heads
                  * model_cfg.head_dim * dtype_bytes)
    budget = int(engine_cfg.hbm_bytes * budget_fraction)
    return max(1, min(max_pages, budget // max(1, page_bytes)))
