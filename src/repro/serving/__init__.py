"""Continuous-batching serving engine over paged KV caches.

The system layer above the kernel library (ROADMAP north star): request
admission, prefill/decode interleaving, paged cache memory management, and
preemption under pressure -- the shared-resource contention the paper
argues accelerator evaluation must include.

* :mod:`repro.serving.paged_cache` -- fixed-size-block KV allocator
  (alloc/free/defrag, capacity accounting vs ``GemminiConfig.hbm_bytes``,
  refcounted copy-on-write prefix index, LRU host offload pool);
* :mod:`repro.serving.scheduler`   -- admission queue, token-budget
  chunk-queue prefill/decode interleave (chunked prefill),
  preemption-by-eviction, TTFT/ITL telemetry;
* :mod:`repro.serving.engine`      -- ``ServingEngine``: the jitted paged
  model steps + the policy loop (``policy="continuous" | "static"``,
  ``prefill_chunk`` for chunked prefill).
"""

from repro.serving.engine import ServingEngine
from repro.serving.paged_cache import (HostSpill, PagedKVAllocator,
                                       arena_pages, pages_for)
from repro.serving.scheduler import (ContinuousScheduler, PrefillChunk,
                                     Request, summarize)

__all__ = ["ContinuousScheduler", "HostSpill", "PagedKVAllocator",
           "PrefillChunk", "Request", "ServingEngine", "arena_pages",
           "pages_for", "summarize"]
