"""Continuous-batching scheduler: admission, interleave, preemption.

The request-level control loop the paper's full-stack argument calls for:
kernel quality only matters under the contention a real serving mix
creates, and this module is where that mix is shaped. Policy, in order of
application each engine iteration:

1. **Admission / prefill** (the chunk queue): queued requests are admitted
   into free decode slots in *admission-policy* order -- FIFO by default,
   or the ``priority`` / ``deadline`` (EDF) SLO-aware orders, preempted
   requests always first (see ``_order_queue``) -- as long as (a) a slot
   is free,
   (b) the paged allocator can hold the request, and (c) the iteration's
   *prefill token budget* is not exhausted. The budget is the classic
   continuous-batching knob balancing time-to-first-token of queued
   requests against inter-token latency of running ones: each admitted
   prompt stalls every running request for one prefill pass. With
   **chunked prefill** (``prefill_chunk``), long prompts split into
   fixed-size spans executed one-or-more per iteration under the same
   budget -- continuation chunks for mid-prefill runners go first, then
   new admissions -- so a single long prompt can no longer stall running
   decodes for a whole prefill pass (bounded TTFT *and* ITL; the paper's
   system-level contention argument at its sharpest).
2. **Decode capacity** (preemption-by-eviction): every running request
   about to cross a page boundary gets one page; when the arena is dry the
   *youngest* running request is evicted -- its pages freed, the request
   re-queued for recompute (prompt + tokens generated so far become the
   new prompt). Youngest-first eviction wastes the least completed work,
   and the oldest request can always make progress, so the loop is
   livelock-free. A request that hits its per-sequence page cap is
   finished as truncated instead (its context limit, not memory pressure).
3. **Decode**: one token for every running slot (the engine's single
   static-shape ``paged_decode_step``).

With ``enforce_deadlines=True`` the scheduler additionally *sheds* any
request whose absolute deadline has passed -- terminal ``deadline_missed``
status at the admission and decode-step boundaries (:meth:`shed_expired`)
-- so expired SLOs stop consuming prefill/decode budget. Off by default:
``admission_policy="deadline"`` without enforcement remains a pure
ordering policy (PR 5 behavior).

Telemetry is per-request (TTFT, end-to-end latency, preemption count) and
aggregated to the p50/p99 + tokens/s numbers BENCH_serving.json tracks.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.paged_cache import PagedKVAllocator, pages_for


@dataclasses.dataclass
class Request:
    """One serving request plus its runtime bookkeeping."""

    rid: int
    prompt: np.ndarray                    # (P,) int32 [or (P, n_q)]
    max_new_tokens: int
    eos_id: int = -1                      # -1: never emitted
    # admission-policy inputs (ignored under FIFO): higher priority admits
    # first; deadline is an absolute time.time() SLO timestamp (None =
    # best-effort, sorts after every deadlined request).
    priority: int = 0
    deadline: Optional[float] = None

    # runtime (engine/scheduler owned)
    state: str = "queued"                 # queued | running | finished | shed
    slot: int = -1
    generated: list = dataclasses.field(default_factory=list)
    cache_len: int = 0                    # cached tokens (prompt+meta+gen)
    n_preempted: int = 0
    truncated: bool = False
    submitted_at: float = 0.0
    queued_since: float = 0.0             # start of the CURRENT queue wait
    admitted_seq: int = -1                # admission order (eviction key)
    t_first_token: Optional[float] = None
    t_last_token: Optional[float] = None
    t_finished: Optional[float] = None
    # chunked-prefill progress (cache positions written so far / needed);
    # target 0 means single-pass prefill (never observably "prefilling")
    prefill_pos: int = 0
    prefill_target: int = 0
    n_chunks: int = 0                     # prefill chunk calls executed
    # chunk-lattice anchor: the cache position prefill resumed from after
    # a host-pool restore or a prefix-cache hit (0 = the classic lattice
    # from position 0). Reset on preemption -- a fresh restart re-decides.
    chunk_anchor: int = 0
    # per-admission cache of the prompt's page-granular content chain keys
    # (prefix cache); invalidated on preemption (serve_prompt grows)
    prefix_keys: Optional[List[bytes]] = None
    itl_s: list = dataclasses.field(default_factory=list)
    # terminal-shed bookkeeping (state == "shed"): why the scheduler
    # dropped it ("deadline_missed" is the only producer today)
    shed_reason: Optional[str] = None

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    @property
    def prefilling(self) -> bool:
        """Running but not yet fully prefilled: the slot holds pages and
        (for recurrent families) carried state, but must not decode --
        the engine keeps it out of the decode active mask."""
        return self.state == "running" and self.prefill_pos < self.prefill_target

    def serve_prompt(self) -> np.ndarray:
        """What prefill must (re)compute: the original prompt plus anything
        generated before a preemption (recompute-style restart)."""
        if not self.generated:
            return self.prompt
        return np.concatenate([self.prompt, np.asarray(self.generated,
                                                       self.prompt.dtype)])


@dataclasses.dataclass
class PrefillChunk:
    """One unit of prefill work the scheduler hands the engine.

    Spans are in *cache-position* space (meta tokens ride in the first
    chunk): this chunk writes positions [start, padded_end), of which
    [start, true_end) are real tokens and the rest bucket padding (last
    chunk of attention-only families; recurrent families never pad).
    ``first and last`` means single-span -- the classic whole-prompt
    prefill path, byte-for-byte the pre-chunking behavior.

    ``kv_pages``: STATIC bound on block-table entries that can ever hold
    this request's live keys (its whole padded prompt footprint in pages
    -- every chunk frontier lives inside it). The scheduler owns it so
    the padding policy has one owner; the engine passes it verbatim to
    the gather attention (dead-key elision; 0 = unbounded)."""

    req: Request
    slot: int
    start: int
    true_end: int
    padded_end: int
    first: bool
    last: bool
    kv_pages: int = 0


class ContinuousScheduler:
    """Slot/page bookkeeping + the three-phase policy above.

    The scheduler is deliberately device-free: it sees token counts and the
    allocator, never arrays, so its decisions are unit-testable without a
    model. The engine executes the actions it returns.
    """

    ADMISSION_POLICIES = ("fifo", "priority", "deadline")

    def __init__(self, allocator: PagedKVAllocator, n_slots: int, *,
                 prefill_token_budget: int = 512,
                 extra_tokens_per_prefill: int = 0,
                 pad_to: int = 1,
                 prefill_chunk: Optional[int] = None,
                 admission_policy: str = "fifo",
                 enforce_deadlines: bool = False,
                 clock: Optional[Callable[[], float]] = None,
                 tracer=None, metrics=None,
                 offload: bool = False,
                 prefix_cache: bool = False,
                 spill_fn: Optional[Callable] = None,
                 restore_fn: Optional[Callable] = None):
        if admission_policy not in self.ADMISSION_POLICIES:
            raise ValueError(f"unknown admission_policy "
                             f"{admission_policy!r}; have "
                             f"{self.ADMISSION_POLICIES}")
        self.alloc = allocator
        self.n_slots = n_slots
        self.prefill_token_budget = prefill_token_budget
        # meta tokens (hymba) ride along with every prefill's cache cost
        self.extra_tokens = extra_tokens_per_prefill
        # the engine bucket-pads prompts (compile caching), so admission
        # must charge the padded cache footprint, not the raw prompt
        self.pad_to = pad_to
        # chunked prefill: split prompts into prefill_chunk-position spans
        # interleaved with decode steps (None/0 = single-pass). Must exceed
        # the meta-token count (the first chunk carries them).
        if prefill_chunk:
            prefill_chunk = max(prefill_chunk, extra_tokens_per_prefill + 1)
        self.prefill_chunk = prefill_chunk or None
        # admission order: "fifo" admits in submission order (unchanged
        # default); "priority"/"deadline" re-sort the queue each
        # iteration (the SLO-aware policy drop-in the scheduler was
        # designed for -- see _order_queue).
        self.admission_policy = admission_policy
        # SLO *enforcement* (off by default -- "deadline" as a pure
        # admission ORDER stays available without it): when on, a request
        # whose absolute deadline has passed is shed -- terminal
        # "deadline_missed" status, pages freed -- at the admission and
        # decode-step boundaries (shed_expired) instead of consuming
        # prefill/decode budget to produce tokens nobody can use.
        self.enforce_deadlines = enforce_deadlines
        # Monotonic by default: wall clocks (time.time) can step backwards
        # under NTP and corrupt every TTFT/ITL/latency duration. Deadlines
        # are absolute timestamps in THIS clock's domain (engine.now()).
        self.clock = clock or time.monotonic
        # Observability hooks (both optional, engine-wired): a
        # repro.obs.trace.Tracer receiving lifecycle events on each
        # request's track, and a repro.obs.metrics.MetricsRegistry
        # receiving transition counters.
        self.tracer = tracer
        self.metrics = metrics
        # KV-lifecycle hooks (docs/serving.md#kv-lifecycle; engine-wired,
        # both off by default). ``offload``: a preempted victim's committed
        # pages spill to the allocator's host pool (``spill_fn``) and a
        # re-admission restores them (``restore_fn``) instead of
        # recomputing from chunk 0; either hook returning falsy degrades
        # that victim to the classic recompute restart. ``prefix_cache``:
        # admission content-hashes the prompt at page granularity and maps
        # already-materialized prefix pages copy-on-write, skipping their
        # prefill chunks.
        self.offload = offload
        self.prefix_cache = prefix_cache
        self.spill_fn = spill_fn
        self.restore_fn = restore_fn
        self.queue: List[Request] = []
        self.running: Dict[int, Request] = {}          # slot -> request
        self.rejected: List[Request] = []              # engine drains these
        self._admit_seq = 0

    # -- observability -----------------------------------------------------
    def _count(self, name: str, n: int = 1) -> None:
        if self.metrics is not None and n:
            self.metrics.counter(name).inc(n)

    def _event(self, req: Request, name: str, **args) -> None:
        if self.tracer is None:
            return
        from repro.obs import trace as otrace
        self.tracer.instant(name, cat="request",
                            tid=otrace.req_tid(req.rid), **args)

    def _note_admitted(self, req: Request) -> None:
        """Close the request's queued span and count the admission."""
        self._count("admissions")
        if self.tracer is None:
            return
        from repro.obs import trace as otrace
        self.tracer.complete("queued", req.queued_since or req.submitted_at,
                             cat="request", tid=otrace.req_tid(req.rid),
                             slot=req.slot, attempt=req.n_preempted + 1)

    def _prefill_need(self, req: Request) -> int:
        plen = len(req.serve_prompt())
        return -(-plen // self.pad_to) * self.pad_to + self.extra_tokens

    def _kv_pages(self, req: Request) -> int:
        """Static live-key page bound for ``req``'s gather attention: the
        pages its whole padded prompt will ever occupy (>= every chunk's
        ``padded_end``), capped at the per-sequence table width."""
        return min(self.alloc.max_pages_per_seq,
                   pages_for(self._prefill_need(req), self.alloc.page_size))

    def _chunk_spans(self, req: Request,
                     anchor: int = 0) -> List[Tuple[int, int, int]]:
        """(start, true_end, padded_end) spans covering prompt + meta in
        cache-position space. Single span (the classic path) when chunking
        is off or the request fits one chunk; otherwise every span is
        exactly ``prefill_chunk`` long except the last, which is padded to
        the engine's compile bucket (``pad_to``; 1 for recurrent families,
        whose scan state must never absorb padding).

        ``anchor > 0`` starts the lattice at that cache position instead
        of 0: positions [0, anchor) are already materialized (a host-pool
        restore or a prefix-cache CoW run) and must not be recomputed.
        The anchor is arbitrary -- a restored decode victim resumes
        mid-page -- so the anchored lattice is simply spans of
        ``prefill_chunk`` from ``anchor``."""
        total = len(req.serve_prompt()) + self.extra_tokens
        c = self.prefill_chunk
        if not anchor and (not c or total <= c):
            return [(0, total, self._prefill_need(req))]
        # The last span's compile-bucket padding never exceeds the
        # single-pass footprint (roundup of the total): a request that
        # fits the arena unchunked must never out-grow it merely because
        # the chunk size is not page-aligned.
        cap = -(-total // self.pad_to) * self.pad_to
        spans, s = [], anchor
        if anchor and not c:
            # chunking off but a lifecycle feature anchored this request:
            # one continuation span covers the remainder.
            pe = min(s + -(-(total - s) // self.pad_to) * self.pad_to, cap)
            return [(s, total, max(pe, total))]
        while s < total:
            e = min(s + c, total)
            pe = e if e - s == c else \
                min(s + -(-(e - s) // self.pad_to) * self.pad_to, cap)
            spans.append((s, e, max(pe, e)))
            s = e
        return spans

    # -- prefix-cache hashing ---------------------------------------------
    def _prefix_keys(self, req: Request) -> List[bytes]:
        """Page-granular content chain keys for ``req``'s prompt: key ``i``
        digests the whole token prefix covering cache positions
        [0, (i+1) * page_size) -- meta positions (model-constant) are
        seeded into the chain head, so two requests share key ``i`` iff
        their first ``i+1`` cache pages hold identical content. Only pages
        fully covered by TRUE positions get keys (pad- or decode-written
        pages are never content-addressable)."""
        page = self.alloc.page_size
        toks = np.ascontiguousarray(np.asarray(req.serve_prompt(), np.int32))
        total = len(toks) + self.extra_tokens
        h = hashlib.sha256(
            f"kvprefix:v1:{page}:{self.extra_tokens}".encode()).digest()
        keys: List[bytes] = []
        for i in range(total // page):
            lo = max(0, i * page - self.extra_tokens)
            hi = (i + 1) * page - self.extra_tokens
            h = hashlib.sha256(h + toks[lo:hi].tobytes()).digest()
            keys.append(h)
        return keys

    def _req_keys(self, req: Request) -> List[bytes]:
        if req.prefix_keys is None:
            req.prefix_keys = self._prefix_keys(req)
        return req.prefix_keys

    def note_committed(self, req: Request) -> None:
        """Engine hook after a prefill execution: publish content keys for
        every page now fully covered by committed TRUE positions
        (``req.cache_len``). Published pages become CoW candidates for
        later admissions and survive this slot's eviction (the index holds
        a reference). Publication happens strictly post-execution --
        publishing at chunk-emission time would index pages a skipped or
        faulted chunk never wrote."""
        if not self.prefix_cache or req.state != "running":
            return
        pages = self.alloc.slot_pages(req.slot)
        keys = self._req_keys(req)
        n_full = min(req.cache_len // self.alloc.page_size,
                     len(keys), len(pages))
        n = 0
        for i in range(n_full):
            if self.alloc.publish_prefix(keys[i], pages[i]):
                n += 1
        if n:
            self._count("prefix_pages_published", n)

    # -- submission --------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.state = "queued"
        req.submitted_at = req.submitted_at or self.clock()
        req.queued_since = req.submitted_at
        self._event(req, "submitted", prompt_tokens=int(len(req.prompt)))
        self.queue.append(req)

    def _order_queue(self) -> None:
        """Apply the admission policy: re-sort the wait queue in place
        before each admission pass. FIFO is the identity (submission
        order, preempted requests re-inserted at the front by
        :meth:`preempt`). The sorted policies are stable, and preempted
        requests keep absolute precedence under every policy -- they hold
        recompute debt, and re-admitting them first preserves the
        youngest-evicted/oldest-progresses livelock-freedom argument.

        * ``priority``: highest ``Request.priority`` first; deadline then
          submission time break ties.
        * ``deadline``: earliest-deadline-first (EDF); deadline-less
          requests are best-effort and sort last by submission time.

        The final tie-break is the rid: submission timestamps from a fast
        monotonic clock (or an injected logical clock) can collide, and an
        order that depends on sort stability over a queue whose layout
        varies with preemption history is not deterministic across runs.
        """
        if self.admission_policy == "fifo" or len(self.queue) < 2:
            return
        inf = float("inf")

        def key(r: Request):
            dl = r.deadline if r.deadline is not None else inf
            if self.admission_policy == "priority":
                return (r.n_preempted == 0, -r.priority, dl,
                        r.submitted_at, r.rid)
            return (r.n_preempted == 0, dl, r.submitted_at, r.rid)

        self.queue.sort(key=key)

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.running)

    def _free_slots(self) -> List[int]:
        return [s for s in range(self.n_slots) if s not in self.running]

    # -- phase 1: admission ------------------------------------------------
    def admissions(self) -> List[Tuple[Request, int, List[int]]]:
        """(request, slot, pages) to prefill this iteration. Pages are
        allocated here (the commitment point); the engine only executes."""
        out: List[Tuple[Request, int, List[int]]] = []
        budget = self.prefill_token_budget
        self._order_queue()
        free = self._free_slots()
        while self.queue and free:
            req = self.queue[0]
            if self._expired(req):
                # Deadline passed while waiting: shed at admission rather
                # than spend a prefill pass on a missed SLO.
                self.queue.pop(0)
                self.shed(req)
                continue
            need = self._prefill_need(req)
            cap = min(self.alloc.n_pages, self.alloc.max_pages_per_seq)
            if pages_for(need, self.alloc.page_size) > cap:
                # Can NEVER be admitted -- a preempted request regrew past
                # the arena (its recompute prompt includes everything it
                # generated). Reject it instead of head-of-line-blocking
                # the queue forever; the engine finishes it as truncated.
                self.queue.pop(0)
                self.rejected.append(req)
                continue
            if out and need > budget:
                break                      # budget spent; keep FIFO order
            if not self.alloc.can_admit(need):
                break                      # head-of-line blocks: no overtake
            self.queue.pop(0)
            slot = free.pop(0)
            pages = self.alloc.alloc_slot(slot, need)
            assert pages is not None       # can_admit just said yes
            req.state, req.slot = "running", slot
            req.admitted_seq = self._admit_seq
            self._admit_seq += 1
            self.running[slot] = req
            self._note_admitted(req)
            self._count("prefill_tokens",
                        len(req.serve_prompt()) + self.extra_tokens)
            if req.n_preempted:
                self._count("restarts_recomputed")
            budget -= need
            out.append((req, slot, pages))
        return out

    # -- phase 1, chunk-queue form ----------------------------------------
    def prefill_schedule(self, admit_new: bool = True) -> List[PrefillChunk]:
        """The iteration's prefill work as a chunk queue.

        ``admit_new=False`` suppresses pass 2 (new admissions) but still
        emits continuation chunks -- the static policy's group barrier
        blocks admission, never the completion of an in-flight prefill.

        With chunking off this is exactly :meth:`admissions` (each admitted
        request becomes one whole-prompt span). With chunking on, the
        queue is built in two passes under the same prefill token budget
        (charged in true cache positions; the first item always lands so
        prefill can never fully starve):

        1. *continuation chunks* for mid-prefill runners, oldest-admitted
           first -- they hold pages and carried state, so finishing them
           frees capacity soonest. A chunk whose pages cannot be allocated
           evicts the youngest strictly-younger runner and retries; if none
           exists the request stalls this iteration (an older runner will
           free pages), or -- when it is the sole runner -- finishes
           truncated (its prompt outgrew the arena and eviction cannot
           help, the mid-prefill mirror of the sole-runner decode rule).
        2. *admissions*: first chunks for queued requests, FIFO, as long
           as a slot is free, the first chunk's pages fit, and budget
           remains. Unservable requests (recompute prompt regrew past the
           arena) are rejected exactly as in :meth:`admissions`.

        With a KV-lifecycle feature on (``offload`` / ``prefix_cache``),
        pass 2 additionally decides restore-vs-recompute per candidate: a
        host-pool spill restores (all-fresh pages, ``restore_fn`` DMAs the
        payload back, prefill resumes at the committed anchor), a prefix
        match maps the hit pages copy-on-write and prefill starts at the
        hit boundary. Either path emits ONE continuation-style chunk
        (``first=False`` -- the cache below the anchor is live) and
        charges the budget only for positions actually computed. A failed
        restore degrades to the classic recompute admission in place.
        Both features off reduces this loop to the PR-8 behavior exactly.
        """
        if not self.prefill_chunk and not (self.offload
                                           or self.prefix_cache):
            if not admit_new:
                return []
            return [PrefillChunk(req, slot, 0, len(req.serve_prompt())
                                 + self.extra_tokens, self._prefill_need(req),
                                 True, True)
                    for (req, slot, _pages) in self.admissions()]
        out: List[PrefillChunk] = []
        budget = self.prefill_token_budget
        # pass 1: continuation chunks, oldest first
        for req in sorted(list(self.running.values()),
                          key=lambda r: r.admitted_seq):
            while req.state == "running" and req.prefilling:
                if out and budget <= 0:
                    break
                w = self._next_chunk(req)
                if w is None:              # arena pressure
                    if self._evict_younger_than(req):
                        continue
                    if len(self.running) == 1:
                        self.finish(req, truncated=True)
                    break
                budget -= w.true_end - w.start
                self._count("prefill_tokens", w.true_end - w.start)
                out.append(w)
                req.prefill_pos = w.true_end
            if budget <= 0 and out:
                break
        # pass 2: new admissions (first chunks; restore/prefix-aware)
        self._order_queue()
        free = self._free_slots() if admit_new else []
        page = self.alloc.page_size
        while self.queue and free and (budget > 0 or not out):
            req = self.queue[0]
            if self._expired(req):
                self.queue.pop(0)          # shed at admission (see above)
                self.shed(req)
                continue
            need = self._prefill_need(req)
            cap = min(self.alloc.n_pages, self.alloc.max_pages_per_seq)
            if pages_for(need, page) > cap:
                self.queue.pop(0)          # can NEVER be admitted
                self.rejected.append(req)
                continue
            target = len(req.serve_prompt()) + self.extra_tokens
            # restart decision: a spilled victim restores at its committed
            # anchor; otherwise a prefix match anchors at the CoW-hit
            # boundary (capped so at least one position is computed -- the
            # final chunk must produce logits to sample from).
            spill = self.alloc.host_peek(req.rid) if self.offload else None
            anchor = int(spill.tokens) if spill is not None else 0
            hits: List[int] = []
            if not anchor and self.prefix_cache:
                keys = self._req_keys(req)
                max_hit = max(0, min(len(keys), (target - 1) // page))
                hits = self.alloc.match_prefix(keys[:max_hit])
                anchor = len(hits) * page
            s, e, pe = self._chunk_spans(req, anchor)[0]
            if out and e - s > budget:
                break                      # budget spent; keep FIFO order
            slot = free[0]
            if hits:
                pages = self.alloc.alloc_slot_shared(slot, pe, hits)
                if pages is None:
                    break                  # head-of-line blocks: no overtake
            else:
                if not self.alloc.can_admit(pe):
                    break                  # head-of-line blocks: no overtake
                pages = self.alloc.alloc_slot(slot, pe)
                assert pages is not None   # can_admit just said yes
            restored = False
            if spill is not None:
                restored = bool(self.restore_fn is not None
                                and self.restore_fn(req, slot, anchor))
                if not restored:
                    # degraded restore (offload_io fault / payload gone):
                    # unwind the allocation and retry THIS request as a
                    # classic recompute admission -- the spill entry is
                    # consumed, so the retry takes the anchor-0 path.
                    self.alloc.free_slot(slot)
                    self.alloc.host_drop(req.rid)
                    continue
            self.queue.pop(0)
            free.pop(0)
            req.state, req.slot = "running", slot
            req.admitted_seq = self._admit_seq
            self._admit_seq += 1
            self.running[slot] = req
            self._note_admitted(req)
            req.prefill_target = target
            req.prefill_pos = e
            req.chunk_anchor = anchor
            budget -= e - s
            self._count("prefill_tokens", e - s)
            if hits:
                self._count("prefix_hit_tokens", anchor)
                self._event(req, "prefix_hit", tokens=anchor,
                            pages=len(hits))
            if restored:
                self._count("restarts_restored")
            elif req.n_preempted:
                self._count("restarts_recomputed")
            first = anchor == 0
            out.append(PrefillChunk(
                req, slot, s, e, pe, first, e >= target,
                kv_pages=0 if first else self._kv_pages(req)))
        return out

    def _next_chunk(self, req: Request) -> Optional[PrefillChunk]:
        """The continuation chunk at ``req.prefill_pos``, with its pages
        allocated (the commitment point) -- or None under arena pressure
        (nothing allocated)."""
        for (s, e, pe) in self._chunk_spans(req, req.chunk_anchor):
            if s == req.prefill_pos:
                new = self.alloc.grow_slot(req.slot, pe)
                if new is None:
                    return None
                return PrefillChunk(req, req.slot, s, e, pe, False,
                                    e >= req.prefill_target,
                                    kv_pages=self._kv_pages(req))
        raise AssertionError(f"prefill_pos {req.prefill_pos} off the "
                             f"chunk lattice for rid {req.rid}")

    def _evict_younger_than(self, req: Request) -> bool:
        """Preempt the youngest runner strictly younger than ``req`` (so
        the oldest mid-prefill request always makes progress: livelock-free
        for the same reason decode eviction is). False when none exists."""
        cands = [r for r in self.running.values()
                 if r.admitted_seq > req.admitted_seq]
        if not cands:
            return False
        self.preempt(max(cands, key=lambda r: r.admitted_seq))
        return True

    # -- phase 2: decode capacity / preemption ----------------------------
    def ensure_decode_capacity(self) -> Tuple[List[Tuple[int, int]],
                                              List[Request],
                                              List[Request]]:
        """Guarantee every running slot can take one more token.

        Returns (new_pages, evicted, truncated): ``new_pages`` as
        (slot, page_id) for the engine's table updates; ``evicted``
        requests were preempted back to the queue (their slots are free);
        ``truncated`` hit their per-sequence context cap and were finished
        here (immediately out of ``running`` -- a truncated request left
        running would be a legal eviction victim later in the same pass,
        and preempting an already-finished request would requeue it as a
        zombie).
        """
        new_pages: List[Tuple[int, int]] = []
        evicted: List[Request] = []
        truncated: List[Request] = []
        for slot in sorted(self.running):
            req = self.running.get(slot)
            if req is None or req.prefilling:
                continue               # mid-prefill slots do not decode
            while True:
                if req.cache_len % self.alloc.page_size != 0:
                    break                  # headroom in the current page
                held = len(self.alloc.slot_pages(slot))
                if req.cache_len < held * self.alloc.page_size:
                    break                  # page already allocated
                if held >= self.alloc.max_pages_per_seq:
                    self.finish(req, truncated=True)   # context limit
                    truncated.append(req)
                    break
                pid = self.alloc.extend_slot(slot)
                if pid is not None:
                    new_pages.append((slot, pid))
                    break
                if len(self.running) <= 1:
                    # The sole runner holds every live page yet needs more:
                    # its context outgrew the arena, and eviction cannot
                    # help. Finish it truncated rather than thrash.
                    self.finish(req, truncated=True)
                    truncated.append(req)
                    break
                victim = self._eviction_victim()
                self.preempt(victim)
                evicted.append(victim)
                if victim is req:
                    break                  # evicted itself; retry later
        return new_pages, evicted, truncated

    def _eviction_victim(self) -> Request:
        """The youngest-admitted runner: least completed work is wasted and
        the oldest request always keeps making progress (no livelock)."""
        return max(self.running.values(), key=lambda r: r.admitted_seq)

    # -- state transitions -------------------------------------------------
    def preempt(self, req: Request) -> None:
        """Evict a running request: free its pages, requeue for recompute.
        Generated tokens are kept (they re-prefill as prompt suffix); a
        mid-prefill victim restarts from chunk 0 (its pages and carried
        recurrent state are gone -- recompute IS the restart mechanism,
        at chunk granularity).

        With ``offload`` on, the victim's committed pages are spilled to
        the host pool first (``spill_fn``; a device->host copy), so its
        next admission can restore instead of recompute. The spill runs
        BEFORE ``free_slot`` -- page contents must be captured while the
        pages are still exclusively owned."""
        if (self.offload and self.spill_fn is not None
                and req.cache_len > 0):
            committed = req.cache_len
            pages = self.alloc.slot_pages(req.slot)[
                :pages_for(committed, self.alloc.page_size)]
            self.spill_fn(req, pages, committed)
        self.alloc.free_slot(req.slot)
        del self.running[req.slot]
        req.state, req.slot, req.cache_len = "queued", -1, 0
        req.prefill_pos = req.prefill_target = 0
        req.chunk_anchor = 0
        req.prefix_keys = None             # serve_prompt grew: keys stale
        req.n_preempted += 1
        req.queued_since = self.clock()
        self._count("preemptions")
        self._event(req, "preempt", n_preempted=req.n_preempted)
        self.queue.insert(0, req)          # preempted requests go first

    def finish(self, req: Request, *, truncated: bool = False) -> None:
        self.alloc.free_slot(req.slot)
        self.alloc.host_drop(req.rid)       # terminal: spill is dead weight
        self.running.pop(req.slot, None)
        req.state = "finished"
        req.truncated = truncated
        req.t_finished = self.clock()
        self._count("finished")
        if truncated:
            self._count("truncated")
        self._event(req, "finished", truncated=truncated,
                    new_tokens=req.n_generated)

    # -- SLO enforcement ---------------------------------------------------
    def _expired(self, req: Request, now: Optional[float] = None) -> bool:
        return (self.enforce_deadlines and req.deadline is not None
                and (self.clock() if now is None else now) >= req.deadline)

    def shed(self, req: Request, reason: str = "deadline_missed") -> None:
        """Terminal drop: free any held slot/pages, mark the request shed.
        Unlike :meth:`preempt` nothing is requeued -- the SLO is already
        missed, and recomputing it would burn budget deadlined traffic
        behind it needs. Partial tokens stay on the request (they are
        exact: shedding never rewinds the stream)."""
        if req.state == "running":
            self.alloc.free_slot(req.slot)
            self.running.pop(req.slot, None)
        self.alloc.host_drop(req.rid)       # terminal: spill is dead weight
        req.state, req.slot = "shed", -1
        req.shed_reason = reason
        req.t_finished = self.clock()
        self._count("shed")
        self._event(req, "shed", reason=reason,
                    new_tokens=req.n_generated)

    def shed_expired(self) -> List[Request]:
        """Shed every queued or running request whose deadline has passed.
        The engine calls this at the admission boundary (start of the
        iteration) and again at the decode-step boundary, so an expired
        request never charges another prefill chunk or decode token.
        No-op (and cheap) unless ``enforce_deadlines`` is on."""
        if not self.enforce_deadlines:
            return []
        now = self.clock()
        out: List[Request] = []
        for req in [r for r in self.queue if self._expired(r, now)]:
            self.queue.remove(req)
            self.shed(req)
            out.append(req)
        for req in [r for r in list(self.running.values())
                    if self._expired(r, now)]:
            self.shed(req)
            out.append(req)
        return out


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------
def _pct(vals: List[float], p: float) -> Optional[float]:
    """Percentile over a possibly-empty population: None when empty.

    A fabricated 0.0 here is worse than a gap — an all-shed or all-failed
    run would read as an infinitely fast one in BENCH rows and trend
    plots (the exact bug this replaces)."""
    if not vals:
        return None
    return float(np.percentile(np.asarray(vals), p))


def summarize(requests: List[Request], wall_s: float) -> Dict[str, float]:
    """Aggregate per-request telemetry into the BENCH_serving schema.

    TTFT and ITL are split out deliberately: TTFT measures queueing +
    prefill (what the admission policy controls), ITL the gaps *between* a
    request's tokens (what a co-tenant's prefill stalls -- the distribution
    chunked prefill exists to tighten). ITL percentiles pool every
    inter-token gap across requests, so one stalled request cannot hide in
    a per-request mean.

    Latency keys are ``None`` (JSON null) when their population is empty
    — no finished request, or no second token ever emitted — so consumers
    can distinguish "nothing completed" from "completed instantly"."""
    done = [r for r in requests if r.state == "finished"]
    lat = [r.t_finished - r.submitted_at for r in done
           if r.t_finished is not None]
    ttft = [r.t_first_token - r.submitted_at for r in done
            if r.t_first_token is not None]
    itl = [g for r in requests for g in r.itl_s]
    new_tokens = sum(r.n_generated for r in done)
    return {
        "requests": float(len(done)),
        "new_tokens": float(new_tokens),
        "wall_s": wall_s,
        "tokens_per_s": new_tokens / max(wall_s, 1e-9),
        "p50_latency_s": _pct(lat, 50),
        "p99_latency_s": _pct(lat, 99),
        "p50_ttft_s": _pct(ttft, 50),
        "p99_ttft_s": _pct(ttft, 99),
        "p50_itl_s": _pct(itl, 50),
        "p95_itl_s": _pct(itl, 95),
        "prefill_chunks": float(sum(r.n_chunks for r in requests)),
        "preemptions": float(sum(r.n_preempted for r in requests)),
        "truncated": float(sum(1 for r in requests if r.truncated)),
        # SLO enforcement: requests dropped with a terminal
        # deadline_missed status (scheduler.shed_expired); always present
        # (0.0 with enforcement off) so BENCH_serving rows track it.
        "shed": float(sum(1 for r in requests if r.state == "shed")),
    }
