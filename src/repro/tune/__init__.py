"""Empirical tile-plan autotuning (the measured "header file").

The paper ships analytically-derived tiling parameters in a generated
header; this subsystem replaces that static schedule with a measured one:

* ``tiling.enumerate_plans``   -- the candidate lattice (core.tiling),
* ``measure``                  -- the per-iteration-synced timing harness,
* ``tuner.resolve_plan``       -- flag-gated plan resolution for the kernels,
* ``cache``                    -- the persistent JSON plan cache.

Controlled by ``GEMMINI_TUNE={off,cached,full}`` (see ``core.flags`` and
docs/tuning.md).
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.core.config import Dataflow, GemminiConfig
from repro.tune.cache import (PlanCache, default_cache_path, fingerprint,
                              get_cache, reset_cache)
from repro.tune.measure import (measure_plan, measurement_backend,
                                time_callable)
from repro.tune.tuner import (TIE_BAND, TuneReport, analytic_cycles,
                              resolve_plan, tune_gemm, tuned_plan_fn)

__all__ = [
    "PlanCache", "TIE_BAND", "TuneReport", "analytic_cycles",
    "default_cache_path", "fingerprint", "get_cache", "measure_plan",
    "measurement_backend", "reset_cache", "resolve_plan", "time_callable",
    "tune_gemm", "tuned_plan_fn", "warm_model_plans",
]


def warm_model_plans(cfg: GemminiConfig, model_cfg, batch: int, seq: int, *,
                     dataflow: Optional[Dataflow] = None,
                     include_decode: bool = True) -> Dict[str, int]:
    """Resolve (and, under ``tune_mode=full``, tune + persist) a plan for
    every GEMM shape a model will run, so serving never tunes on the request
    path. Returns {shapes, cache_hits, cache_misses} for the warm pass."""
    from repro.models.transformer import model_gemm_shapes
    cache = get_cache()
    h0, m0 = cache.hits, cache.misses
    shapes = model_gemm_shapes(model_cfg, batch, seq,
                               include_decode=include_decode)
    for (m, n, k) in shapes:
        resolve_plan(cfg, m, n, k, dataflow=dataflow)
    return {"shapes": len(shapes), "cache_hits": cache.hits - h0,
            "cache_misses": cache.misses - m0}
