"""Empirical kernel-schedule autotuning (the measured "header file").

The paper ships analytically-derived tiling parameters in a generated
header; this subsystem replaces that static schedule with a measured one,
for every kernel class the stack runs hot:

* ``tiling.enumerate_plans``       -- the GEMM candidate lattice,
* ``schedules``                    -- attention (block_q/block_k) and conv
                                      (co_tile) schedule spaces,
* ``measure``                      -- the per-iteration-synced timing harness,
* ``tuner.resolve_plan`` /
  ``tuner.resolve_attn_schedule`` /
  ``tuner.resolve_conv_schedule``  -- flag-gated resolution for the kernels,
* ``cache``                        -- the persistent JSON schedule cache.

Controlled by ``GEMMINI_TUNE={off,cached,full}`` (see ``core.flags`` and
docs/tuning.md).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.config import Dataflow, GemminiConfig
from repro.tune.cache import (PlanCache, default_cache_path, fingerprint,
                              get_cache, kernel_fingerprint, reset_cache)
from repro.tune.measure import (measure_attn_schedule, measure_conv_schedule,
                                measure_paged_schedule, measure_plan,
                                measurement_backend, time_callable)
from repro.tune.schedules import (AttnSchedule, ConvSchedule,
                                  PagedAttnSchedule, attn_cache_key,
                                  attn_cycles, conv_cache_key, conv_cycles,
                                  enumerate_attn_schedules,
                                  enumerate_conv_schedules,
                                  enumerate_paged_schedules,
                                  paged_attn_cache_key, paged_attn_cycles)
from repro.tune.tuner import (TIE_BAND, SchedReport, TuneReport,
                              analytic_cycles, resolve_attn_schedule,
                              resolve_conv_schedule,
                              resolve_paged_attn_schedule, resolve_plan,
                              tune_attention, tune_conv, tune_gemm,
                              tune_paged_attention, tuned_plan_fn)

__all__ = [
    "AttnSchedule", "ConvSchedule", "PagedAttnSchedule", "PlanCache",
    "SchedReport", "TIE_BAND", "TuneReport", "analytic_cycles",
    "attn_cache_key", "attn_cycles", "conv_cache_key", "conv_cycles",
    "default_cache_path", "enumerate_attn_schedules",
    "enumerate_conv_schedules", "enumerate_paged_schedules", "fingerprint",
    "get_cache", "kernel_fingerprint", "measure_attn_schedule",
    "measure_conv_schedule", "measure_paged_schedule", "measure_plan",
    "measurement_backend", "paged_attn_cache_key", "paged_attn_cycles",
    "reset_cache", "resolve_attn_schedule", "resolve_conv_schedule",
    "resolve_paged_attn_schedule", "resolve_plan", "time_callable",
    "tune_attention", "tune_conv", "tune_gemm", "tune_paged_attention",
    "tuned_plan_fn", "warm_conv_plans", "warm_model_plans",
]


def warm_model_plans(cfg: GemminiConfig, model_cfg, batch: int, seq: int, *,
                     dataflow: Optional[Dataflow] = None,
                     include_decode: bool = True,
                     include_attention: bool = True,
                     n_shards: int = 1,
                     paged_slots: int = 0,
                     paged_max_context: int = 0) -> Dict[str, int]:
    """Resolve (and, under ``tune_mode=full``, tune + persist) a schedule for
    every GEMM *and attention* shape a model will run, so serving never
    tunes on the request path.

    ``n_shards``: data-parallel mesh split -- each device sees the
    per-device batch after the mesh partitions the global one, so shapes
    are warmed at the per-device M (``ceil(batch / n_shards) * seq``), not
    the global M the partitioner never launches.

    GEMM shapes carry their ``has_bias`` flag: biased projections (e.g.
    qwen QKV) ride the engine's D input and fingerprint differently from
    their un-biased twins, so warming without the flag would populate
    entries the request path never hits.

    ``paged_slots``/``paged_max_context``: when set (the continuous-batching
    serving engine), additionally resolve the paged-attention page size at
    the engine's decode batch -- the shape the paged pools are sized with
    at startup. One entry, window=None: the engine runs ONE page size for
    every layer, so it resolves the global-window (worst-case) key, and
    warming per-layer-window entries would populate fingerprints the
    engine never consults (the PR-2 warm-path has_bias bug, as a class).

    Returns {shapes, gemm_shapes, attn_shapes, paged_shapes, cache_hits,
    cache_misses} for the warm pass.
    """
    from repro.models.transformer import (model_attention_shapes,
                                          model_gemm_shapes)
    cache = get_cache()
    h0, m0 = cache.hits, cache.misses
    shard_batch = max(1, -(-batch // max(1, n_shards)))
    gshapes = model_gemm_shapes(model_cfg, shard_batch, seq,
                                include_decode=include_decode)
    for (m, n, k, has_bias) in gshapes:
        resolve_plan(cfg, m, n, k, dataflow=dataflow, has_bias=has_bias)
    ashapes: List[Tuple] = []
    if include_attention:
        ashapes = model_attention_shapes(model_cfg, shard_batch, seq)
        for (b, tq, tk, h, kvh, d, causal, window) in ashapes:
            resolve_attn_schedule(cfg, b, tq, tk, h, kvh, d, causal=causal,
                                  window=window, dtype=model_cfg.dtype)
    pshapes: List[Tuple] = []
    if paged_slots and paged_max_context and model_cfg.has_attn:
        pshapes.append((paged_slots, model_cfg.n_heads,
                        model_cfg.n_kv_heads, model_cfg.head_dim,
                        paged_max_context, None))
        for (b, h, kvh, d, ctx, window) in pshapes:
            resolve_paged_attn_schedule(cfg, b, h, kvh, d, ctx,
                                        window=window,
                                        dtype=model_cfg.dtype)
    return {"shapes": len(gshapes) + len(ashapes) + len(pshapes),
            "gemm_shapes": len(gshapes), "attn_shapes": len(ashapes),
            "paged_shapes": len(pshapes),
            "cache_hits": cache.hits - h0,
            "cache_misses": cache.misses - m0}


def warm_conv_plans(cfg: GemminiConfig, shapes) -> Dict[str, int]:
    """Resolve a co_tile schedule for each explicit conv shape
    ``(n, h, w, ci, co, kh, kw, stride, padding, has_bias)`` -- the warm
    entry for CNN workloads (the LM model zoo has no conv layers, so these
    shapes come from the caller, e.g. a vision-tower driver or benchmark).
    """
    cache = get_cache()
    h0, m0 = cache.hits, cache.misses
    shapes = list(shapes)
    for (n, h, w, ci, co, kh, kw, stride, padding, has_bias) in shapes:
        resolve_conv_schedule(cfg, n, h, w, ci, co, kh, kw, stride=stride,
                              padding=padding, has_bias=has_bias)
    return {"shapes": len(shapes), "cache_hits": cache.hits - h0,
            "cache_misses": cache.misses - m0}
