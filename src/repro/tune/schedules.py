"""Per-kernel schedule spaces for the kernel-agnostic tuner.

PR 1 tuned one kernel class: the GEMM engine, whose schedule is a
:class:`~repro.core.tiling.TilePlan` (the "GemmPlan"). This module adds the
other two kernel classes the stack runs hot:

* :class:`AttnSchedule` -- flash attention's ``(block_q, block_k)`` blocking
  (kernels/attention.py). ``block_q`` sets the VMEM-resident query tile /
  online-softmax accumulator; ``block_k`` sets the streamed K/V tile.
* :class:`ConvSchedule` -- the implicit-im2col conv kernel's ``co_tile``
  (kernels/conv.py): the output-channel tile whose accumulator stays
  resident across the filter-tap stream.

Each space follows the GEMM tuner's contract so ``tune.tuner`` can drive any
of them through one measure/tiebreak path:

* a **lattice enumerator** (every candidate legal under the config's
  scratchpad/accumulator budgets, the static default always included),
* an **analytic cycle model** faithful to how the Pallas kernel lowers the
  schedule (the deterministic tiebreak when measured times tie),
* a **stable cache fingerprint** (``attn_cache_key`` / ``conv_cache_key``,
  sharing ``cache.kernel_fingerprint`` with the GEMM path).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core import isa
from repro.core.config import GemminiConfig, bytes_of
from repro.tune import cache as tcache

# Static defaults -- the schedules the kernels ship with when tuning is off
# (kernels/attention.py and kernels/conv.py keyword defaults).
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
DEFAULT_CO_TILE = 128
DEFAULT_PAGE_SIZE = 64

# Candidate block sizes before clamping against the problem (the kernels
# clamp the same way: block = min(block, max(t, 8))).
_ATTN_BLOCKS = (64, 128, 256, 512, 1024)
_CO_TILES = (8, 16, 32, 64, 128, 256, 512)
_PAGE_SIZES = (8, 16, 32, 64, 128, 256, 512)

# Fixed per-grid-step cost (cycles) of the paged decode kernel: the block
# table SMEM read + DMA issue latency a tiny page cannot amortize. Rough,
# but it is what makes the page-size lattice non-degenerate on the analytic
# tiebreak (pure bandwidth/MAC terms would always pick the smallest page).
PAGE_STEP_CYCLES = 200.0


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def schedule_dtype(dtype):
    """Normalize a schedule's streamed dtype: accepts the engine's short
    names ("bf16", "fp32", ...) as well as anything numpy/jax understands,
    so fingerprints and byte widths agree no matter which spelling the
    caller used."""
    import jax.numpy as jnp
    if isinstance(dtype, str):
        from repro.core import config as _config
        try:
            return jnp.dtype(_config.dtype_of(dtype))
        except ValueError:
            pass
    return jnp.dtype(dtype)


def _macs_per_cycle(cfg: GemminiConfig) -> float:
    return cfg.dim * cfg.dim * (1.0 if cfg.pipeline_depth > 1 else 0.5)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttnSchedule:
    """Flash-attention blocking: (block_q, block_k)."""

    block_q: int
    block_k: int

    def effective(self, tq: int, tk: int) -> "AttnSchedule":
        """Clamped exactly as kernels/attention.py clamps at launch."""
        return AttnSchedule(min(self.block_q, max(tq, 8)),
                            min(self.block_k, max(tk, 8)))


def default_attn_schedule() -> AttnSchedule:
    return AttnSchedule(DEFAULT_BLOCK_Q, DEFAULT_BLOCK_K)


def _attn_fits(cfg: GemminiConfig, bq: int, bk: int, d: int,
               in_bytes: int) -> bool:
    # Streamed per KV step: one K and one V tile (double-buffered).
    streamed = cfg.pipeline_depth * 2 * bk * d * in_bytes
    # Resident across the KV stream: q tile + f32 accumulator + (m, l) state.
    resident = bq * d * (in_bytes + 4) + 2 * bq * 4
    return (streamed <= cfg.scratchpad_bytes
            and resident <= cfg.accumulator_bytes)


def attn_cycles(sched: AttnSchedule, cfg: GemminiConfig, b: int, h: int,
                kvh: int, tq: int, tk: int, d: int, *, causal: bool,
                window: Optional[int], in_bytes: int,
                sys: Optional[isa.SystemParams] = None) -> float:
    """Deterministic cost of the schedule as kernels/attention.py runs it.

    Counts only *live* (q-block, kv-block) pairs -- the kernel's whole-block
    skip predicate (``attention.block_live``, including the pad_k term) is
    re-evaluated here so a schedule whose blocking skips more fully-masked
    work ranks better, which is the kernel-level reason sliding-window
    layers prefer block_k <= window.
    """
    sys = sys or isa.ROCKET
    eff = sched.effective(tq, tk)
    bq, bk = eff.block_q, eff.block_k
    nq, nk = _ceil_div(tq, bq), _ceil_div(tk, bk)
    # Live (i, j) pairs counted in O(nq): for each q block the live j form
    # one interval [j_lo, j_hi] under ``attention.block_live``'s terms
    # (padding: k0 < tk; causal: k0 <= q0 + bq - 1; window: k0 + bk - 1 >
    # q0 - window) -- a 128k-context schedule must not cost nq*nk Python
    # iterations per candidate.
    live = 0
    for i in range(nq):
        q0 = i * bq + (tk - tq)
        hi_k = tk - 1
        if causal:
            hi_k = min(hi_k, q0 + bq - 1)
        j_hi = min(nk - 1, hi_k // bk) if hi_k >= 0 else -1
        j_lo = 0
        if window is not None:
            # smallest j with j*bk + bk - 1 > q0 - window
            j_lo = max(0, -(-(q0 - window - bk + 2) // bk))
        live += max(0, j_hi - j_lo + 1)
    # Two MXU contractions per live block: Q@K^T and P@V.
    macs = 2 * b * h * live * bq * bk * d
    # K/V fetched per live block; the q tile is fetched once per q row
    # (its block index is constant across the KV stream, so Mosaic's
    # revisiting elides the re-copy).
    loads = b * h * (live * 2 * bk * d + nq * bq * d) * in_bytes
    stores = b * h * nq * bq * d * in_bytes
    bw = sys.effective_bw(cfg.dim)
    return max(macs / _macs_per_cycle(cfg), loads / bw, stores / bw)


def enumerate_attn_schedules(cfg: GemminiConfig, b: int, h: int, kvh: int,
                             tq: int, tk: int, d: int, *, causal: bool = True,
                             window: Optional[int] = None,
                             in_bytes: int = 2,
                             max_candidates: int = 16) -> List[AttnSchedule]:
    """Legal (block_q, block_k) lattice, analytic-cost ordered.

    Candidates are the *effective* (problem-clamped) block sizes, so two
    nominal schedules that clamp to the same launch parameters dedupe. The
    static default (clamped) is always included.
    """
    def axis(t: int) -> List[int]:
        return sorted({min(p, max(t, 8)) for p in _ATTN_BLOCKS})

    default = default_attn_schedule().effective(tq, tk)
    scheds = {default}
    for bq in axis(tq):
        for bk in axis(tk):
            if _attn_fits(cfg, bq, bk, d, in_bytes):
                scheds.add(AttnSchedule(bq, bk))
    ordered = sorted(
        scheds,
        key=lambda s: (attn_cycles(s, cfg, b, h, kvh, tq, tk, d,
                                   causal=causal, window=window,
                                   in_bytes=in_bytes),
                       -s.block_q, -s.block_k))
    ordered = ordered[:max_candidates]
    if default not in ordered:
        ordered[-1] = default
    return ordered


def attn_cache_key(cfg: GemminiConfig, b: int, tq: int, tk: int, h: int,
                   kvh: int, d: int, *, causal: bool,
                   window: Optional[int], dtype) -> str:
    """Stable fingerprint for an attention schedule lookup.

    Everything that changes the legal lattice or the live-block ranking is
    in the payload: problem shape, GQA grouping, masking structure, and the
    streamed dtype (q/k/v storage width; softcap is elementwise and
    schedule-neutral, so it is excluded).
    """
    payload = {
        "b": int(b), "tq": int(tq), "tk": int(tk),
        "h": int(h), "kvh": int(kvh), "d": int(d),
        "causal": bool(causal),
        "win": int(window) if window else 0,
        "dtype": schedule_dtype(dtype).name,
    }
    # Attention consults only the VMEM budgets / dim / pipelining: the
    # engine's GEMM dtypes and tile caps must not discriminate, or a warm
    # pass under a quantized engine config would key entries a bf16-default
    # request path never hits.
    return tcache.kernel_fingerprint("attn", cfg, payload,
                                     engine_dtypes=False, tile_caps=False)


# ---------------------------------------------------------------------------
# paged attention (serving decode)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PagedAttnSchedule:
    """Paged-decode blocking: the KV page size (tokens per cache block).

    Unlike the other schedule spaces this one is *allocation-coupled*: the
    page size is baked into the serving engine's pool shapes at startup
    (``serving.PagedKVAllocator``), and the kernel streams exactly one page
    per grid step. The lattice therefore trades kernel efficiency (bigger
    pages amortize the per-step table-read/DMA overhead) against allocator
    efficiency (bigger pages waste ~page/2 tokens of HBM per request to
    internal fragmentation, shrinking the number of co-resident requests).
    """

    page_size: int

    def effective(self, max_context: int) -> "PagedAttnSchedule":
        return PagedAttnSchedule(max(8, min(self.page_size, max_context)))


def default_paged_schedule() -> PagedAttnSchedule:
    return PagedAttnSchedule(DEFAULT_PAGE_SIZE)


def _paged_fits(cfg: GemminiConfig, page: int, rep: int, d: int,
                in_bytes: int) -> bool:
    # Streamed per page step: one K and one V page (double-buffered).
    streamed = cfg.pipeline_depth * 2 * page * d * in_bytes
    # Resident across the stream: the (rep, D) query rows + f32 accumulator
    # + (m, l) state, as kernels/attention._paged_decode_kernel holds them.
    resident = rep * d * (in_bytes + 4) + 2 * rep * 4
    return (streamed <= cfg.scratchpad_bytes
            and resident <= cfg.accumulator_bytes)


def paged_attn_cycles(sched: PagedAttnSchedule, cfg: GemminiConfig, b: int,
                      h: int, kvh: int, d: int, max_context: int, *,
                      window: Optional[int], in_bytes: int,
                      mean_len: Optional[int] = None,
                      sys: Optional[isa.SystemParams] = None) -> float:
    """Deterministic decode-step cost as the paged kernel runs it, at a
    representative request length (``mean_len``, default max_context/2).

    Live pages per request follow ``attention.block_live`` with block_q=1:
    ceil(len/page) minus the pages a sliding window lets the kernel skip.
    The fragmentation penalty models the allocator side: the last page of
    every request is half-wasted on average, which at a fixed HBM budget
    evicts-or-queues proportionally more co-resident requests, so it is
    charged as extra amortized traffic.
    """
    sys = sys or isa.ROCKET
    eff = sched.effective(max_context)
    page = eff.page_size
    rep = h // kvh
    ln = mean_len if mean_len is not None else max(1, max_context // 2)
    pos = ln - 1
    j_hi = pos // page
    j_lo = 0
    if window is not None:
        # smallest j with j*page + page - 1 > pos - window (block_live's
        # window term at block_q = 1)
        j_lo = max(0, -(-(pos - window - page + 2) // page))
    live = max(1, j_hi - j_lo + 1)
    # Two MXU contractions per live page: Q@K^T and P@V on (rep, page, d).
    macs = 2 * b * kvh * live * rep * page * d
    loads = b * kvh * live * 2 * page * d * in_bytes
    # Internal fragmentation: ~page/2 dead tokens resident per request,
    # charged at the K+V byte cost they occupy in the budget.
    frag = b * kvh * (page / 2) * 2 * d * in_bytes
    bw = sys.effective_bw(cfg.dim)
    compute = max(macs / _macs_per_cycle(cfg), (loads + frag) / bw)
    return compute + b * kvh * live * PAGE_STEP_CYCLES


def enumerate_paged_schedules(cfg: GemminiConfig, b: int, h: int, kvh: int,
                              d: int, max_context: int, *,
                              window: Optional[int] = None,
                              in_bytes: int = 2,
                              max_candidates: int = 8
                              ) -> List[PagedAttnSchedule]:
    """Legal page-size lattice, analytic-cost ordered; the clamped static
    default always survives (the GEMM solver's minimal-tile guarantee)."""
    rep = h // kvh
    default = default_paged_schedule().effective(max_context)
    scheds = {default}
    for p in _PAGE_SIZES:
        s = PagedAttnSchedule(p).effective(max_context)
        if _paged_fits(cfg, s.page_size, rep, d, in_bytes):
            scheds.add(s)
    ordered = sorted(
        scheds,
        key=lambda s: (paged_attn_cycles(s, cfg, b, h, kvh, d, max_context,
                                         window=window, in_bytes=in_bytes),
                       -s.page_size))
    ordered = ordered[:max_candidates]
    if default not in ordered:
        ordered[-1] = default
    return ordered


def paged_attn_cache_key(cfg: GemminiConfig, b: int, h: int, kvh: int,
                         d: int, max_context: int, *,
                         window: Optional[int], dtype) -> str:
    """Stable fingerprint for a paged-schedule lookup. Like the dense
    attention key: only the VMEM budgets / dim / pipelining discriminate
    on the config side (the kernel streams the model dtype regardless of
    the engine's GEMM datapath)."""
    payload = {
        "b": int(b), "h": int(h), "kvh": int(kvh), "d": int(d),
        "ctx": int(max_context),
        "win": int(window) if window else 0,
        "dtype": schedule_dtype(dtype).name,
    }
    return tcache.kernel_fingerprint("paged_attn", cfg, payload,
                                     engine_dtypes=False, tile_caps=False)


# ---------------------------------------------------------------------------
# conv (implicit im2col)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ConvSchedule:
    """Implicit-im2col conv blocking: the output-channel tile."""

    co_tile: int

    def effective(self, co: int) -> "ConvSchedule":
        return ConvSchedule(min(self.co_tile, co))


def default_conv_schedule() -> ConvSchedule:
    return ConvSchedule(DEFAULT_CO_TILE)


def _conv_dims(h: int, w: int, kh: int, kw: int, stride: int, padding: int):
    """(oh, ow, hp, wp): output dims + the VMEM-resident input block dims
    (exact tap cover, as kernels/conv.py trims it)."""
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    return oh, ow, (oh - 1) * stride + kh, (ow - 1) * stride + kw


def _conv_fits(cfg: GemminiConfig, co_tile: int, oh: int, ow: int, ci: int,
               hp: int, wp: int) -> bool:
    in_b = bytes_of(cfg.input_dtype)
    acc_b = bytes_of(cfg.acc_dtype)
    # Resident: the (oh*ow, co_tile) accumulator at acc width.
    if oh * ow * co_tile * acc_b > cfg.accumulator_bytes:
        return False
    # Streamed/resident in the scratchpad: the whole input block for the tap
    # stream + the double-buffered per-tap weight tile.
    streamed = hp * wp * ci * in_b + cfg.pipeline_depth * ci * co_tile * in_b
    return streamed <= cfg.scratchpad_bytes


def conv_cycles(sched: ConvSchedule, cfg: GemminiConfig, n: int, h: int,
                w: int, ci: int, co: int, kh: int, kw: int, *,
                stride: int = 1, padding: int = 0, has_bias: bool = False,
                sys: Optional[isa.SystemParams] = None) -> float:
    """Cost of the schedule as kernels/conv.py lowers it: grid
    (N, ceil(co/co_tile), KH*KW), the input block re-fetched per co tile,
    the weight tap tile per grid step, padded-co MACs wasted."""
    sys = sys or isa.ROCKET
    ct = sched.effective(co).co_tile
    nco = _ceil_div(co, ct)
    oh, ow, hp, wp = _conv_dims(h, w, kh, kw, stride, padding)
    in_b = bytes_of(cfg.input_dtype)
    acc_b = bytes_of(cfg.acc_dtype)
    macs = n * nco * kh * kw * oh * ow * ci * ct
    loads = n * nco * (hp * wp * ci * in_b + kh * kw * ci * ct * in_b)
    if has_bias:
        loads += n * nco * ct * acc_b
    stores = n * oh * ow * nco * ct * bytes_of(cfg.output_dtype)
    bw = sys.effective_bw(cfg.dim)
    return max(macs / _macs_per_cycle(cfg), loads / bw, stores / bw)


def enumerate_conv_schedules(cfg: GemminiConfig, n: int, h: int, w: int,
                             ci: int, co: int, kh: int, kw: int, *,
                             stride: int = 1, padding: int = 0,
                             has_bias: bool = False,
                             max_candidates: int = 12) -> List[ConvSchedule]:
    """Legal co_tile lattice (power-of-two tiles clamped to co, plus co
    itself), analytic-cost ordered; the clamped static default is always
    included, and the smallest tile survives even when budgets exclude all
    (mirror of the GEMM solver's minimal-tile guarantee)."""
    oh, ow, hp, wp = _conv_dims(h, w, kh, kw, stride, padding)
    cands = sorted({min(t, co) for t in _CO_TILES} | {co})
    legal = [ConvSchedule(c) for c in cands
             if _conv_fits(cfg, c, oh, ow, ci, hp, wp)]
    if not legal:
        legal = [ConvSchedule(cands[0])]
    default = default_conv_schedule().effective(co)
    if default not in legal and _conv_fits(cfg, default.co_tile, oh, ow,
                                           ci, hp, wp):
        legal.append(default)
    ordered = sorted(
        legal,
        key=lambda s: (conv_cycles(s, cfg, n, h, w, ci, co, kh, kw,
                                   stride=stride, padding=padding,
                                   has_bias=has_bias),
                       -s.co_tile))
    ordered = ordered[:max_candidates]
    if default not in ordered and default in legal:
        ordered[-1] = default
    return ordered


def conv_cache_key(cfg: GemminiConfig, n: int, h: int, w: int, ci: int,
                   co: int, kh: int, kw: int, *, stride: int, padding: int,
                   has_bias: bool) -> str:
    payload = {
        "nhwc": [int(n), int(h), int(w), int(ci)],
        "co": int(co), "khw": [int(kh), int(kw)],
        "stride": int(stride), "pad": int(padding),
        "bias": bool(has_bias),
    }
    # The conv kernel runs at the engine dtypes (x/w at input, accumulator
    # at acc) so they stay in the key; the GEMM-only max_tile caps do not.
    return tcache.kernel_fingerprint("conv", cfg, payload, tile_caps=False)
