"""Wall-clock measurement harness for candidate schedules.

The one non-negotiable rule of timing dispatched JAX computations: sync
*inside* the loop. ``fn(*args)`` returns as soon as the work is enqueued, so
a loop that only syncs the last result measures dispatch overhead, not
execution (the original ``bench_kernels._time`` bug). ``time_callable`` calls
``jax.block_until_ready`` on every iteration and reports min-of-iters (the
noise-robust statistic schedulers should rank by) alongside the mean.

Backend selection for plan measurement:

* on a TPU host the candidate is lowered for real (``kernels.gemm`` with the
  candidate plan) -- the measured ranking is the true Mosaic ranking;
* on CPU hosts (CI) Mosaic cannot lower, so we time a *schedule proxy*: the
  XLA reference GEMM on operands padded to the candidate plan's dims. That
  captures the padding waste a bad snap costs, but candidates that differ
  only in tile split time identically -- the tuner's analytic-cost tiebreak
  (``tuner.analytic_cycles``) decides those, keeping CI deterministic.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.config import GemminiConfig
from repro.core.tiling import TilePlan


def time_callable(fn: Callable, *args, iters: int = 5,
                  warmup: int = 1) -> Dict[str, float]:
    """Time ``fn(*args)``: per-iteration sync, returns mean/min microseconds."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))     # compile + warm caches
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return {"mean_us": sum(times) / len(times), "min_us": min(times),
            "iters": float(iters)}


def measurement_backend() -> str:
    """"pallas" when Mosaic can lower here, else the XLA schedule proxy."""
    return "pallas" if jax.default_backend() == "tpu" else "proxy"


def measure_plan(cfg: GemminiConfig, plan: TilePlan, *, has_bias: bool = False,
                 backend: Optional[str] = None, iters: int = 3,
                 warmup: int = 1) -> Dict[str, float]:
    """Wall-time one candidate plan on this host (zeros operands: timing is
    data-independent for dense GEMM)."""
    backend = backend or measurement_backend()
    a = jnp.zeros((plan.m, plan.k), cfg.input_jnp)
    b = jnp.zeros((plan.k, plan.n), cfg.input_jnp)
    d = jnp.zeros((plan.m, plan.n), cfg.acc_jnp) if has_bias else None

    if backend == "pallas":
        from repro.kernels import gemm as gemm_kernel

        def run(a, b):
            return gemm_kernel.gemm(a, b, d, plan, cfg,
                                    dataflow=plan.dataflow)
    else:
        from repro.kernels import ref as ref_ops

        def run(a, b):
            return ref_ops.gemm_ref(a, b, d, acc_dtype=cfg.acc_jnp,
                                    out_dtype=cfg.output_jnp)

    return time_callable(jax.jit(run), a, b, iters=iters, warmup=warmup)
