"""Wall-clock measurement harness for candidate schedules.

The one non-negotiable rule of timing dispatched JAX computations: sync
*inside* the loop. ``fn(*args)`` returns as soon as the work is enqueued, so
a loop that only syncs the last result measures dispatch overhead, not
execution (the original ``bench_kernels._time`` bug). ``time_callable`` calls
``jax.block_until_ready`` on every iteration and reports min-of-iters (the
noise-robust statistic schedulers should rank by) alongside the mean.

Backend selection for schedule measurement (all kernel classes):

* on a TPU host the candidate is lowered for real (``kernels.gemm`` /
  ``kernels.attention`` / ``kernels.conv`` with the candidate schedule) --
  the measured ranking is the true Mosaic ranking;
* on CPU hosts (CI) Mosaic cannot lower, so we time a *schedule proxy*: the
  XLA reference path on operands padded to the candidate schedule's dims.
  That captures the padding waste a bad blocking costs, but candidates that
  differ only in split time identically -- the tuner's analytic-cost
  tiebreaks (``tuner.analytic_cycles`` / ``schedules.attn_cycles`` /
  ``schedules.conv_cycles``) decide those, keeping CI deterministic.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.config import GemminiConfig
from repro.core.tiling import TilePlan


def time_callable(fn: Callable, *args, iters: int = 5,
                  warmup: int = 1, label: str = "") -> Dict[str, float]:
    """Time ``fn(*args)``: per-iteration sync, returns mean/min microseconds.

    With a process-global tracer installed (``repro.obs.trace.install``),
    each measurement lands as a ``measure:<label>`` span on the tuner
    track -- warmup/compile included, so trace timelines show what the
    tuner actually spent, not just the steady-state iterations.
    """
    from repro.obs import trace as otrace
    tracer = otrace.active()
    t_span = tracer.clock() if tracer is not None else 0.0
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))     # compile + warm caches
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    out = {"mean_us": sum(times) / len(times), "min_us": min(times),
           "iters": float(iters)}
    if tracer is not None:
        tracer.complete(f"measure:{label or 'anon'}", t_span,
                        tracer.clock(), cat="tune",
                        tid=otrace.TID_TUNER, min_us=out["min_us"],
                        mean_us=out["mean_us"], iters=iters)
    return out


def measurement_backend() -> str:
    """"pallas" when Mosaic can lower here, else the XLA schedule proxy."""
    return "pallas" if jax.default_backend() == "tpu" else "proxy"


def measure_plan(cfg: GemminiConfig, plan: TilePlan, *, has_bias: bool = False,
                 backend: Optional[str] = None, iters: int = 3,
                 warmup: int = 1) -> Dict[str, float]:
    """Wall-time one candidate plan on this host (zeros operands: timing is
    data-independent for dense GEMM)."""
    backend = backend or measurement_backend()
    a = jnp.zeros((plan.m, plan.k), cfg.input_jnp)
    b = jnp.zeros((plan.k, plan.n), cfg.input_jnp)
    d = jnp.zeros((plan.m, plan.n), cfg.acc_jnp) if has_bias else None

    if backend == "pallas":
        from repro.kernels import gemm as gemm_kernel

        def run(a, b):
            return gemm_kernel.gemm(a, b, d, plan, cfg,
                                    dataflow=plan.dataflow)
    else:
        from repro.kernels import ref as ref_ops

        def run(a, b):
            return ref_ops.gemm_ref(a, b, d, acc_dtype=cfg.acc_jnp,
                                    out_dtype=cfg.output_jnp)

    return time_callable(jax.jit(run), a, b, iters=iters, warmup=warmup,
                         label=f"gemm[{plan.m}x{plan.n}x{plan.k}"
                               f"/{plan.tile_m}x{plan.tile_n}x{plan.tile_k}]")


def measure_attn_schedule(cfg: GemminiConfig, sched, b: int, tq: int,
                          tk: int, h: int, kvh: int, d: int, *,
                          causal: bool = True, window: Optional[int] = None,
                          dtype="bf16", backend: Optional[str] = None,
                          iters: int = 3, warmup: int = 1) -> Dict[str, float]:
    """Wall-time one (block_q, block_k) candidate on this host.

    Pallas backend runs the real flash kernel with the candidate blocking;
    the CPU proxy times the XLA blockwise path on operands padded to the
    candidate's block grid (the padding waste a bad blocking costs).
    """
    from repro.tune.schedules import schedule_dtype
    backend = backend or measurement_backend()
    dt = schedule_dtype(dtype)
    eff = sched.effective(tq, tk)
    bq, bk = eff.block_q, eff.block_k

    if backend == "pallas":
        from repro.kernels import attention as attn_kernel
        q = jnp.zeros((b, tq, h, d), dt)
        k = jnp.zeros((b, tk, kvh, d), dt)
        v = jnp.zeros((b, tk, kvh, d), dt)

        def run(q, k, v):
            return attn_kernel.flash_attention(
                q, k, v, causal=causal, window=window,
                block_q=bq, block_k=bk)
    else:
        from repro.models.attention import blockwise_attention_xla
        nq, nk = -(-tq // bq), -(-tk // bk)
        q = jnp.zeros((b, nq * bq, h, d), dt)
        k = jnp.zeros((b, nk * bk, kvh, d), dt)
        v = jnp.zeros((b, nk * bk, kvh, d), dt)

        def run(q, k, v):
            return blockwise_attention_xla(q, k, v, causal=causal,
                                           window=window, block_k=bk)

    return time_callable(jax.jit(run), q, k, v, iters=iters, warmup=warmup,
                         label=f"attn[bq={bq},bk={bk}]")


def measure_paged_schedule(cfg: GemminiConfig, sched, b: int, h: int,
                           kvh: int, d: int, max_context: int, *,
                           window: Optional[int] = None, dtype="bf16",
                           backend: Optional[str] = None, iters: int = 3,
                           warmup: int = 1) -> Dict[str, float]:
    """Wall-time one page-size candidate for the paged decode kernel.

    Both backends build a pool sized for a full decode batch (every slot at
    ``max_context``, sequentially-allocated tables -- the layout cost of
    fragmentation is the allocator's concern, not the kernel's). Pallas
    runs the in-kernel-gather kernel; the CPU proxy times the explicit
    XLA gather path, which DOES see the page size (its gather/reshape
    granularity), so candidates genuinely measure differently even on CI.
    """
    from repro.core.context import ExecutionContext
    from repro.tune.schedules import schedule_dtype

    backend = backend or measurement_backend()
    dt = schedule_dtype(dtype)
    page = sched.effective(max_context).page_size
    mp = -(-max_context // page)
    n_pages = b * mp
    q = jnp.zeros((b, 1, h, d), dt)
    k_pool = jnp.zeros((kvh, n_pages + 1, page, d), dt)
    v_pool = jnp.zeros((kvh, n_pages + 1, page, d), dt)
    tables = jnp.arange(b * mp, dtype=jnp.int32).reshape(b, mp)
    lengths = jnp.full((b,), max_context, jnp.int32)
    ctx = ExecutionContext(
        cfg=cfg, backend="pallas" if backend == "pallas" else "xla",
        tune_mode="off")   # measuring: never recurse into the tuner

    def run(q, k_pool, v_pool):
        return ctx.paged_attention(q, k_pool, v_pool, tables, lengths,
                                   window=window)

    return time_callable(jax.jit(run), q, k_pool, v_pool, iters=iters,
                         warmup=warmup, label=f"paged[page={page}]")


def measure_conv_schedule(cfg: GemminiConfig, sched, n: int, h: int, w: int,
                          ci: int, co: int, kh: int, kw: int, *,
                          stride: int = 1, padding: int = 0,
                          has_bias: bool = False,
                          backend: Optional[str] = None, iters: int = 3,
                          warmup: int = 1) -> Dict[str, float]:
    """Wall-time one co_tile candidate on this host.

    Pallas backend runs the implicit-im2col kernel with the candidate tile;
    the CPU proxy times the explicit-im2col reference with the output
    channels padded to the candidate's tile grid.
    """
    backend = backend or measurement_backend()
    ct = sched.effective(co).co_tile
    x = jnp.zeros((n, h, w, ci), cfg.input_jnp)
    bias = jnp.zeros((co,), cfg.acc_jnp) if has_bias else None

    if backend == "pallas":
        from repro.kernels import conv as conv_kernel
        wt = jnp.zeros((kh, kw, ci, co), cfg.input_jnp)

        def run(x, wt):
            return conv_kernel.conv2d_implicit(
                x, wt, bias, cfg=cfg, stride=stride, padding=padding,
                co_tile=ct)
    else:
        from repro.kernels import ref as ref_ops
        cop = -(-co // ct) * ct
        wt = jnp.zeros((kh, kw, ci, cop), cfg.input_jnp)
        bp = jnp.zeros((cop,), cfg.acc_jnp) if has_bias else None

        def run(x, wt):
            return ref_ops.conv2d_ref(x, wt, bp, stride=stride,
                                      padding=padding,
                                      acc_dtype=cfg.acc_jnp,
                                      out_dtype=cfg.output_jnp)

    return time_callable(jax.jit(run), x, wt, iters=iters, warmup=warmup,
                         label=f"conv[co_tile={ct}]")
