"""Empirical tile-plan autotuner.

``resolve_plan`` is the single entry the kernels' dispatch layer
(``kernels.ops.gemm``) consults on every un-planned GEMM:

* ``tune_mode="off"``    -- greedy analytic plan (the paper's static header).
* ``tune_mode="cached"`` -- persisted tuned plan if one exists, greedy
                            otherwise; never measures.
* ``tune_mode="full"``   -- cache hit, else measure ``enumerate_plans``
                            candidates, pick the winner, persist it.

Winner selection is measurement-led but deterministic: candidates whose
min-of-iters time lands within ``TIE_BAND`` of the best are considered tied
(CPU proxy timings, and even real TPU timings, are noisy at the few-percent
level), and ties break by the analytic decoupled-queue cycle model
(``core.isa``), then by tile shape. On CPU CI hosts every candidate times
identically up to padding, so the analytic model effectively ranks them --
same answer every run.

The tuner doubles as the DSE's measured-cost backend: ``tuned_plan_fn``
returns a drop-in replacement for ``tiling.plan_gemm`` that
``core.dse.evaluate`` accepts, letting the analytic model be calibrated
against measured schedules (ROADMAP follow-on).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

from repro.core import flags, isa
from repro.core import tiling
from repro.core.config import Dataflow, GemminiConfig, bytes_of
from repro.core.tiling import TilePlan, enumerate_plans, plan_gemm
from repro.tune import measure
from repro.tune.cache import PlanCache, get_cache

# Measured times within 5% of the best are a tie -> analytic model decides.
TIE_BAND = 0.05


def analytic_cycles(plan: TilePlan, cfg: GemminiConfig, *,
                    has_bias: bool = False,
                    sys: Optional[isa.SystemParams] = None) -> float:
    """Deterministic cost of the plan *as the TPU kernels lower it*.

    Not ``isa.simulate``: that models the paper's ASIC dataflows (WS keeps B
    resident across M), whereas both Pallas kernels run K-innermost and
    re-fetch the B tile every K step of every output tile (see
    kernels/gemm.py). Ranking candidates by the ASIC model would reward
    B-reuse the lowered kernel does not realize, so the tiebreak uses the
    kernel-faithful traffic:

        A+B fetches = gm*gn*gk*(tm*tk + tk*tn), one C write per output.
    """
    sys = sys or isa.ROCKET
    gm, gn, gk = plan.grid
    tm, tn, tk = plan.tile_m, plan.tile_n, plan.tile_k
    in_b = bytes_of(cfg.input_dtype)
    load_bytes = gm * gn * gk * (tm * tk + tk * tn) * in_b
    if has_bias:
        load_bytes += gm * gn * tm * tn * bytes_of(cfg.acc_dtype)
    store_bytes = plan.m * plan.n * bytes_of(cfg.output_dtype)
    bw = sys.effective_bw(cfg.dim)
    macs_per_cycle = cfg.dim * cfg.dim * (1.0 if cfg.pipeline_depth > 1
                                          else 0.5)
    return max(plan.macs / macs_per_cycle,
               load_bytes / bw, store_bytes / bw)


@dataclasses.dataclass(frozen=True)
class CandidateResult:
    plan: TilePlan
    min_us: float
    mean_us: float
    cycles: float
    is_greedy: bool


@dataclasses.dataclass(frozen=True)
class TuneReport:
    plan: TilePlan                      # the winner
    candidates: Tuple[CandidateResult, ...]
    greedy: CandidateResult
    backend: str
    cache_key: str = ""

    @property
    def speedup_vs_greedy(self) -> float:
        best = min(c.min_us for c in self.candidates)
        return self.greedy.min_us / best if best else 1.0


def tune_gemm(cfg: GemminiConfig, m: int, n: int, k: int, *,
              dataflow: Optional[Dataflow] = None, has_bias: bool = False,
              backend: Optional[str] = None, iters: int = 3,
              max_candidates: int = 16,
              cache: Optional[PlanCache] = None,
              persist: bool = True) -> TuneReport:
    """Measure the candidate lattice and persist the winner."""
    backend = backend or measure.measurement_backend()
    greedy_plan = plan_gemm(cfg, m, n, k, dataflow=dataflow,
                            has_bias=has_bias)
    candidates = enumerate_plans(cfg, m, n, k, dataflow=dataflow,
                                 has_bias=has_bias,
                                 max_candidates=max_candidates)

    results: List[CandidateResult] = []
    greedy_result: Optional[CandidateResult] = None
    # The CPU proxy only observes padded problem dims, so candidates sharing
    # them MUST time identically or host noise (not the analytic tiebreak)
    # would pick the winner: memoize per padded-dims group. Real pallas
    # measurement sees the actual schedule -- never memoized.
    proxy_memo: dict = {}
    for plan in candidates:
        memo_key = (plan.m, plan.n, plan.k) if backend != "pallas" else None
        if memo_key is not None and memo_key in proxy_memo:
            t = proxy_memo[memo_key]
        else:
            t = measure.measure_plan(cfg, plan, has_bias=has_bias,
                                     backend=backend, iters=iters)
            if memo_key is not None:
                proxy_memo[memo_key] = t
        r = CandidateResult(
            plan=plan, min_us=t["min_us"], mean_us=t["mean_us"],
            cycles=analytic_cycles(plan, cfg, has_bias=has_bias),
            is_greedy=(plan.tile_m, plan.tile_n, plan.tile_k) ==
                      (greedy_plan.tile_m, greedy_plan.tile_n,
                       greedy_plan.tile_k))
        results.append(r)
        if r.is_greedy:
            greedy_result = r
    if greedy_result is None:        # greedy always enumerated, but be safe
        t = measure.measure_plan(cfg, greedy_plan, has_bias=has_bias,
                                 backend=backend, iters=iters)
        greedy_result = CandidateResult(
            plan=greedy_plan, min_us=t["min_us"], mean_us=t["mean_us"],
            cycles=analytic_cycles(greedy_plan, cfg, has_bias=has_bias),
            is_greedy=True)
        results.append(greedy_result)

    best_us = min(r.min_us for r in results)
    tied = [r for r in results if r.min_us <= best_us * (1.0 + TIE_BAND)]

    def _tie_key(r: CandidateResult):
        gm, gn, gk = r.plan.grid
        # cycles, then fewest grid steps (fewest instructions), then the
        # largest tiles -- a total, deterministic order.
        return (r.cycles, gm * gn * gk,
                -r.plan.tile_m, -r.plan.tile_n, -r.plan.tile_k)

    winner = min(tied, key=_tie_key)

    key = ""
    cache = cache or get_cache()
    df = winner.plan.dataflow
    key = cache.store(cfg, df, m, n, k, has_bias, winner.plan,
                      source="measured" if backend == "pallas"
                      else "proxy+analytic",
                      best_us=winner.min_us, greedy_us=greedy_result.min_us,
                      n_candidates=len(results), persist=persist)
    return TuneReport(plan=winner.plan, candidates=tuple(results),
                      greedy=greedy_result, backend=backend, cache_key=key)


def resolve_plan(cfg: GemminiConfig, m: int, n: int, k: int, *,
                 dataflow: Optional[Dataflow] = None,
                 has_bias: bool = False) -> TilePlan:
    """The plan the engine should run now, honoring the ``tune_mode`` flag."""
    mode = flags.get("tune_mode")
    if mode not in flags.TUNE_MODES:
        raise ValueError(f"GEMMINI_TUNE/tune_mode must be one of "
                         f"{flags.TUNE_MODES}, got {mode!r}")
    if mode == "off":
        return plan_gemm(cfg, m, n, k, dataflow=dataflow, has_bias=has_bias)
    # Resolve the dataflow exactly as plan_gemm would, so cache keys agree
    # (no greedy solve needed on the hit path).
    df = tiling._resolve_dataflow(cfg, dataflow)
    cached = get_cache().lookup(cfg, df, m, n, k, has_bias)
    if cached is not None:
        return cached
    if mode == "cached":
        return plan_gemm(cfg, m, n, k, dataflow=df, has_bias=has_bias)
    return tune_gemm(cfg, m, n, k, dataflow=df, has_bias=has_bias).plan


def tuned_plan_fn(mode: Optional[str] = None
                  ) -> Callable[..., TilePlan]:
    """A ``plan_gemm``-compatible callable for ``core.dse.evaluate``: the
    DSE's measured-cost backend. ``mode`` overrides the flag ("cached" to
    evaluate with yesterday's tuning run, "full" to tune as it sweeps)."""
    def fn(cfg: GemminiConfig, m: int, n: int, k: int, *,
           dataflow: Optional[Dataflow] = None,
           has_bias: bool = False) -> TilePlan:
        if mode is None:
            return resolve_plan(cfg, m, n, k, dataflow=dataflow,
                                has_bias=has_bias)
        prev = flags.get("tune_mode")
        flags.set_flag("tune_mode", mode)
        try:
            return resolve_plan(cfg, m, n, k, dataflow=dataflow,
                                has_bias=has_bias)
        finally:
            flags.set_flag("tune_mode", prev)
    return fn
