"""Empirical kernel-schedule autotuner (GEMM, attention, conv).

The ``resolve_*`` functions are the single entries the kernels' dispatch
layer (``ExecutionContext`` -> ``kernels.ops`` impls) consults on every
un-planned launch: ``resolve_plan`` for ``ctx.gemm``,
``resolve_attn_schedule`` for ``ctx.flash_attention``,
``resolve_conv_schedule`` for ``ctx.conv2d(fused=True)``. All three honor
the same flag (or the dispatching context's scoped ``tune_mode``
override), and under a mesh'd context they run inside ``shard_map``
tracing -- the shapes they fingerprint are per-device shapes:

* ``tune_mode="off"``    -- static schedule (greedy analytic plan for GEMM,
                            the kernels' shipped block-size defaults for
                            attention/conv: the paper's static header).
* ``tune_mode="cached"`` -- persisted tuned schedule if one exists, static
                            otherwise; never measures.
* ``tune_mode="full"``   -- cache hit, else measure the kernel's candidate
                            lattice, pick the winner, persist it.

Winner selection is measurement-led but deterministic: candidates whose
min-of-iters time lands within ``TIE_BAND`` of the best are considered tied
(CPU proxy timings, and even real TPU timings, are noisy at the few-percent
level), and ties break by the analytic decoupled-queue cycle model
(``core.isa``), then by tile shape. On CPU CI hosts every candidate times
identically up to padding, so the analytic model effectively ranks them --
same answer every run.

The tuner doubles as the DSE's measured-cost backend: ``tuned_plan_fn``
returns a drop-in replacement for ``tiling.plan_gemm`` that
``core.dse.evaluate`` accepts, letting the analytic model be calibrated
against measured schedules (ROADMAP follow-on).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

from repro.core import flags, isa
from repro.core import tiling
from repro.core.config import Dataflow, GemminiConfig, bytes_of
from repro.core.tiling import TilePlan, enumerate_plans, plan_gemm
from repro.tune import measure, schedules
from repro.tune.cache import PlanCache, get_cache
from repro.tune.schedules import AttnSchedule, ConvSchedule, PagedAttnSchedule

# Measured times within 5% of the best are a tie -> analytic model decides.
TIE_BAND = 0.05


def _feasibility():
    """The lint layer's contract-feasibility predicates, or None.

    Imported lazily: ``analysis.lint`` imports ``tune.schedules`` for its
    probe lattices, so a module-level import here would cycle. Any import
    failure degrades to "no filtering" -- the tuner must never depend on
    the analysis layer to function.
    """
    try:
        from repro.analysis.lint import feasibility
        return feasibility
    except Exception:
        return None


def _contract_filter(cands, keep_pred, feasible):
    """Drop candidates the kernel-contract linter proves infeasible.

    ``analysis.lint.feasibility`` re-derives each candidate's per-grid-step
    VMEM footprint from the declared kernel contract (the GL301/GL302
    budget proof), so provably-overflowing schedules are dropped before we
    pay to measure them. Strictly advisory: the default/greedy reference
    (``keep_pred``) is always retained -- winner selection dereferences it
    unconditionally -- a predicate error keeps the candidate, and if
    filtering would empty the lattice the original list survives.
    """
    kept = []
    for c in cands:
        try:
            ok = keep_pred(c) or feasible(c)
        except Exception:
            ok = True
        if ok:
            kept.append(c)
    return kept if kept else list(cands)


def _check_mode() -> str:
    mode = flags.get("tune_mode")
    if mode not in flags.TUNE_MODES:
        raise ValueError(f"GEMMINI_TUNE/tune_mode must be one of "
                         f"{flags.TUNE_MODES}, got {mode!r}")
    return mode


def _tie_pick(results, key_fn):
    """Measurement-led, deterministically tie-broken winner selection: the
    candidates within TIE_BAND of the best min-of-iters time are tied and
    ``key_fn`` (analytic cycles first) provides a total order among them."""
    best_us = min(r.min_us for r in results)
    tied = [r for r in results if r.min_us <= best_us * (1.0 + TIE_BAND)]
    return min(tied, key=key_fn)


def analytic_cycles(plan: TilePlan, cfg: GemminiConfig, *,
                    has_bias: bool = False,
                    sys: Optional[isa.SystemParams] = None) -> float:
    """Deterministic cost of the plan *as the TPU kernels lower it*.

    Not ``isa.simulate``: that models the paper's ASIC dataflows (WS keeps B
    resident across M), whereas both Pallas kernels run K-innermost and
    re-fetch the B tile every K step of every output tile (see
    kernels/gemm.py). Ranking candidates by the ASIC model would reward
    B-reuse the lowered kernel does not realize, so the tiebreak uses the
    kernel-faithful traffic:

        A+B fetches = gm*gn*gk*(tm*tk + tk*tn), one C write per output.
    """
    sys = sys or isa.ROCKET
    gm, gn, gk = plan.grid
    tm, tn, tk = plan.tile_m, plan.tile_n, plan.tile_k
    in_b = bytes_of(cfg.input_dtype)
    load_bytes = gm * gn * gk * (tm * tk + tk * tn) * in_b
    if has_bias:
        load_bytes += gm * gn * tm * tn * bytes_of(cfg.acc_dtype)
    store_bytes = plan.m * plan.n * bytes_of(cfg.output_dtype)
    bw = sys.effective_bw(cfg.dim)
    macs_per_cycle = cfg.dim * cfg.dim * (1.0 if cfg.pipeline_depth > 1
                                          else 0.5)
    return max(plan.macs / macs_per_cycle,
               load_bytes / bw, store_bytes / bw)


@dataclasses.dataclass(frozen=True)
class CandidateResult:
    plan: TilePlan
    min_us: float
    mean_us: float
    cycles: float
    is_greedy: bool


@dataclasses.dataclass(frozen=True)
class TuneReport:
    plan: TilePlan                      # the winner
    candidates: Tuple[CandidateResult, ...]
    greedy: CandidateResult
    backend: str
    cache_key: str = ""

    @property
    def speedup_vs_greedy(self) -> float:
        best = min(c.min_us for c in self.candidates)
        return self.greedy.min_us / best if best else 1.0


def tune_gemm(cfg: GemminiConfig, m: int, n: int, k: int, *,
              dataflow: Optional[Dataflow] = None, has_bias: bool = False,
              backend: Optional[str] = None, iters: int = 3,
              max_candidates: int = 16,
              cache: Optional[PlanCache] = None,
              persist: bool = True) -> TuneReport:
    """Measure the candidate lattice and persist the winner."""
    backend = backend or measure.measurement_backend()
    greedy_plan = plan_gemm(cfg, m, n, k, dataflow=dataflow,
                            has_bias=has_bias)
    candidates = enumerate_plans(cfg, m, n, k, dataflow=dataflow,
                                 has_bias=has_bias,
                                 max_candidates=max_candidates)
    feas = _feasibility()
    if feas is not None:
        candidates = _contract_filter(
            candidates,
            lambda p: (p.tile_m, p.tile_n, p.tile_k) ==
                      (greedy_plan.tile_m, greedy_plan.tile_n,
                       greedy_plan.tile_k),
            lambda p: feas.gemm_plan_feasible(cfg, p, has_bias=has_bias))

    results: List[CandidateResult] = []
    greedy_result: Optional[CandidateResult] = None
    # The CPU proxy only observes padded problem dims, so candidates sharing
    # them MUST time identically or host noise (not the analytic tiebreak)
    # would pick the winner: memoize per padded-dims group. Real pallas
    # measurement sees the actual schedule -- never memoized.
    proxy_memo: dict = {}
    for plan in candidates:
        memo_key = (plan.m, plan.n, plan.k) if backend != "pallas" else None
        if memo_key is not None and memo_key in proxy_memo:
            t = proxy_memo[memo_key]
        else:
            t = measure.measure_plan(cfg, plan, has_bias=has_bias,
                                     backend=backend, iters=iters)
            if memo_key is not None:
                proxy_memo[memo_key] = t
        r = CandidateResult(
            plan=plan, min_us=t["min_us"], mean_us=t["mean_us"],
            cycles=analytic_cycles(plan, cfg, has_bias=has_bias),
            is_greedy=(plan.tile_m, plan.tile_n, plan.tile_k) ==
                      (greedy_plan.tile_m, greedy_plan.tile_n,
                       greedy_plan.tile_k))
        results.append(r)
        if r.is_greedy:
            greedy_result = r
    if greedy_result is None:        # greedy always enumerated, but be safe
        t = measure.measure_plan(cfg, greedy_plan, has_bias=has_bias,
                                 backend=backend, iters=iters)
        greedy_result = CandidateResult(
            plan=greedy_plan, min_us=t["min_us"], mean_us=t["mean_us"],
            cycles=analytic_cycles(greedy_plan, cfg, has_bias=has_bias),
            is_greedy=True)
        results.append(greedy_result)

    def _tie_key(r: CandidateResult):
        gm, gn, gk = r.plan.grid
        # cycles, then fewest grid steps (fewest instructions), then the
        # largest tiles -- a total, deterministic order.
        return (r.cycles, gm * gn * gk,
                -r.plan.tile_m, -r.plan.tile_n, -r.plan.tile_k)

    winner = _tie_pick(results, _tie_key)

    key = ""
    cache = cache or get_cache()
    df = winner.plan.dataflow
    key = cache.store(cfg, df, m, n, k, has_bias, winner.plan,
                      source="measured" if backend == "pallas"
                      else "proxy+analytic",
                      best_us=winner.min_us, greedy_us=greedy_result.min_us,
                      n_candidates=len(results), persist=persist)
    return TuneReport(plan=winner.plan, candidates=tuple(results),
                      greedy=greedy_result, backend=backend, cache_key=key)


def resolve_plan(cfg: GemminiConfig, m: int, n: int, k: int, *,
                 dataflow: Optional[Dataflow] = None,
                 has_bias: bool = False) -> TilePlan:
    """The plan the engine should run now, honoring the ``tune_mode`` flag."""
    mode = _check_mode()
    if mode == "off":
        return plan_gemm(cfg, m, n, k, dataflow=dataflow, has_bias=has_bias)
    # Resolve the dataflow exactly as plan_gemm would, so cache keys agree
    # (no greedy solve needed on the hit path).
    df = tiling._resolve_dataflow(cfg, dataflow)
    cached = get_cache().lookup(cfg, df, m, n, k, has_bias)
    if cached is not None:
        return cached
    if mode == "cached":
        return plan_gemm(cfg, m, n, k, dataflow=df, has_bias=has_bias)
    return tune_gemm(cfg, m, n, k, dataflow=df, has_bias=has_bias).plan


# ---------------------------------------------------------------------------
# attention / conv schedule tuning (kernel-agnostic layer)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SchedResult:
    """One measured candidate of a non-GEMM schedule space."""

    sched: object                       # AttnSchedule | ConvSchedule
    min_us: float
    mean_us: float
    cycles: float
    is_default: bool


@dataclasses.dataclass(frozen=True)
class SchedReport:
    sched: object                       # the winner
    candidates: Tuple[SchedResult, ...]
    default: SchedResult                # the static (untuned) schedule
    backend: str
    cache_key: str = ""

    @property
    def speedup_vs_default(self) -> float:
        best = min(c.min_us for c in self.candidates)
        return self.default.min_us / best if best else 1.0


def _sched_tie_key(r: SchedResult):
    # cycles, then the default schedule (prefer the shipped static blocking
    # on a true tie), then the largest blocks (fewest grid steps) -- a
    # total, deterministic order, mirroring the GEMM tiebreak.
    return (r.cycles, not r.is_default,
            tuple(-v for v in dataclasses.astuple(r.sched)))


def tune_attention(cfg: GemminiConfig, b: int, tq: int, tk: int, h: int,
                   kvh: int, d: int, *, causal: bool = True,
                   window: Optional[int] = None, dtype="bf16",
                   backend: Optional[str] = None, iters: int = 3,
                   max_candidates: int = 16,
                   cache: Optional[PlanCache] = None,
                   persist: bool = True) -> SchedReport:
    """Measure the (block_q, block_k) lattice and persist the winner."""
    backend = backend or measure.measurement_backend()
    in_bytes = schedules.schedule_dtype(dtype).itemsize
    default = schedules.default_attn_schedule().effective(tq, tk)
    cands = schedules.enumerate_attn_schedules(
        cfg, b, h, kvh, tq, tk, d, causal=causal, window=window,
        in_bytes=in_bytes, max_candidates=max_candidates)
    feas = _feasibility()
    if feas is not None:
        cands = _contract_filter(
            cands,
            lambda s: s.effective(tq, tk) == default,
            lambda s: feas.attn_schedule_feasible(
                cfg, s, b=b, h=h, kvh=kvh, tq=tq, tk=tk, d=d, dtype=dtype))

    results: List[SchedResult] = []
    # The XLA proxy cannot see block_q (no q blocking in the blockwise
    # path) but DOES execute block_k (its KV scan length), so memoize per
    # (padded dims, block_k): candidates the proxy cannot distinguish must
    # time identically (analytic tiebreak decides), while distinct KV
    # blockings are measured for real.
    proxy_memo: dict = {}
    for s in cands:
        eff = s.effective(tq, tk)
        nq, nk = -(-tq // eff.block_q), -(-tk // eff.block_k)
        memo_key = ((nq * eff.block_q, nk * eff.block_k, eff.block_k)
                    if backend != "pallas" else None)
        if memo_key is not None and memo_key in proxy_memo:
            t = proxy_memo[memo_key]
        else:
            t = measure.measure_attn_schedule(
                cfg, s, b, tq, tk, h, kvh, d, causal=causal, window=window,
                dtype=dtype, backend=backend, iters=iters)
            if memo_key is not None:
                proxy_memo[memo_key] = t
        results.append(SchedResult(
            sched=eff, min_us=t["min_us"], mean_us=t["mean_us"],
            cycles=schedules.attn_cycles(s, cfg, b, h, kvh, tq, tk, d,
                                         causal=causal, window=window,
                                         in_bytes=in_bytes),
            is_default=(eff == default)))
    default_result = next(r for r in results if r.is_default)
    winner = _tie_pick(results, _sched_tie_key)

    cache = cache or get_cache()
    key = schedules.attn_cache_key(cfg, b, tq, tk, h, kvh, d, causal=causal,
                                   window=window, dtype=dtype)
    key = cache.store_schedule(
        key, {"block_q": winner.sched.block_q, "block_k": winner.sched.block_k},
        source="measured" if backend == "pallas" else "proxy+analytic",
        best_us=winner.min_us, greedy_us=default_result.min_us,
        n_candidates=len(results), persist=persist)
    return SchedReport(sched=winner.sched, candidates=tuple(results),
                       default=default_result, backend=backend, cache_key=key)


def resolve_attn_schedule(cfg: GemminiConfig, b: int, tq: int, tk: int,
                          h: int, kvh: int, d: int, *, causal: bool = True,
                          window: Optional[int] = None,
                          dtype="bf16") -> AttnSchedule:
    """The attention blocking to launch now, honoring ``tune_mode``."""
    mode = _check_mode()
    if mode == "off":
        return schedules.default_attn_schedule()
    key = schedules.attn_cache_key(cfg, b, tq, tk, h, kvh, d, causal=causal,
                                   window=window, dtype=dtype)
    params = get_cache().lookup_schedule(key, ("block_q", "block_k"))
    if params is not None:
        return AttnSchedule(params["block_q"], params["block_k"])
    if mode == "cached":
        return schedules.default_attn_schedule()
    return tune_attention(cfg, b, tq, tk, h, kvh, d, causal=causal,
                          window=window, dtype=dtype).sched


def tune_paged_attention(cfg: GemminiConfig, b: int, h: int, kvh: int,
                         d: int, max_context: int, *,
                         window: Optional[int] = None, dtype="bf16",
                         backend: Optional[str] = None, iters: int = 3,
                         max_candidates: int = 8,
                         cache: Optional[PlanCache] = None,
                         persist: bool = True) -> SchedReport:
    """Measure the page-size lattice for the paged decode kernel and
    persist the winner. Measured at a full-context decode batch (the
    worst-case step the engine must sustain); the analytic tiebreak
    (``schedules.paged_attn_cycles``) additionally prices the allocator's
    internal-fragmentation cost, which wall time alone cannot see."""
    backend = backend or measure.measurement_backend()
    in_bytes = schedules.schedule_dtype(dtype).itemsize
    default = schedules.default_paged_schedule().effective(max_context)
    cands = schedules.enumerate_paged_schedules(
        cfg, b, h, kvh, d, max_context, window=window, in_bytes=in_bytes,
        max_candidates=max_candidates)
    feas = _feasibility()
    if feas is not None:
        cands = _contract_filter(
            cands,
            lambda s: s.effective(max_context) == default,
            lambda s: feas.paged_schedule_feasible(
                cfg, s, b=b, h=h, kvh=kvh, d=d, max_context=max_context,
                dtype=dtype))

    results: List[SchedResult] = []
    for s in cands:
        eff = s.effective(max_context)
        t = measure.measure_paged_schedule(
            cfg, s, b, h, kvh, d, max_context, window=window, dtype=dtype,
            backend=backend, iters=iters)
        results.append(SchedResult(
            sched=eff, min_us=t["min_us"], mean_us=t["mean_us"],
            cycles=schedules.paged_attn_cycles(s, cfg, b, h, kvh, d,
                                               max_context, window=window,
                                               in_bytes=in_bytes),
            is_default=(eff == default)))
    default_result = next(r for r in results if r.is_default)
    winner = _tie_pick(results, _sched_tie_key)

    cache = cache or get_cache()
    key = schedules.paged_attn_cache_key(cfg, b, h, kvh, d, max_context,
                                         window=window, dtype=dtype)
    key = cache.store_schedule(
        key, {"page_size": winner.sched.page_size},
        source="measured" if backend == "pallas" else "proxy+analytic",
        best_us=winner.min_us, greedy_us=default_result.min_us,
        n_candidates=len(results), persist=persist)
    return SchedReport(sched=winner.sched, candidates=tuple(results),
                       default=default_result, backend=backend,
                       cache_key=key)


def resolve_paged_attn_schedule(cfg: GemminiConfig, b: int, h: int, kvh: int,
                                d: int, max_context: int, *,
                                window: Optional[int] = None,
                                dtype="bf16") -> PagedAttnSchedule:
    """The page size the serving engine should size its pools with now,
    honoring ``tune_mode``. Called once at engine startup (the page size is
    baked into the pool allocation), never on the request path."""
    mode = _check_mode()
    if mode == "off":
        return schedules.default_paged_schedule().effective(max_context)
    key = schedules.paged_attn_cache_key(cfg, b, h, kvh, d, max_context,
                                         window=window, dtype=dtype)
    params = get_cache().lookup_schedule(key, ("page_size",))
    if params is not None:
        return PagedAttnSchedule(params["page_size"])
    if mode == "cached":
        return schedules.default_paged_schedule().effective(max_context)
    return tune_paged_attention(cfg, b, h, kvh, d, max_context,
                                window=window, dtype=dtype).sched


def tune_conv(cfg: GemminiConfig, n: int, h: int, w: int, ci: int, co: int,
              kh: int, kw: int, *, stride: int = 1, padding: int = 0,
              has_bias: bool = False, backend: Optional[str] = None,
              iters: int = 3, max_candidates: int = 12,
              cache: Optional[PlanCache] = None,
              persist: bool = True) -> SchedReport:
    """Measure the co_tile lattice and persist the winner."""
    backend = backend or measure.measurement_backend()
    default = schedules.default_conv_schedule().effective(co)
    cands = schedules.enumerate_conv_schedules(
        cfg, n, h, w, ci, co, kh, kw, stride=stride, padding=padding,
        has_bias=has_bias, max_candidates=max_candidates)
    if default not in cands:
        cands.append(default)
    feas = _feasibility()
    if feas is not None:
        cands = _contract_filter(
            cands,
            lambda s: s.effective(co) == default,
            lambda s: feas.conv_schedule_feasible(
                cfg, s, n=n, h=h, w=w, ci=ci, co=co, kh=kh, kw=kw,
                stride=stride, padding=padding, has_bias=has_bias))

    results: List[SchedResult] = []
    proxy_memo: dict = {}
    for s in cands:
        eff = s.effective(co)
        nco = -(-co // eff.co_tile)
        memo_key = nco * eff.co_tile if backend != "pallas" else None
        if memo_key is not None and memo_key in proxy_memo:
            t = proxy_memo[memo_key]
        else:
            t = measure.measure_conv_schedule(
                cfg, s, n, h, w, ci, co, kh, kw, stride=stride,
                padding=padding, has_bias=has_bias, backend=backend,
                iters=iters)
            if memo_key is not None:
                proxy_memo[memo_key] = t
        results.append(SchedResult(
            sched=eff, min_us=t["min_us"], mean_us=t["mean_us"],
            cycles=schedules.conv_cycles(s, cfg, n, h, w, ci, co, kh, kw,
                                         stride=stride, padding=padding,
                                         has_bias=has_bias),
            is_default=(eff == default)))
    default_result = next(r for r in results if r.is_default)
    winner = _tie_pick(results, _sched_tie_key)

    cache = cache or get_cache()
    key = schedules.conv_cache_key(cfg, n, h, w, ci, co, kh, kw,
                                   stride=stride, padding=padding,
                                   has_bias=has_bias)
    key = cache.store_schedule(
        key, {"co_tile": winner.sched.co_tile},
        source="measured" if backend == "pallas" else "proxy+analytic",
        best_us=winner.min_us, greedy_us=default_result.min_us,
        n_candidates=len(results), persist=persist)
    return SchedReport(sched=winner.sched, candidates=tuple(results),
                       default=default_result, backend=backend, cache_key=key)


def resolve_conv_schedule(cfg: GemminiConfig, n: int, h: int, w: int,
                          ci: int, co: int, kh: int, kw: int, *,
                          stride: int = 1, padding: int = 0,
                          has_bias: bool = False) -> ConvSchedule:
    """The conv co_tile to launch now, honoring ``tune_mode``."""
    mode = _check_mode()
    if mode == "off":
        return schedules.default_conv_schedule()
    key = schedules.conv_cache_key(cfg, n, h, w, ci, co, kh, kw,
                                   stride=stride, padding=padding,
                                   has_bias=has_bias)
    params = get_cache().lookup_schedule(key, ("co_tile",))
    if params is not None:
        return ConvSchedule(params["co_tile"])
    if mode == "cached":
        return schedules.default_conv_schedule()
    return tune_conv(cfg, n, h, w, ci, co, kh, kw, stride=stride,
                     padding=padding, has_bias=has_bias).sched


def tuned_plan_fn(mode: Optional[str] = None
                  ) -> Callable[..., TilePlan]:
    """A ``plan_gemm``-compatible callable for ``core.dse.evaluate``: the
    DSE's measured-cost backend. ``mode`` overrides the flag ("cached" to
    evaluate with yesterday's tuning run, "full" to tune as it sweeps)."""
    def fn(cfg: GemminiConfig, m: int, n: int, k: int, *,
           dataflow: Optional[Dataflow] = None,
           has_bias: bool = False) -> TilePlan:
        if mode is None:
            return resolve_plan(cfg, m, n, k, dataflow=dataflow,
                                has_bias=has_bias)
        prev = flags.get("tune_mode")
        flags.set_flag("tune_mode", mode)
        try:
            return resolve_plan(cfg, m, n, k, dataflow=dataflow,
                                has_bias=has_bias)
        finally:
            flags.set_flag("tune_mode", prev)
    return fn
