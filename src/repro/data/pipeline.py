"""Deterministic, host-sharded synthetic LM data pipeline.

Design constraints (1000+-node deployments):

* **Stateless addressing.** Batch ``step`` is a pure function of
  ``(seed, step, row)`` -- no data-loader state to checkpoint, no
  coordination between hosts. After a restart (or an *elastic reshard* onto
  a different number of hosts) every host regenerates exactly the rows it
  now owns; the global batch is bit-identical regardless of topology.
* **Host-sharded materialization.** ``make_global_batch`` builds the
  globally-sharded jax.Array via ``jax.make_array_from_callback``: each
  process touches only the rows its addressable shards need -- O(B/hosts)
  host memory, never the full global batch.
* **Structured enough to learn.** Rows are Markov-chain token streams (a
  fixed random transition table seeded by ``seed``) with document breaks,
  so cross-entropy on it has a non-trivial optimum: the end-to-end example
  can show a falling loss, not just moving bytes.

The same generator also serves the multimodal stubs: ``extra_embeds`` (VLM
patch / audio-frame embeddings) are deterministic low-rank random features
of the row id, per the assignment's "frontend is a STUB" instruction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    branching: int = 4          # Markov out-degree (lower = more learnable)
    doc_len: int = 1024         # average synthetic document length
    n_codebooks: int = 1        # musicgen-style multi-stream tokens
    pad_id: int = -100          # label id carrying no loss


class SyntheticLM:
    """Deterministic Markov-chain token stream."""

    def __init__(self, cfg: SyntheticLMConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab, 32768)   # cap table size for huge vocabs
        self._v = v
        # per-state successor table: (v, branching)
        self._table = rng.integers(0, v, (v, cfg.branching), dtype=np.int64)

    # -- row generation ------------------------------------------------------
    def _row_rng(self, step: int, row: int) -> np.random.Generator:
        # stable address: independent of host count / sharding
        return np.random.default_rng(
            (self.cfg.seed * 0x9E3779B9 + step * 1_000_003 + row) % (2**63))

    def row(self, step: int, row: int) -> np.ndarray:
        """One (seq,) [or (seq, n_codebooks)] int32 token row."""
        cfg = self.cfg
        rng = self._row_rng(step, row)
        n_q = max(1, cfg.n_codebooks)
        out = np.empty((cfg.seq, n_q), np.int32)
        for q in range(n_q):
            state = int(rng.integers(0, self._v))
            choices = rng.integers(0, cfg.branching, cfg.seq)
            breaks = rng.random(cfg.seq) < (1.0 / cfg.doc_len)
            toks = np.empty((cfg.seq,), np.int64)
            for t in range(cfg.seq):
                if breaks[t]:
                    state = int(rng.integers(0, self._v))
                toks[t] = state
                state = int(self._table[state, choices[t]])
            out[:, q] = toks.astype(np.int32)
        return out if n_q > 1 else out[:, 0]

    def host_batch(self, step: int, rows: range) -> Dict[str, np.ndarray]:
        """The given global-row range (this host's shard) for ``step``."""
        toks = np.stack([self.row(step, r) for r in rows])
        return {"tokens": toks, "labels": toks.copy()}


def make_global_batch(gen: SyntheticLM, step: int, sharding,
                      extra_embed_dim: Optional[int] = None,
                      extra_tokens: int = 0) -> Dict[str, jax.Array]:
    """Build the globally-sharded batch; each process generates only the
    rows its addressable shards cover."""
    cfg = gen.cfg
    n_q = max(1, cfg.n_codebooks)
    shape: Tuple[int, ...] = (cfg.global_batch, cfg.seq)
    if n_q > 1:
        shape = shape + (n_q,)

    cache: Dict[Tuple[int, int], np.ndarray] = {}

    def rows_for(index) -> np.ndarray:
        r = index[0]
        start = 0 if r.start is None else r.start
        stop = shape[0] if r.stop is None else r.stop
        key = (start, stop)
        if key not in cache:
            cache[key] = np.stack([gen.row(step, i)
                                   for i in range(start, stop)])
        block = cache[key]
        return block[(slice(None),) + tuple(index[1:])]

    tokens = jax.make_array_from_callback(shape, sharding, rows_for)
    out = {"tokens": tokens, "labels": tokens}
    if extra_embed_dim:
        # multimodal stub: deterministic low-rank features of the row id
        eshape = (cfg.global_batch, extra_tokens, extra_embed_dim)

        def embeds_for(index):
            idx = np.arange(eshape[0])[index[0]].reshape(-1, 1, 1)
            t = np.arange(eshape[1])[index[1]].reshape(1, -1, 1)
            d = np.arange(eshape[2])[index[2]].reshape(1, 1, -1)
            val = np.sin(0.1 * (idx * 131 + t * 17 + d) + cfg.seed)
            return val.astype(np.float32)

        out["extra_embeds"] = jax.make_array_from_callback(
            eshape, sharding if len(sharding.spec) == 3 else
            jax.sharding.NamedSharding(
                sharding.mesh, jax.sharding.PartitionSpec(
                    *(tuple(sharding.spec)[:1] + (None, None)))),
            embeds_for)
    return out
