from repro.data.pipeline import (SyntheticLMConfig, SyntheticLM,
                                 make_global_batch)

__all__ = ["SyntheticLMConfig", "SyntheticLM", "make_global_batch"]
