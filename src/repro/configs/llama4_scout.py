"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) expert
d_ff=8192, vocab=202048, MoE 16 experts top-1 + shared expert, sigmoid
router weights applied to the expert *input* (llama4 style). Early-fusion
multimodality is out of backbone scope per the assignment (text tokens only).
"""

from repro.configs import register
from repro.models.transformer import ModelConfig


@register("llama4-scout-17b-a16e")
def llama4_scout() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        vocab=202048,
        n_experts=16,
        top_k=1,
        moe_d_ff=8192,
        n_shared_experts=1,
        router_weights_before=True,
        activation="silu",
        rope_base=500_000.0,
        tie_embeddings=False,
    )
