"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) vocab=49155,
MoE 40 experts top-8, expert d_ff=512.

Note: the assignment sheet's structured field says 40 experts while its
prose says 32; the HF config for granite-3.0-3b-a800m has 40, so 40 is used
(see DESIGN.md section 5). 40 experts are padded to 48 slots for 16-way EP.
"""

from repro.configs import register
from repro.models.transformer import ModelConfig


@register("granite-moe-3b-a800m")
def granite_moe_3b() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        head_dim=64,
        vocab=49155,
        n_experts=40,
        top_k=8,
        moe_d_ff=512,
        activation="silu",
        tie_embeddings=True,
    )
