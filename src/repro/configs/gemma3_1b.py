"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1, MQA) d_ff=6912
vocab=262144 -- 5:1 local:global, 128k context, qk-norm."""

from repro.configs import register
from repro.models.transformer import ModelConfig


@register("gemma3-1b")
def gemma3_1b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab=262144,
        activation="gelu",
        local_window=512,
        global_period=6,            # 5 local : 1 global
        rope_base=1_000_000.0,      # global layers
        rope_base_local=10_000.0,   # local layers
        qk_norm=True,
        post_norms=True,
        embed_scale=True,
        tie_embeddings=True,
    )
