"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 -- anyres tiling. Backbone only; the vision tower is a stub:
input_specs provides precomputed patch embeddings (576 tokens per image
tile, prepended to the text tokens)."""

from repro.configs import register
from repro.models.transformer import ModelConfig

N_IMAGE_TOKENS = 576  # one anyres base tile of 24x24 patches


@register("llava-next-34b")
def llava_next_34b() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="dense",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab=64000,
        activation="silu",
        rope_base=1_000_000.0,
        tie_embeddings=False,
        modality="vlm",
    )
