"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 -- 5:1 local:global, 128k context, qk-norm."""

from repro.configs import register
from repro.models.transformer import ModelConfig


@register("gemma3-4b")
def gemma3_4b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab=262144,
        activation="gelu",
        local_window=1024,
        global_period=6,            # 5 local : 1 global
        rope_base=1_000_000.0,
        rope_base_local=10_000.0,
        qk_norm=True,
        post_norms=True,
        embed_scale=True,
        tie_embeddings=True,
    )
