"""Architecture registry: the 10 assigned archs + paper DSE design points.

``get(name)`` returns the full-size ModelConfig; ``get_smoke(name)`` returns
the reduced same-family config used by CPU smoke tests.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.models.transformer import ModelConfig

_REGISTRY: Dict[str, Tuple[Callable[[], ModelConfig],
                           Callable[[], ModelConfig]]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def get_smoke(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    import dataclasses
    cfg = get(name)
    kw = dict(
        n_layers=2, d_model=64, vocab=128, d_ff=128 if cfg.d_ff else 0,
        head_dim=16, dtype=cfg.dtype)
    if cfg.has_attn:
        kw.update(n_heads=4, n_kv_heads=max(1, cfg.n_kv_heads * 4
                                            // max(cfg.n_heads, 1)))
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2), moe_d_ff=32,
                  expert_padding=1)
    if cfg.has_ssm:
        kw.update(d_state=8, ssm_head_dim=8)
    if cfg.local_window:
        kw.update(local_window=8)
    if cfg.n_meta_tokens:
        kw.update(n_meta_tokens=4)
    return dataclasses.replace(cfg, **kw)


def names():
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    from repro.configs import (gemma2_2b, gemma3_1b, gemma3_4b,        # noqa
                               granite_moe_3b, hymba_1_5b,
                               llama4_scout, llava_next_34b,
                               mamba2_1_3b, musicgen_medium, qwen1_5_4b)
    _LOADED = True


# archs for which long_500k is runnable (sub-quadratic; see DESIGN.md sec. 5)
LONG_CONTEXT_ARCHS = frozenset({
    "gemma2-2b", "gemma3-1b", "gemma3-4b", "hymba-1.5b", "mamba2-1.3b"})

ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def shapes_for(arch: str):
    base = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        base.append("long_500k")
    return tuple(base)
