"""musicgen-medium [audio]: 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 -- decoder-only over EnCodec tokens, 4 codebooks (embeddings
summed at input, 4 parallel output heads). The EnCodec frontend is a stub:
tokens are the 4-codebook integer frames; conditioning embeddings come via
``extra_embeds``. RoPE replaces sinusoidal positions (TPU adaptation note
in DESIGN.md)."""

from repro.configs import register
from repro.models.transformer import ModelConfig


@register("musicgen-medium")
def musicgen_medium() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="dense",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab=2048,
        n_codebooks=4,
        activation="gelu",
        tie_embeddings=False,
        modality="audio",
    )
