"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20, i.e. MHA) d_ff=6912
vocab=151936 -- QKV bias (the Gemmini D-bias path, a native engine feature)."""

from repro.configs import register
from repro.models.transformer import ModelConfig


@register("qwen1.5-4b")
def qwen1_5_4b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        head_dim=128,
        d_ff=6912,
        vocab=151936,
        activation="silu",
        qkv_bias=True,
        rope_base=1_000_000.0,
        tie_embeddings=False,
    )
