"""mamba2-1.3b [ssm]: 48L d_model=2048, attention-free, vocab=50280,
ssm_state=128 -- SSD (state-space duality), expand=2 (d_inner=4096),
64 heads of dim 64, causal conv width 4."""

from repro.configs import register
from repro.models.transformer import ModelConfig


@register("mamba2-1.3b")
def mamba2_1_3b() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        vocab=50280,
        d_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_groups=1,
        d_conv=4,
        ssm_chunk=256,
        activation="silu",
        tie_embeddings=True,
    )
