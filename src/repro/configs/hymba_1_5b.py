"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 -- parallel attention + mamba heads in every
block (outputs per-branch normalized then averaged), 128 meta tokens,
sliding-window attention with a few global layers."""

from repro.configs import register
from repro.models.transformer import ModelConfig


@register("hymba-1.5b")
def hymba_1_5b() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab=32001,
        activation="silu",
        local_window=1024,
        global_period=16,          # ~2 global layers (paper: first/mid/last)
        d_state=16,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_groups=1,
        n_meta_tokens=128,
        tie_embeddings=True,
    )
