"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000
-- local+global alternating (1:1), logit softcap, post-norms."""

from repro.configs import register
from repro.models.transformer import ModelConfig


@register("gemma2-2b")
def gemma2_2b() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab=256000,
        activation="gelu",
        attn_softcap=50.0,
        final_softcap=30.0,
        local_window=4096,
        global_period=2,          # alternate local / global
        post_norms=True,
        embed_scale=True,
        tie_embeddings=True,
    )
