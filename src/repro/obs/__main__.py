"""Trace inspector CLI: ``python -m repro.obs <trace> [--check] [--top K]``.

Summarizes an exported trace (Chrome-trace JSON or JSONL event log):
top-k spans by self time, the kernel utilization table (when the trace
carries profiled ``cat="kernel"`` spans), and a per-request lifecycle
timeline.  ``--check`` validates the Chrome-trace schema and exits
non-zero on any violation — CI runs it as a gate on the serve smoke's
trace artifact.
"""

from __future__ import annotations

import argparse
import collections
import json
import sys
from typing import Any, Dict, Iterable, List

from repro.obs import trace as otrace


def _fmt_ms(us: float) -> str:
    return f"{us / 1e3:.3f}ms"


def top_spans(events: Iterable[Dict[str, Any]], k: int) -> List[str]:
    agg: Dict[tuple, List[float]] = collections.defaultdict(lambda: [0, 0.0])
    for ev in events:
        if ev.get("ph") != "X":
            continue
        cell = agg[(ev.get("cat", "?"), ev["name"])]
        cell[0] += 1
        cell[1] += float(ev.get("dur", 0.0))
    ranked = sorted(agg.items(), key=lambda kv: kv[1][1], reverse=True)[:k]
    lines = [f"{'span':<36} {'cat':<10} {'count':>7} {'total':>12}"]
    for (cat, name), (count, total) in ranked:
        lines.append(f"{name:<36} {cat:<10} {count:>7} {_fmt_ms(total):>12}")
    return lines


def kernel_table(events: Iterable[Dict[str, Any]]) -> List[str]:
    agg: Dict[tuple, Dict[str, float]] = {}
    for ev in events:
        if ev.get("ph") != "X" or ev.get("cat") != "kernel":
            continue
        args = ev.get("args") or {}
        key = (ev["name"], args.get("contract"), args.get("sig"))
        cell = agg.setdefault(key, {"calls": 0, "total": 0.0,
                                    "best": float("inf"),
                                    "flops": float(args.get("flops") or 0.0),
                                    "bytes": float(args.get("bytes") or 0.0)})
        cell["calls"] += 1
        cell["total"] += float(ev.get("dur", 0.0))
        cell["best"] = min(cell["best"], float(ev.get("dur", 0.0)))
    if not agg:
        return []
    from repro.analysis.roofline import HBM_BW, PEAK_FLOPS_BF16
    lines = [f"{'op':<24} {'contract':<24} {'calls':>6} {'best':>10} "
             f"{'comp%':>7} {'mem%':>7}"]
    for (name, contract, _sig), cell in sorted(
            agg.items(), key=lambda kv: kv[1]["total"], reverse=True):
        best_s = cell["best"] / 1e6
        cu = (cell["flops"] / best_s / PEAK_FLOPS_BF16 * 100) if best_s else 0
        mu = (cell["bytes"] / best_s / HBM_BW * 100) if best_s else 0
        lines.append(f"{name:<24} {str(contract):<24} {cell['calls']:>6.0f} "
                     f"{_fmt_ms(cell['best']):>10} {cu:>7.2f} {mu:>7.2f}")
    return lines


def request_timeline(events: Iterable[Dict[str, Any]]) -> List[str]:
    by_req: Dict[int, List[Dict[str, Any]]] = collections.defaultdict(list)
    for ev in events:
        tid = ev.get("tid", 0)
        if isinstance(tid, int) and tid >= otrace.REQ_TID_BASE:
            by_req[tid - otrace.REQ_TID_BASE].append(ev)
    lines: List[str] = []
    for rid in sorted(by_req):
        evs = sorted(by_req[rid], key=lambda e: float(e.get("ts", 0.0)))
        steps = []
        for ev in evs:
            stamp = _fmt_ms(float(ev.get("ts", 0.0)))
            if ev.get("ph") == "X":
                steps.append(f"{ev['name']}@{stamp}"
                             f"(+{_fmt_ms(float(ev.get('dur', 0.0)))})")
            else:
                steps.append(f"{ev['name']}@{stamp}")
        lines.append(f"req {rid}: " + " -> ".join(steps))
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize / validate an exported repro.obs trace.")
    ap.add_argument("trace", help="Chrome-trace JSON or JSONL event log")
    ap.add_argument("--check", action="store_true",
                    help="validate Chrome-trace schema; non-zero exit on "
                         "violations (CI gate)")
    ap.add_argument("--top", type=int, default=15,
                    help="spans to list in the top-k table")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of text")
    args = ap.parse_args(argv)

    try:
        events = otrace.load(args.trace)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot load {args.trace}: {e}", file=sys.stderr)
        return 2

    if args.check:
        errors = otrace.validate_chrome(events)
        if errors:
            for err in errors:
                print(f"SCHEMA: {err}", file=sys.stderr)
            print(f"{args.trace}: {len(errors)} schema violation(s)",
                  file=sys.stderr)
            return 1
        print(f"{args.trace}: OK ({len(events)} events)")
        return 0

    if args.json:
        payload = {
            "events": len(events),
            "spans": sum(1 for e in events if e.get("ph") == "X"),
            "instants": sum(1 for e in events if e.get("ph") in ("i", "I")),
            "requests": len({e["tid"] - otrace.REQ_TID_BASE for e in events
                             if isinstance(e.get("tid"), int)
                             and e["tid"] >= otrace.REQ_TID_BASE}),
        }
        print(json.dumps(payload, indent=2))
        return 0

    print(f"== {args.trace}: {len(events)} events ==")
    print()
    print("-- top spans by total time --")
    for line in top_spans(events, args.top):
        print(line)
    kt = kernel_table(events)
    if kt:
        print()
        print("-- kernel utilization (from profiled spans) --")
        for line in kt:
            print(line)
    tl = request_timeline(events)
    if tl:
        print()
        print("-- request timelines --")
        for line in tl[:50]:
            print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
