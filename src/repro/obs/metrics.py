"""Labelled metrics registry: counters, gauges, histograms.

Replaces the serving engine's ad-hoc ``self.counters`` dict with one
schema that feeds ``summarize()``, ``BENCH_serving.json`` rows, and the
tracer's counter tracks.  Everything is plain-Python and allocation-light
so the registry can sit on the engine hot path.

Identity model: a metric is ``(name, frozenset(labels.items()))``.
``value(name)`` aggregates across all label sets of a counter, which is
what bench rows want (``registry.value("preemptions")`` regardless of
which policy label fired them).
"""

from __future__ import annotations

import collections
import math
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

LabelKey = Tuple[str, Tuple[Tuple[str, Any], ...]]


def _key(name: str, labels: Dict[str, Any]) -> LabelKey:
    return (name, tuple(sorted(labels.items())))


@dataclass
class Counter:
    name: str
    labels: Dict[str, Any]
    value: float = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


@dataclass
class Gauge:
    """Last-write-wins gauge that also tracks min/max over its lifetime.

    With ``series_capacity > 0`` it keeps a bounded (t, value) time
    series — used for arena occupancy / queue depth tracks.
    """

    name: str
    labels: Dict[str, Any]
    value: Optional[float] = None
    max: Optional[float] = None
    min: Optional[float] = None
    series: Optional[Deque[Tuple[float, float]]] = field(default=None, repr=False)

    def set(self, value: float, t: Optional[float] = None) -> None:
        self.value = value
        self.max = value if self.max is None else max(self.max, value)
        self.min = value if self.min is None else min(self.min, value)
        if self.series is not None and t is not None:
            self.series.append((t, value))


@dataclass
class Histogram:
    """Reservoir of observations with exact percentiles.

    Bounded: keeps the most recent ``capacity`` samples plus running
    count/sum so rates stay exact even after the window slides.
    """

    name: str
    labels: Dict[str, Any]
    capacity: int = 4096
    count: int = 0
    sum: float = 0.0
    samples: Deque[float] = field(default_factory=collections.deque, repr=False)

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        if len(self.samples) >= self.capacity:
            self.samples.popleft()
        self.samples.append(value)

    def percentile(self, p: float) -> Optional[float]:
        """Exact percentile over the retained window; None when empty."""
        if not self.samples:
            return None
        xs = sorted(self.samples)
        rank = (p / 100.0) * (len(xs) - 1)
        lo = math.floor(rank)
        hi = math.ceil(rank)
        if lo == hi:
            return xs[int(rank)]
        frac = rank - lo
        return xs[lo] * (1 - frac) + xs[hi] * frac

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None


class MetricsRegistry:
    """Registry of counters/gauges/histograms keyed by (name, labels)."""

    def __init__(self, *, gauge_series: int = 0) -> None:
        self._counters: Dict[LabelKey, Counter] = {}
        self._gauges: Dict[LabelKey, Gauge] = {}
        self._histograms: Dict[LabelKey, Histogram] = {}
        self._gauge_series = gauge_series

    # -------------------------------------------------------------- lookup

    def counter(self, name: str, **labels: Any) -> Counter:
        k = _key(name, labels)
        c = self._counters.get(k)
        if c is None:
            c = self._counters[k] = Counter(name, labels)
        return c

    def gauge(self, name: str, **labels: Any) -> Gauge:
        k = _key(name, labels)
        g = self._gauges.get(k)
        if g is None:
            series: Optional[Deque[Tuple[float, float]]] = (
                collections.deque(maxlen=self._gauge_series)
                if self._gauge_series > 0 else None)
            g = self._gauges[k] = Gauge(name, labels, series=series)
        return g

    def histogram(self, name: str, **labels: Any) -> Histogram:
        k = _key(name, labels)
        h = self._histograms.get(k)
        if h is None:
            h = self._histograms[k] = Histogram(name, labels)
        return h

    # ----------------------------------------------------------- aggregate

    def value(self, name: str) -> float:
        """Sum of a counter across every label set (0.0 if never touched)."""
        return sum(c.value for c in self._counters.values() if c.name == name)

    def gauge_peak(self, name: str) -> Optional[float]:
        peaks = [g.max for g in self._gauges.values()
                 if g.name == name and g.max is not None]
        return max(peaks) if peaks else None

    def snapshot(self) -> Dict[str, Any]:
        """Flat, JSON-ready view of every metric (used by bench rows)."""

        def tag(m) -> str:
            if not m.labels:
                return m.name
            lbl = ",".join(f"{k}={v}" for k, v in sorted(m.labels.items()))
            return f"{m.name}{{{lbl}}}"

        out: Dict[str, Any] = {"counters": {}, "gauges": {}, "histograms": {}}
        for c in self._counters.values():
            out["counters"][tag(c)] = c.value
        for g in self._gauges.values():
            out["gauges"][tag(g)] = {"last": g.value, "max": g.max, "min": g.min}
        for h in self._histograms.values():
            out["histograms"][tag(h)] = {
                "count": h.count, "mean": h.mean,
                "p50": h.percentile(50), "p95": h.percentile(95),
                "p99": h.percentile(99),
            }
        return out

    def counters_flat(self) -> Dict[str, float]:
        """Per-name counter totals (labels aggregated)."""
        out: Dict[str, float] = {}
        for c in self._counters.values():
            out[c.name] = out.get(c.name, 0.0) + c.value
        return out

    def gauge_peaks(self) -> Dict[str, float]:
        """Per-name gauge maxima, suffixed ``_peak`` for summary merging."""
        out: Dict[str, float] = {}
        for g in self._gauges.values():
            if g.max is None:
                continue
            k = f"{g.name}_peak"
            out[k] = g.max if k not in out else max(out[k], g.max)
        return out
