"""Ring-buffered span tracer with Chrome-trace / JSONL export.

The tracer is the single event sink for the whole stack: the serving
engine emits request-lifecycle and step-phase spans, the paged allocator
emits alloc/extend/evict/defrag events, the tuner emits measurement
spans, the fault injector emits fault-fire instants, and the kernel
profiler emits per-op timing spans.  Everything lands in one bounded
`collections.deque` ring, so an always-on tracer in a long-running
server costs O(capacity) memory and a dict append per event.

Tracing is **off by default**.  It activates through any of:

- ``ServingEngine(trace=...)`` (bool / int capacity / ``Tracer``),
- the ``GEMMINI_TRACE`` environment variable (``1`` or a capacity),
- an explicit :func:`install` of a tracer as the process-global sink
  (used by ``serve --trace`` so tuner + fault events flow too).

Event model (Chrome trace event format, ``ts``/``dur`` in microseconds):

- ``ph="X"`` complete span (name, cat, ts, dur, args)
- ``ph="i"`` instant event
- ``ph="C"`` counter track (args = {series: value})
- ``ph="M"`` metadata (thread names for the fixed track layout below)

Track (tid) layout inside the single process (pid 0):
engine step phases on ``TID_ENGINE``, allocator on ``TID_ALLOC``, tuner
on ``TID_TUNER``, faults on ``TID_FAULT``, kernel profile spans on
``TID_PROFILE``, and each request on ``REQ_TID_BASE + rid`` so Perfetto
renders one lane per request lifecycle.
"""

from __future__ import annotations

import collections
import contextlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, Iterable, List, Optional

ENV_VAR = "GEMMINI_TRACE"

PID = 0
TID_ENGINE = 0
TID_ALLOC = 1
TID_TUNER = 2
TID_FAULT = 3
TID_PROFILE = 4
REQ_TID_BASE = 1000

_THREAD_NAMES = {
    TID_ENGINE: "engine",
    TID_ALLOC: "allocator",
    TID_TUNER: "tuner",
    TID_FAULT: "faults",
    TID_PROFILE: "kernels",
}

DEFAULT_CAPACITY = 65536


def req_tid(rid: int) -> int:
    """Perfetto track id for request ``rid``."""
    return REQ_TID_BASE + int(rid)


@dataclass
class Tracer:
    """Bounded in-memory event ring.

    ``clock`` must be monotonic; timestamps are stored in microseconds
    relative to the tracer's construction so traces start near t=0.
    """

    capacity: int = DEFAULT_CAPACITY
    clock: Callable[[], float] = time.monotonic
    events: Deque[Dict[str, Any]] = field(init=False, repr=False)
    dropped: int = field(init=False, default=0)

    def __post_init__(self) -> None:
        if self.capacity <= 0:
            raise ValueError(f"tracer capacity must be positive, got {self.capacity}")
        self.events = collections.deque(maxlen=self.capacity)
        self._epoch = self.clock()

    # ---------------------------------------------------------------- core

    def now_us(self, t: Optional[float] = None) -> float:
        """Convert a clock reading (default: now) to trace microseconds."""
        t = self.clock() if t is None else t
        return (t - self._epoch) * 1e6

    def _push(self, ev: Dict[str, Any]) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(ev)

    def emit(
        self,
        name: str,
        *,
        ph: str,
        cat: str = "engine",
        tid: int = TID_ENGINE,
        ts: Optional[float] = None,
        dur: Optional[float] = None,
        args: Optional[Dict[str, Any]] = None,
    ) -> None:
        ev: Dict[str, Any] = {
            "name": name,
            "cat": cat,
            "ph": ph,
            "ts": self.now_us() if ts is None else ts,
            "pid": PID,
            "tid": tid,
        }
        if dur is not None:
            ev["dur"] = dur
        if args:
            ev["args"] = args
        self._push(ev)

    # ------------------------------------------------------------ emitters

    def instant(self, name: str, *, cat: str = "engine", tid: int = TID_ENGINE,
                **args: Any) -> None:
        self.emit(name, ph="i", cat=cat, tid=tid, args=args or None)

    def complete(self, name: str, t0: float, t1: Optional[float] = None, *,
                 cat: str = "engine", tid: int = TID_ENGINE, **args: Any) -> None:
        """Record a finished span; ``t0``/``t1`` are raw clock readings."""
        t1 = self.clock() if t1 is None else t1
        self.emit(name, ph="X", cat=cat, tid=tid, ts=self.now_us(t0),
                  dur=max(0.0, (t1 - t0) * 1e6), args=args or None)

    def counter(self, name: str, *, tid: int = TID_ENGINE, **series: float) -> None:
        self.emit(name, ph="C", cat="metrics", tid=tid, args=dict(series))

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "engine", tid: int = TID_ENGINE,
             **args: Any):
        t0 = self.clock()
        try:
            yield
        finally:
            self.complete(name, t0, cat=cat, tid=tid, **args)

    def label_thread(self, tid: int, name: str) -> None:
        self.emit("thread_name", ph="M", cat="__metadata", tid=tid,
                  ts=0.0, args={"name": name})

    # -------------------------------------------------------------- export

    def tail(self, n: int) -> List[Dict[str, Any]]:
        """Last ``n`` events, oldest first (for diagnostics dumps)."""
        return list(self.events)[-n:]

    def chrome(self) -> Dict[str, Any]:
        """Chrome trace event format payload (``traceEvents`` object form)."""
        meta = [
            {"name": "thread_name", "cat": "__metadata", "ph": "M", "ts": 0.0,
             "pid": PID, "tid": tid, "args": {"name": label}}
            for tid, label in sorted(_THREAD_NAMES.items())
        ]
        return {
            "traceEvents": meta + list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped,
                          "capacity": self.capacity},
        }

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome(), f)
        return path

    def export_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")
        return path


# ------------------------------------------------------------- validation

_VALID_PH = {"X", "B", "E", "i", "I", "C", "M", "b", "e", "n", "s", "t", "f"}


def validate_chrome(payload: Any) -> List[str]:
    """Schema-check a Chrome trace payload; return a list of errors.

    Accepts the object form (``{"traceEvents": [...]}``) or the bare
    array form.  Used by ``python -m repro.obs --check`` as a CI gate.
    """
    errors: List[str] = []
    if isinstance(payload, dict):
        events = payload.get("traceEvents")
        if not isinstance(events, list):
            return ["payload object lacks a 'traceEvents' list"]
    elif isinstance(payload, list):
        events = payload
    else:
        return [f"payload must be an object or array, got {type(payload).__name__}"]

    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                errors.append(f"{where} ({ev.get('name', '?')}): missing '{key}'")
        ph = ev.get("ph")
        if ph is not None and ph not in _VALID_PH:
            errors.append(f"{where} ({ev.get('name', '?')}): bad phase {ph!r}")
        ts = ev.get("ts")
        if ts is not None and not isinstance(ts, (int, float)):
            errors.append(f"{where} ({ev.get('name', '?')}): non-numeric ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(
                    f"{where} ({ev.get('name', '?')}): complete span needs dur >= 0")
        if ph == "C" and not isinstance(ev.get("args"), dict):
            errors.append(f"{where} ({ev.get('name', '?')}): counter needs args dict")
        if len(errors) > 50:
            errors.append("... (truncated)")
            break
    return errors


def load(path: str) -> List[Dict[str, Any]]:
    """Load events from a Chrome-trace JSON or a JSONL event log."""
    with open(path) as f:
        text = f.read()
    try:
        # Whole-file JSON first: a JSONL file (one object per line) fails
        # here with "Extra data" and falls through -- sniffing the first
        # character cannot tell the two apart.
        payload = json.loads(text)
    except json.JSONDecodeError:
        return [json.loads(line) for line in text.splitlines() if line.strip()]
    if isinstance(payload, dict):
        return list(payload.get("traceEvents", []))
    return list(payload)


# ------------------------------------------------------ global installation

_ACTIVE: Optional[Tracer] = None


def install(tracer: Tracer) -> Tracer:
    """Make ``tracer`` the process-global event sink (tuner/fault events)."""
    global _ACTIVE
    _ACTIVE = tracer
    return tracer


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[Tracer]:
    return _ACTIVE


def _from_env() -> Optional[Tracer]:
    spec = os.environ.get(ENV_VAR, "").strip().lower()
    if spec in ("", "0", "off", "false", "no"):
        return None
    if spec.isdigit() and int(spec) > 1:
        return Tracer(capacity=int(spec))
    return Tracer()


def as_tracer(obj: Any, *, clock: Optional[Callable[[], float]] = None
              ) -> Optional[Tracer]:
    """Normalize a user-facing ``trace=`` knob into a Tracer (or None).

    ``None`` defers to ``GEMMINI_TRACE``; ``False`` forces off; ``True``
    enables with the default capacity; an int sets the ring capacity;
    a ``Tracer`` is used as-is (its own clock wins).
    """
    if isinstance(obj, Tracer):
        return obj
    if obj is None:
        t = _from_env()
    elif obj is False:
        return None
    elif obj is True:
        t = Tracer()
    elif isinstance(obj, int):
        t = Tracer(capacity=obj)
    else:
        raise TypeError(f"trace= expects None/bool/int/Tracer, got {type(obj).__name__}")
    if t is not None and clock is not None:
        t = Tracer(capacity=t.capacity, clock=clock)
    return t


def iter_spans(events: Iterable[Dict[str, Any]], *, cat: Optional[str] = None,
               ph: Optional[str] = None) -> Iterable[Dict[str, Any]]:
    for ev in events:
        if cat is not None and ev.get("cat") != cat:
            continue
        if ph is not None and ev.get("ph") != ph:
            continue
        yield ev
