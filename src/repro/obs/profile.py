"""Opt-in kernel performance counters at the dispatch boundary.

When a :class:`Profiler` is installed (``GEMMINI_PROFILE=1`` env,
``serve --profile``, or an explicit :func:`install`),
``ExecutionContext.__getattr__`` wraps every op dispatch: the call is
timed with a blocking ``jax.block_until_ready`` sync and recorded into a
per-(op, shape-signature) bucket, joined with the op's
`KernelContract`-derived FLOPs/bytes (:mod:`repro.obs.kernel_costs`).
Dividing by `analysis/roofline`'s per-chip peaks gives achieved
compute/memory utilization per kernel instantiation — the software
analog of the paper's hardware counters.

Profiling applies only to EAGER dispatches (the same
``trace_state_clean`` rule the fault injector follows): a timer inside a
jit trace would measure tracing, not execution, and the blocking sync
would serialize the compiled pipeline.  Ops dispatched inside a jitted
engine step are invisible here — profile with an eager/interpret
context (the tests and ``bench_kernels`` do), or read whole-step timing
from the engine's trace spans instead.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.roofline import HBM_BW, PEAK_FLOPS_BF16, PEAK_OPS_INT8
from repro.obs import kernel_costs

ENV_VAR = "GEMMINI_PROFILE"


def _shape_sig(args: Tuple, kw: Dict[str, Any]) -> str:
    parts: List[str] = []
    for a in args:
        shape = getattr(a, "shape", None)
        if shape is not None:
            dtype = getattr(a, "dtype", "")
            parts.append(f"{tuple(shape)}{dtype}")
        elif a is None:
            parts.append("-")
        else:
            parts.append(repr(a))
    for k in sorted(kw):
        v = kw[k]
        if getattr(v, "shape", None) is not None:
            v = f"{tuple(v.shape)}{v.dtype}"
        parts.append(f"{k}={v}")
    return ",".join(parts)


@dataclasses.dataclass
class OpBucket:
    """Aggregated timings for one (op, shape-signature) instantiation."""

    op: str
    sig: str
    contract: Optional[str] = None
    flops: float = 0.0            # per call
    bytes: float = 0.0            # per call
    arith: str = "float"
    calls: int = 0
    total_s: float = 0.0
    min_s: float = float("inf")
    max_s: float = 0.0

    def record(self, dt_s: float) -> None:
        self.calls += 1
        self.total_s += dt_s
        self.min_s = min(self.min_s, dt_s)
        self.max_s = max(self.max_s, dt_s)

    @property
    def peak_flops(self) -> float:
        return PEAK_OPS_INT8 if self.arith == "int" else PEAK_FLOPS_BF16

    def utilization(self) -> Dict[str, Optional[float]]:
        """Achieved-vs-roofline fractions from the bucket's BEST call
        (min_s): warmup/compile noise inflates means, and the roofline
        question is what the kernel can sustain."""
        if not self.calls or self.min_s == float("inf"):
            return {"compute": None, "memory": None, "bound": None}
        if self.flops <= 0 and self.bytes <= 0:
            return {"compute": None, "memory": None, "bound": None}
        cu = (self.flops / self.min_s) / self.peak_flops
        mu = (self.bytes / self.min_s) / HBM_BW
        t_c = self.flops / self.peak_flops
        t_m = self.bytes / HBM_BW
        return {"compute": cu, "memory": mu,
                "bound": "compute" if t_c >= t_m else "memory"}

    def row(self) -> Dict[str, Any]:
        util = self.utilization()
        return {
            "op": self.op, "sig": self.sig, "contract": self.contract,
            "calls": self.calls, "total_s": self.total_s,
            "min_s": None if self.min_s == float("inf") else self.min_s,
            "max_s": self.max_s, "flops": self.flops, "bytes": self.bytes,
            "arith": self.arith, "compute_util": util["compute"],
            "memory_util": util["memory"], "bound": util["bound"],
        }


class Profiler:
    """Per-op timing + contract-cost aggregation.

    ``tracer``: optional :class:`repro.obs.trace.Tracer`; when set, each
    profiled call also lands as a ``cat="kernel"`` complete span on the
    profile track.
    """

    def __init__(self, *, clock=time.perf_counter, tracer=None) -> None:
        self.clock = clock
        self.tracer = tracer
        self.buckets: Dict[Tuple[str, str], OpBucket] = {}

    def bucket(self, op: str, args: Tuple, kw: Dict[str, Any], cfg
               ) -> OpBucket:
        sig = _shape_sig(args, kw)
        key = (op, sig)
        b = self.buckets.get(key)
        if b is None:
            b = self.buckets[key] = OpBucket(op=op, sig=sig)
            cost = kernel_costs.op_cost(op, args, kw, cfg)
            if cost is not None:
                b.contract = cost.contract
                b.flops = cost.flops
                b.bytes = cost.bytes
                b.arith = cost.arith
        return b

    def record(self, bucket: OpBucket, t0: float, t1: float) -> None:
        bucket.record(t1 - t0)
        if self.tracer is not None:
            from repro.obs import trace as otrace
            self.tracer.complete(
                bucket.op, t0, t1, cat="kernel", tid=otrace.TID_PROFILE,
                contract=bucket.contract, flops=bucket.flops,
                bytes=bucket.bytes, sig=bucket.sig)

    # -------------------------------------------------------------- report

    def table(self, *, by: str = "total_s") -> List[Dict[str, Any]]:
        rows = [b.row() for b in self.buckets.values()]
        rows.sort(key=lambda r: r.get(by) or 0.0, reverse=True)
        return rows

    def report(self, *, top: int = 20) -> str:
        rows = self.table()[:top]
        if not rows:
            return "profiler: no ops recorded"
        head = (f"{'op':<24} {'contract':<24} {'calls':>6} {'total_ms':>9} "
                f"{'best_ms':>8} {'gflops':>8} {'comp%':>6} {'mem%':>6} "
                f"{'bound':>8}")
        lines = [head, "-" * len(head)]
        for r in rows:
            cu = r["compute_util"]
            mu = r["memory_util"]
            lines.append(
                f"{r['op']:<24} {str(r['contract']):<24} {r['calls']:>6} "
                f"{r['total_s'] * 1e3:>9.3f} "
                f"{(r['min_s'] or 0.0) * 1e3:>8.3f} "
                f"{r['flops'] / 1e9:>8.2f} "
                f"{'--' if cu is None else format(cu * 100, '.2f'):>6} "
                f"{'--' if mu is None else format(mu * 100, '.2f'):>6} "
                f"{str(r['bound'] or '--'):>8}")
        return "\n".join(lines)

    def snapshot(self) -> List[Dict[str, Any]]:
        return self.table()


# ------------------------------------------------------ global installation

_ACTIVE: Optional[Profiler] = None


def install(profiler: Optional[Profiler] = None) -> Profiler:
    global _ACTIVE
    _ACTIVE = profiler or Profiler()
    return _ACTIVE


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[Profiler]:
    global _ACTIVE
    if _ACTIVE is None:
        spec = os.environ.get(ENV_VAR, "").strip().lower()
        if spec not in ("", "0", "off", "false", "no"):
            _ACTIVE = Profiler()
    return _ACTIVE
