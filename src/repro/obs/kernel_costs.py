"""KernelContract-derived FLOP/byte costs for profiled dispatches.

The profiler (:mod:`repro.obs.profile`) times ops at the
`ExecutionContext` dispatch boundary; this module supplies the other
half of a performance counter — how much *work* that call represents —
by building the op's real :class:`~repro.kernels.contracts.KernelContract`
(the same builders `repro.analysis.lint` checks) for the concrete call
shapes and deriving:

- **bytes**: per operand, full-array traffic for affine operands and
  block-bytes x grid-steps for ``data_dependent`` (block-table-gathered)
  operands — the traffic the launch actually DMAs.  Contracts are built
  with a degenerate one-block-per-axis schedule, so affine operands are
  touched exactly once and the number is a roofline *lower bound* on
  traffic (real tuned schedules revisit).
- **flops**: an analytic formula per kernel family (registered beside
  the shape mapping below), matching the dots the contract declares.

Joined with `analysis/roofline`'s per-chip peaks this yields
achieved-vs-roofline utilization per kernel instantiation — the software
analog of Gemmini's hardware performance counters.
"""

from __future__ import annotations

import dataclasses
import math
import types
from typing import Any, Callable, Dict, Optional, Tuple

from repro.kernels.contracts import CONTRACT_BUILDERS, KernelContract, dt


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Static work estimate for one dispatched op instantiation."""

    contract: str                 # kernel-family / contract name
    flops: float
    bytes: float
    arith: str                    # "float" | "int" — picks the peak
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)


def contract_bytes(c: KernelContract) -> float:
    """Total HBM traffic implied by one launch of contract ``c``."""
    grid_steps = 1
    for _, size in c.grid:
        grid_steps *= size
    total = 0.0
    for spec in c.inputs + c.outputs:
        itemsize = spec.dtype[1]
        if spec.data_dependent is None:
            total += math.prod(spec.shape) * itemsize
        else:
            # Gathered through prefetched scalars: one block per grid
            # step is DMA'd regardless of the full pool shape.
            total += math.prod(spec.block) * itemsize * grid_steps
    return total


def _one_block_plan(m: int, n: int, k: int):
    """Degenerate single-block GEMM schedule: each operand streamed once."""
    return types.SimpleNamespace(m=m, n=n, k=k, tile_m=m, tile_n=n, tile_k=k,
                                 grid=(1, 1, 1))


def _gemm_contract_name(cfg, kw) -> str:
    df = kw.get("dataflow") or getattr(cfg, "dataflow", None)
    return "gemm_ws" if "WS" in str(getattr(df, "value", df)) else "gemm_os"


# -- per-op (args, kw, cfg) -> (contract, flops) mappings --------------------

def _cost_gemm(args, kw, cfg) -> Tuple[KernelContract, float, str]:
    a, b = args[0], args[1]
    d = args[2] if len(args) > 2 else kw.get("d")
    m, k = a.shape
    n = b.shape[1]
    name = _gemm_contract_name(cfg, kw)
    c = CONTRACT_BUILDERS[name](cfg, _one_block_plan(m, n, k),
                                has_bias=d is not None)
    flops = 2.0 * m * n * k + (m * n if d is not None else 0.0)
    return c, flops, dt(cfg.input_dtype)[0]


def _cost_matmul(args, kw, cfg) -> Tuple[KernelContract, float, str]:
    a, b = args[0], args[1]
    m = math.prod(a.shape[:-1])
    k = a.shape[-1]
    n = b.shape[-1]
    name = _gemm_contract_name(cfg, kw)
    c = CONTRACT_BUILDERS[name](cfg, _one_block_plan(m, n, k), has_bias=False)
    return c, 2.0 * m * n * k, dt(cfg.input_dtype)[0]


def _cost_conv2d(args, kw, cfg) -> Tuple[KernelContract, float, str]:
    x, w = args[0], args[1]
    b = args[2] if len(args) > 2 else kw.get("b")
    n, h, wd, ci = x.shape
    kh, kw_, _, co = w.shape
    stride = kw.get("stride", 1)
    padding = kw.get("padding", 0)
    c = CONTRACT_BUILDERS["conv2d_implicit"](
        cfg, n=n, h=h, w=wd, ci=ci, co=co, kh=kh, kw=kw_, co_tile=co,
        stride=stride, padding=padding, has_bias=b is not None)
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (wd + 2 * padding - kw_) // stride + 1
    flops = 2.0 * n * oh * ow * ci * co * kh * kw_
    return c, flops, dt(cfg.input_dtype)[0]


def _cost_flash_attention(args, kw, cfg) -> Tuple[KernelContract, float, str]:
    q, k = args[0], args[1]
    b, tq, h, d = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    c = CONTRACT_BUILDERS["flash_attention"](
        cfg, b=b, h=h, kvh=kvh, tq=tq, tk=tk, d=d, block_q=max(tq, 8),
        block_k=max(tk, 8), dtype=str(q.dtype))
    # QK^T and PV: 2 matmuls of (tq, tk) x d each, per batch x head.
    return c, 4.0 * b * h * tq * tk * d, "float"


def _cost_paged_attention(args, kw, cfg) -> Tuple[KernelContract, float, str]:
    q, k_pool, _, block_tables = args[0], args[1], args[2], args[3]
    b, _, h, d = q.shape
    kvh, n_pages, page, _ = k_pool.shape
    mp = block_tables.shape[1]
    c = CONTRACT_BUILDERS["paged_decode_attention"](
        cfg, b=b, h=h, kvh=kvh, d=d, page=page, mp=mp, n_pages=n_pages,
        dtype=str(q.dtype))
    # Table-capacity bound: the grid walks every table slot (dead pages
    # are clamp-elided on device but still deterministic work here).
    return c, 4.0 * b * h * (mp * page) * d, "float"


def _cost_paged_prefill(args, kw, cfg) -> Tuple[KernelContract, float, str]:
    q, k_pool, _, block_table = args[0], args[1], args[2], args[3]
    _, tq, h, d = q.shape
    kvh, n_pages, page, _ = k_pool.shape
    mp = block_table.shape[0]
    kv_pages = kw.get("kv_pages")
    if kv_pages is not None:
        mp = min(mp, int(kv_pages))
    c = CONTRACT_BUILDERS["paged_prefill_attention"](
        cfg, h=h, kvh=kvh, tq=tq, d=d, page=page, mp=mp, n_pages=n_pages,
        block_q=max(tq, 8), dtype=str(q.dtype))
    return c, 4.0 * h * tq * (mp * page) * d, "float"


def _cost_ssd(args, kw, cfg) -> Tuple[KernelContract, float, str]:
    x, _, _, b, _ = args[0], args[1], args[2], args[3], args[4]
    bsz, t, h, p = x.shape
    ngroups, n = b.shape[2], b.shape[3]
    q = min(kw.get("chunk", 256), t)
    nc = -(-t // q)
    c = CONTRACT_BUILDERS["ssd"](
        cfg, bsz=bsz, h=h, nc=nc, q=q, p=p, n=n, ngroups=ngroups,
        dtype=str(x.dtype),
        return_final_state=bool(kw.get("return_final_state")))
    # Per (batch, head, chunk): C@B^T (2q^2 n) + L@X (2q^2 p) + the two
    # state GEMMs B^T@X and C@state (2qnp each).
    per_chunk = 2.0 * q * q * n + 2.0 * q * q * p + 4.0 * q * n * p
    return c, bsz * h * nc * per_chunk, "float"


_COST_FNS: Dict[str, Callable] = {
    "gemm": _cost_gemm,
    "matmul": _cost_matmul,
    "conv2d": _cost_conv2d,
    "flash_attention": _cost_flash_attention,
    "paged_attention": _cost_paged_attention,
    "paged_prefill_attention": _cost_paged_prefill,
    "ssd": _cost_ssd,
}


def op_cost(op: str, args: Tuple, kw: Dict[str, Any], cfg) -> Optional[OpCost]:
    """Build the op's contract for these call shapes and derive its cost.

    Returns None for ops with no registered cost mapping or when the
    shapes cannot be interpreted (the profiler then reports timing only).
    """
    fn = _COST_FNS.get(op)
    if fn is None:
        return None
    try:
        c, flops, arith = fn(args, kw, cfg)
    except Exception:
        return None
    return OpCost(contract=c.name, flops=flops, bytes=contract_bytes(c),
                  arith=arith,
                  detail={"grid": dict(c.grid),
                          "operands": len(c.inputs) + len(c.outputs)})


def costed_ops() -> Tuple[str, ...]:
    return tuple(sorted(_COST_FNS))
