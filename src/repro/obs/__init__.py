"""Observability substrate: span tracing, metrics, kernel perf counters.

Three cooperating pieces (docs/observability.md):

- :mod:`repro.obs.trace` — ring-buffered span tracer (request lifecycle,
  engine step phases, allocator/tuner/fault events) with Chrome-trace /
  JSONL export.  Off by default; ``GEMMINI_TRACE`` /
  ``ServingEngine(trace=)`` / ``serve --trace`` enable it.
- :mod:`repro.obs.metrics` — labelled counters/gauges/histograms; the
  one schema behind ``engine.summarize()`` and BENCH_serving rows.
- :mod:`repro.obs.profile` + :mod:`repro.obs.kernel_costs` — opt-in
  per-op timing at the `ExecutionContext` boundary joined with
  `KernelContract` FLOPs/bytes into achieved-vs-roofline utilization
  (``GEMMINI_PROFILE`` / ``serve --profile``).

``python -m repro.obs <trace.json>`` summarizes an exported trace.
"""

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.profile import Profiler
from repro.obs.trace import Tracer, req_tid, validate_chrome

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Profiler", "Tracer", "req_tid", "validate_chrome",
]
