"""Conv2D as an implicit-im2col GEMM Pallas kernel.

The shipped Gemmini design does im2col on the *host* CPU, and the paper's
own DSE shows that host-side work caps whole-network speedup (MobileNet:
330x on layer 1, 6x end-to-end). Section 7 proposes mapping convolutions to
GEMMs *transparently in hardware*; this kernel is that future-work item,
adapted to the TPU memory hierarchy: the im2col patch matrix is never
materialized in HBM -- patch rows are sliced out of the (VMEM-resident)
input block inside the kernel and fed straight to the MXU, with the
Gemmini accumulate/round-shift/saturate/activation epilogue fused.

Schedule: grid = (N, CO_tiles, KH*KW) with the filter-tap axis innermost
("arbitrary"): the (OH*OW, co_t) accumulator tile is output-stationary in
VMEM across the tap stream (each tap contributes one (OH*OW, CI) x
(CI, co_t) GEMM), and the epilogue runs on the last tap -- the OS dataflow
of the GEMM engine, re-applied at the convolution level. ``co_tile`` is the
kernel's tunable schedule parameter (``tune.schedules.ConvSchedule``);
``ctx.conv2d(fused=True)`` resolves it through the flag-gated tuner.

Fusion audit note (ROADMAP): the epilogue is fused (the accumulator never
round-trips HBM -- rescale/saturate/activation run in-kernel on the last
tap), and the bias load is hoisted out of the tap stream: the bias operand
only exists when a bias does, and its block index is tap-invariant.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import repro.kernels as kernels_pkg

from repro.core.config import Activation, GemminiConfig
from repro.kernels import epilogue as epi
from repro.kernels.contracts import kernel_contract


def _conv_kernel(*refs,
                 kh: int, kw: int, oh: int, ow: int, stride: int,
                 acc_dtype, out_dtype, shift: int, activation: Activation,
                 has_bias: bool):
    # The bias operand exists only when a bias does: no zeros block is
    # streamed through the tap stream for bias-free convs, and when present
    # its BlockSpec index (0, cc) is tap-invariant, so the load is hoisted
    # out of the tap stream (Mosaic's block revisiting elides the re-copy;
    # the ref is only read on tap 0).
    if has_bias:
        x_ref, w_ref, b_ref, o_ref, acc_ref = refs
    else:
        x_ref, w_ref, o_ref, acc_ref = refs
    tap = pl.program_id(2)
    i = tap // kw
    j = tap % kw

    @pl.when(tap == 0)
    def _init():
        if has_bias:
            acc_ref[...] = jnp.broadcast_to(
                b_ref[...].astype(acc_dtype), acc_ref.shape)
        else:
            acc_ref[...] = jnp.zeros_like(acc_ref)

    # slice the (i, j) tap's patch rows from the padded input block:
    # rows r of the output sample input row i + r*stride.
    x = x_ref[0]                                    # (HP, WP, CI)
    hp, wp, ci = x.shape
    xi = jax.lax.dynamic_slice(
        x, (i, j, 0), (hp - kh + 1, wp - kw + 1, ci))
    if stride > 1:
        xi = jax.lax.slice(xi, (0, 0, 0), xi.shape, (stride, stride, 1))
    patch = xi.reshape(oh * ow, ci)
    w = w_ref[0]                                    # (CI, co_t)
    acc_ref[...] += jax.lax.dot_general(
        patch, w, (((1,), (0,)), ((), ())), preferred_element_type=acc_dtype)

    @pl.when(tap == kh * kw - 1)
    def _flush():
        o_ref[0] = epi.apply(acc_ref[...], shift=shift, activation=activation,
                             out_dtype=out_dtype).reshape(oh, ow, -1)


@kernel_contract("conv2d_implicit")
def conv2d_implicit(x: jnp.ndarray, w: jnp.ndarray,
                    b: Optional[jnp.ndarray] = None, *, cfg: GemminiConfig,
                    stride: int = 1, padding: int = 0, shift: int = 0,
                    activation: Activation = Activation.NONE,
                    co_tile: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """x: (N, H, W, CI) , w: (KH, KW, CI, CO) -> (N, OH, OW, CO).

    The input image block lives in VMEM for the whole tap stream; the output
    accumulator is resident at ``cfg.acc_dtype`` width (the Gemmini
    accumulator SRAM); rescale/saturate/activation are fused on the last tap.
    """
    n, h, wd, ci = x.shape
    kh, kw, ci2, co = w.shape
    assert ci == ci2, (ci, ci2)
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (wd + 2 * padding - kw) // stride + 1
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding),
                        (0, 0)))
    hp, wp = x.shape[1], x.shape[2]
    # trim any excess rows/cols beyond what the taps need (exact cover)
    need_h = (oh - 1) * stride + kh
    need_w = (ow - 1) * stride + kw
    x = x[:, :need_h, :need_w]
    hp, wp = need_h, need_w

    co_tile = min(co_tile, co)
    nco = -(-co // co_tile)
    pad_co = nco * co_tile - co
    wm = w.reshape(kh * kw, ci, co)
    if pad_co:
        wm = jnp.pad(wm, ((0, 0), (0, 0), (0, pad_co)))
    has_bias = b is not None

    kernel = functools.partial(
        _conv_kernel, kh=kh, kw=kw, oh=oh, ow=ow, stride=stride,
        acc_dtype=cfg.acc_jnp, out_dtype=cfg.output_jnp, shift=shift,
        activation=activation, has_bias=has_bias)

    in_specs = [
        pl.BlockSpec((1, hp, wp, ci), lambda nn, cc, tt: (nn, 0, 0, 0)),
        pl.BlockSpec((1, ci, co_tile), lambda nn, cc, tt: (tt, 0, cc)),
    ]
    operands = [x, wm]
    if has_bias:
        # Tap-invariant index: the bias tile for output-channel block cc is
        # fetched once per (n, cc), not once per filter tap.
        in_specs.append(pl.BlockSpec((1, co_tile),
                                     lambda nn, cc, tt: (0, cc)))
        operands.append(jnp.pad(b.astype(cfg.acc_jnp), (0, pad_co))[None, :])

    out = pl.pallas_call(
        kernel,
        grid=(n, nco, kh * kw),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, oh, ow, co_tile),
                               lambda nn, cc, tt: (nn, 0, 0, cc)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, nco * co_tile),
                                       cfg.output_jnp),
        scratch_shapes=[pltpu.VMEM((oh * ow, co_tile), cfg.acc_jnp)],
        compiler_params=kernels_pkg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[..., :co]
