"""Shared accumulator->output epilogue used inside Pallas kernels.

Implements the Gemmini peripheral circuitry (paper section 2.1): rounding
bitshift, saturation to the output bitwidth, and the activation units
(ReLU / ReLU6; GELU/SiLU added for the LM model zoo). Written against plain
jnp ops on values (not refs) so the identical code runs inside a Pallas
kernel body, in the XLA fallback path, and in the ref oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import Activation


def _rounding_shift(x, shift: int):
    # Static-shift variant of core.quantize.rounding_shift (kernel-friendly:
    # no jnp.where over traced shift).
    if shift <= 0:
        return x
    half = 1 << (shift - 1)
    frac = jnp.bitwise_and(x, (1 << shift) - 1)
    shifted = jax.lax.shift_right_arithmetic(x, shift)
    bump = (frac > half) | ((frac == half) & (jnp.bitwise_and(shifted, 1) == 1))
    return shifted + bump.astype(x.dtype)


def activate(x, activation: Activation):
    if activation is Activation.NONE:
        return x
    if activation is Activation.RELU:
        return jnp.maximum(x, 0)
    if activation is Activation.RELU6:
        six = jnp.asarray(6, x.dtype) if jnp.issubdtype(x.dtype, jnp.integer) \
            else jnp.asarray(6.0, x.dtype)
        return jnp.clip(x, 0, six)
    if activation is Activation.GELU:
        return jax.nn.gelu(x)
    if activation is Activation.SILU:
        return x * jax.nn.sigmoid(x)
    raise ValueError(activation)


def apply(acc, *, shift: int, activation: Activation, out_dtype):
    """acc (int32 or fp32) -> activation(round_shift(acc)) saturated to out."""
    if jnp.issubdtype(acc.dtype, jnp.integer):
        y = _rounding_shift(acc.astype(jnp.int32), shift)
        y = activate(y, activation)
        if jnp.issubdtype(jnp.dtype(out_dtype), jnp.integer) and \
                jnp.dtype(out_dtype) != jnp.int32:
            info = jnp.iinfo(out_dtype)
            y = jnp.clip(y, info.min, info.max)
        return y.astype(out_dtype)
    y = activate(acc, activation)
    if shift:
        y = y / (2.0 ** shift)
    return y.astype(out_dtype)
