"""Flash attention as a Pallas TPU kernel (the LM-serving hot spot).

Supports everything the assigned architectures need: grouped-query attention
(any H/KVH ratio incl. MQA), causal masking, sliding-window "local" layers,
gemma-2 logit soft-capping, and non-square Tq != Tk (cache-backed prefill).

Schedule (TPU-native, re-derived for HBM->VMEM->MXU per DESIGN.md):
  grid = (B, H, nq, nk) with the KV axis innermost ("arbitrary" = sequential,
  enabling the carried online-softmax state). The q tile is resident in VMEM
  across the KV stream -- this is exactly the Gemmini *output-stationary*
  dataflow applied to attention: the output accumulator (acc, m, l) stays in
  the wide-precision scratch while K/V tiles stream past, and the epilogue
  (1/l normalization) runs on the last KV step, like the OS GEMM's
  rounding-shift epilogue on the last K step.

Block-skipping: fully-masked KV blocks (beyond the causal frontier, outside
the sliding window, or entirely in the pad_k zero-padding past the true
sequence) are skipped via ``pl.when``, so local-attention layers do
O(T*window) work, not O(T^2) -- the kernel-level reason gemma3's 5:1
local:global pattern makes 128k context affordable. ``block_live`` is the
single skip predicate shared by both kernels and by the tuner's analytic
cost model (``tune.schedules.attn_cycles``).

Fusion audit note (ROADMAP): the epilogue is already fused -- the
1/l finalize reads the f32 (acc, m, l) scratch and writes the output tile
in-kernel on the last KV step; the accumulator never round-trips HBM.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import repro.kernels as kernels_pkg
from repro.kernels.contracts import kernel_contract

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def block_live(k0, q0, *, block_q: int, block_k: int, tk: int,
               causal: bool, window: Optional[int]):
    """Whole-block liveness: some (qpos, kpos) pair in the (q0.., k0..)
    block is unmasked. Works on Python ints (tuner cost model) and traced
    values (kernel ``pl.when`` predicate) alike:

      padding: k0 < tk                       (block not fully in pad_k)
      causal:  k0 <= q0 + block_q - 1
      window:  k0 + block_k - 1 > q0 - window
    """
    live = k0 < tk
    if causal:
        live = live & (k0 <= q0 + block_q - 1)
    if window is not None:
        live = live & (k0 + block_k - 1 > q0 - window)
    return live


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 nk: int, block_q: int, block_k: int, tq: int, tk: int,
                 causal: bool, window: Optional[int],
                 softcap: Optional[float], scale: float):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global positions; queries are right-aligned against the keys
    q0 = i * block_q + (tk - tq)
    k0 = j * block_k

    # ---- whole-block skip test (static-shape friendly) -------------------
    # The k0 < tk padding term matters for non-causal/no-window layers:
    # without it every fully-padded KV block (the pad_k region) still runs
    # the MXU and relies on the -inf mask to zero its contribution.
    live = block_live(k0, q0, block_q=block_q, block_k=block_k, tk=tk,
                      causal=causal, window=window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < tk                                   # kv padding
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@kernel_contract("flash_attention")
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Tq, H, D); k/v: (B, Tk, KVH, D); returns (B, Tq, H, D).

    ``window``: sliding-window size for local layers (None = global).
    """
    b, tq, h, d = q.shape
    _, tk, kvh, _ = k.shape
    if h % kvh != 0:
        raise ValueError(f"H={h} not a multiple of KVH={kvh}")
    sc = scale if scale is not None else 1.0 / math.sqrt(d)

    block_q = min(block_q, max(tq, 8))
    block_k = min(block_k, max(tk, 8))
    nq = -(-tq // block_q)
    nk = -(-tk // block_k)
    pad_q = nq * block_q - tq
    pad_k = nk * block_k - tk

    # (B, H, T, D) layout: last-two-dim tiles are (block, D) -- MXU-aligned.
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    rep = h // kvh
    kernel = functools.partial(
        _attn_kernel, nk=nk, block_q=block_q, block_k=block_k,
        tq=tq, tk=tk, causal=causal, window=window, softcap=softcap,
        scale=sc)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, hh, i, j: (bb, hh, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, i, j: (bb, hh // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, i, j: (bb, hh // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, i, j: (bb, hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=kernels_pkg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :tq]
    return jnp.moveaxis(out, 1, 2)   # back to (B, Tq, H, D)


# ---------------------------------------------------------------------------
# single-token decode kernel: one query row vs a long KV cache
# ---------------------------------------------------------------------------
def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, nk: int, block_k: int, tk: int,
                   window: Optional[int], softcap: Optional[float],
                   scale: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = len_ref[0]                     # current position (keys <= pos live)
    k0 = j * block_k
    # Same skip predicate as the prefill kernel with q0 = pos and block_q=1;
    # the k0 < tk padding term skips blocks fully in the pad_k region (pos
    # is caller-supplied, so do not rely on pos < tk to imply it).
    live = (k0 < tk) & (k0 <= pos)
    if window is not None:
        live = live & (k0 + block_k - 1 > pos - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale           # (H, D) heads tile
        k = k_ref[0].astype(jnp.float32)                   # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (H, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= pos
        if window is not None:
            mask &= kpos > pos - window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@kernel_contract("decode_attention")
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     pos: jnp.ndarray, *, window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     scale: Optional[float] = None, block_k: int = 1024,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B, 1, H, D) vs cache k/v: (B, S, KVH, D); pos: scalar int32.

    The per-(batch) grid streams KV blocks while the H query rows stay
    resident; MQA/GQA is handled by flattening each query-group's heads into
    the rows of a single (H_per_group, D) matmul tile.
    """
    b, tq, h, d = q.shape
    assert tq == 1
    _, s, kvh, _ = k.shape
    rep = h // kvh
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    block_k = min(block_k, s)
    nk = -(-s // block_k)
    pad_k = nk * block_k - s

    # (B*KVH, rep, D) query rows; (B*KVH, S, D) caches
    qg = q[:, 0].reshape(b, kvh, rep, d).reshape(b * kvh, rep, d)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * kvh, s, d)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * kvh, s, d)
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pad_k), (0, 0)))
    lens = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b * kvh,))

    kernel = functools.partial(_decode_kernel, nk=nk, block_k=block_k, tk=s,
                               window=window, softcap=softcap, scale=sc)
    out = pl.pallas_call(
        kernel,
        grid=(b * kvh, nk),
        in_specs=[
            pl.BlockSpec((1, rep, d), lambda g, j: (g, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1,), lambda g, j: (g,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, rep, d), lambda g, j: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, rep, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
        compiler_params=kernels_pkg.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qg, kt, vt, lens)
    return out.reshape(b, kvh * rep, d)[:, None].reshape(b, 1, h, d)


# ---------------------------------------------------------------------------
# paged-attention decode kernel: gather KV pages via per-request block tables
# ---------------------------------------------------------------------------
def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, npages: int, page: int,
                         window: Optional[int], softcap: Optional[float],
                         scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)                 # logical page index within the seq

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ln = len_ref[b]                      # live tokens incl. the current one
    pos = ln - 1
    k0 = j * page
    # The shared whole-block predicate with block_q = 1 (one query row): the
    # padding term k0 < ln skips pages past the request's frontier entirely
    # -- dead and never-allocated table slots do no MXU work. An empty slot
    # (ln == 0) has no live page at all; _finalize's l == 0 guard then
    # yields a zero row the engine ignores.
    live = block_live(k0, pos, block_q=1, block_k=page, tk=ln,
                      causal=True, window=window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (rep, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (page, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= pos
        if window is not None:
            mask &= kpos > pos - window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == npages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@kernel_contract("paged_decode_attention")
def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_tables: jnp.ndarray,
                           lengths: jnp.ndarray, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None,
                           interpret: bool = False) -> jnp.ndarray:
    """Single-token decode against a *paged* KV cache.

    q: (B, 1, H, D); k_pool/v_pool: (KVH, NP, page, D) shared page pools;
    block_tables: (B, MP) int32 page ids mapping request positions
    [j*page, (j+1)*page) to pool page ``block_tables[b, j]``; lengths: (B,)
    int32 live tokens per request (the current token included -- write the
    KV of the new token first, then attend).

    The gather happens *inside* the kernel: each (b, kvh, j) grid step's
    K/V BlockSpec index map reads the block table (scalar-prefetched into
    SMEM) and DMAs exactly one pool page into VMEM -- the pool is never
    materialized per-request in HBM, which is the whole point of paging.
    Dead logical pages (j past the request frontier) clamp their index map
    to the last live page, so Mosaic's block-revisiting elides the re-copy,
    and the ``block_live`` predicate skips their compute.
    """
    b, tq, h, d = q.shape
    assert tq == 1
    kvh, npool, page, _ = k_pool.shape
    mp = block_tables.shape[1]
    rep = h // kvh
    sc = scale if scale is not None else 1.0 / math.sqrt(d)

    qg = q[:, 0].reshape(b, kvh, rep, d)
    bt = block_tables.reshape(-1).astype(jnp.int32)          # (B*MP,)
    lens = lengths.astype(jnp.int32)

    def _page_index(bb, hh, j, bt_ref, len_ref):
        # Clamp dead j to the request's last live page: same block index ->
        # Mosaic elides the DMA; an empty request (len 0) pins page bt[b,0].
        jmax = jnp.maximum(len_ref[bb] - 1, 0) // page
        return (hh, bt_ref[bb * mp + jnp.minimum(j, jmax)], 0, 0)

    kernel = functools.partial(_paged_decode_kernel, npages=mp, page=page,
                               window=window, softcap=softcap, scale=sc)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, mp),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d),
                         lambda bb, hh, j, bt_ref, len_ref: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, page, d), _page_index),
            pl.BlockSpec((1, 1, page, d), _page_index),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, rep, d),
            lambda bb, hh, j, bt_ref, len_ref: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, rep, d), q.dtype),
        compiler_params=kernels_pkg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, lens, qg, k_pool, v_pool)
    return out.reshape(b, 1, h, d)


# ---------------------------------------------------------------------------
# paged-attention chunked-prefill kernel: a fresh chunk of queries vs
# cache pages + itself, gathered via the request's block table
# ---------------------------------------------------------------------------
def _paged_prefill_kernel(bt_ref, start_ref, q_ref, k_ref, v_ref, o_ref,
                          m_ref, l_ref, acc_ref, *, mp: int, page: int,
                          block_q: int, tq: int, window: Optional[int],
                          softcap: Optional[float], scale: float):
    i = pl.program_id(1)                 # q block within the chunk
    j = pl.program_id(2)                 # logical page index within the seq

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = start_ref[0]                 # chunk's first logical position
    q0 = start + i * block_q
    k0 = j * page
    # Shared whole-block predicate: the frontier (start + tq, the chunk's
    # own KV was scattered before this call) plays the tk padding role, so
    # never-written logical pages do no MXU work; causal + window terms
    # skip exactly as in the prefill kernel.
    live = block_live(k0, q0, block_q=block_q, block_k=page, tk=start + tq,
                      causal=True, window=window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (page, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, page), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, page), 1)
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == mp - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@kernel_contract("paged_prefill_attention")
def paged_prefill_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                            v_pool: jnp.ndarray, block_table: jnp.ndarray,
                            start: jnp.ndarray, *,
                            window: Optional[int] = None,
                            softcap: Optional[float] = None,
                            scale: Optional[float] = None,
                            block_q: int = 512,
                            interpret: bool = False) -> jnp.ndarray:
    """Chunked-prefill attention against a *paged* KV cache.

    q: (1, T, H, D), one request's fresh chunk of queries at logical
    positions [start, start + T); k_pool/v_pool: (KVH, NP, page, D) shared
    page pools, with the chunk's own KV already scattered in (write first,
    then attend); block_table: (MP,) int32 page ids for THIS request;
    start: scalar int32 (traced -- one compile serves every chunk offset).

    The block-table gather of ``paged_decode_attention`` extended to a
    whole query tile: grid (H, nq, MP) with the page axis innermost, each
    step's K/V BlockSpec index map reading the scalar-prefetched table to
    DMA one pool page into VMEM. Dead logical pages (beyond what q block i
    can see under the causal frontier) clamp their index map to the last
    visible page so Mosaic's block-revisiting elides the copy, and the
    shared ``block_live`` predicate skips their compute -- a chunk at
    position s does O(s + T) page work, not O(MP).
    """
    b, tq, h, d = q.shape
    assert b == 1, "chunked prefill is per-request (one slot per call)"
    kvh, npool, page, _ = k_pool.shape
    mp = block_table.shape[0]
    rep = h // kvh
    sc = scale if scale is not None else 1.0 / math.sqrt(d)

    block_q = min(block_q, max(tq, 8))
    nq = -(-tq // block_q)
    pad_q = nq * block_q - tq
    qt = jnp.moveaxis(q[0], 1, 0)                          # (H, T, D)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, pad_q), (0, 0)))
    bt = block_table.reshape(-1).astype(jnp.int32)
    start_arr = jnp.asarray(start, jnp.int32).reshape((1,))

    def _page_index(hh, i, j, bt_ref, start_ref):
        # Clamp dead j to the last page visible from q block i (or the
        # chunk frontier, whichever is nearer): same block index -> Mosaic
        # elides the DMA, and the table is never read out of range.
        qmax = start_ref[0] + (i + 1) * block_q - 1
        jmax = jnp.minimum(qmax, start_ref[0] + tq - 1) // page
        return (hh // rep, bt_ref[jnp.minimum(j, jmax)], 0, 0)

    kernel = functools.partial(
        _paged_prefill_kernel, mp=mp, page=page, block_q=block_q, tq=tq,
        window=window, softcap=softcap, scale=sc)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(h, nq, mp),
        in_specs=[
            pl.BlockSpec((1, block_q, d),
                         lambda hh, i, j, bt_ref, start_ref: (hh, i, 0)),
            pl.BlockSpec((1, 1, page, d), _page_index),
            pl.BlockSpec((1, 1, page, d), _page_index),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, d),
            lambda hh, i, j, bt_ref, start_ref: (hh, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((h, nq * block_q, d), q.dtype),
        compiler_params=kernels_pkg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, start_arr, qt, k_pool, v_pool)
    return jnp.moveaxis(out[:, :tq], 0, 1)[None]           # (1, T, H, D)
