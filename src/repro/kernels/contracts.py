"""Declared contracts for every `pallas_call` in this package.

A :class:`KernelContract` is the statically checkable half of a Pallas
kernel: the grid, the dimension semantics, every operand's full shape /
block shape / index map / memory space, the VMEM scratch, how output
revisits reduce, and the dot-precision pairs the kernel body computes.
`repro.analysis.lint` abstractly interprets these over the tuner's
schedule lattice to prove coverage, write-race freedom, VMEM fit, and
precision soundness *before anything runs* (docs/analysis.md).

Contracts live next to the kernels (this package) so the declaration
and the launch site evolve together; the lint layer only consumes them.
Each launcher is annotated ``@kernel_contract("<name>")`` and the
builder with the same name constructs the contract for one concrete
(problem, schedule) instantiation — the builder mirrors the launcher's
`pallas_call` literally: same grid order, same lambdas, same scratch.

Index maps are the *same* lambda bodies as the launch sites, evaluated
by the linter on symbolic coordinates (`analysis/lint/affine.py`).
Operands whose real index map reads a scalar-prefetched ref (the paged
kernels' block-table gathers) cannot be affine — they declare
``data_dependent`` with the invariant the kernel maintains instead, and
the checker verifies everything else (block shape, VMEM, race, the
declared scalar-prefetch count) while skipping coverage for them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.config import GemminiConfig

# -- dtype normalization ----------------------------------------------------

_NAME_ALIASES = {
    "bf16": "bfloat16", "fp16": "float16", "fp32": "float32",
    "fp64": "float64",
}


def dt(dtype) -> Tuple[str, int]:
    """Any dtype spelling -> ("float"|"int", itemsize)."""
    if isinstance(dtype, tuple):
        return dtype
    if isinstance(dtype, str):
        dtype = _NAME_ALIASES.get(dtype, dtype)
        if dtype == "bfloat16":
            return ("float", 2)
    d = np.dtype(dtype)
    kind = "int" if d.kind in "iu" else "float"
    return (kind, d.itemsize)


# -- contract dataclasses ---------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OperandSpec:
    """One input or output of a `pallas_call`.

    ``index_map`` takes the grid coordinates (same signature as the
    BlockSpec lambda, scalar-ref args dropped); ``data_dependent``
    (non-None) replaces it with a prose invariant when the real map
    gathers through prefetched scalars.  ``budget`` picks which VMEM
    budget the block charges when *resident* ("scratchpad" |
    "accumulator"), matching the tuner's per-kernel fit model.
    """

    name: str
    shape: Tuple[int, ...]
    block: Tuple[int, ...]
    index_map: Optional[Callable] = None
    dtype: Tuple[str, int] = ("float", 4)
    memory_space: str = "vmem"          # "vmem" | "smem"
    data_dependent: Optional[str] = None
    budget: str = "accumulator"


@dataclasses.dataclass(frozen=True)
class ScratchSpec:
    """One ``pltpu.VMEM`` scratch allocation."""

    name: str
    shape: Tuple[int, ...]
    dtype: Tuple[str, int] = ("float", 4)


@dataclasses.dataclass(frozen=True)
class Reduction:
    """How an output absorbs grid revisits along sequential axes.

    ``via="scratch"``: partials accumulate in the named VMEM scratch and
    flush to the output block on the final revisit — the only sound
    pattern for separated grid revisits.  ``via="alias"``: partials
    round-trip through an input/output alias in HBM — Pallas does NOT
    guarantee read-after-write through an alias across separated grid
    steps (the seed's silently-wrong WS GEMM), so the checker rejects
    it outright (GL203).
    """

    out: str
    axes: Tuple[str, ...]
    via: str = "scratch"                # "scratch" | "alias"
    scratch: Optional[str] = None
    alias_input: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class DotContract:
    """One matmul inside the kernel body: operand + accumulator dtypes."""

    lhs: Tuple[str, int]
    rhs: Tuple[str, int]
    acc: Tuple[str, int]


@dataclasses.dataclass(frozen=True)
class KernelContract:
    name: str
    grid: Tuple[Tuple[str, int], ...]          # (axis name, size), launch order
    semantics: Tuple[str, ...]                 # "parallel" | "arbitrary"
    inputs: Tuple[OperandSpec, ...]
    outputs: Tuple[OperandSpec, ...]
    scratch: Tuple[ScratchSpec, ...] = ()
    reductions: Tuple[Reduction, ...] = ()
    dots: Tuple[DotContract, ...] = ()
    scalar_prefetch: int = 0                   # PrefetchScalarGridSpec count
    io_aliases: Tuple[Tuple[int, int], ...] = ()   # input idx -> output idx

    def __post_init__(self):
        if len(self.semantics) != len(self.grid):
            raise ValueError(f"{self.name}: {len(self.semantics)} semantics "
                             f"for {len(self.grid)} grid axes")


# -- registry + launcher annotation ----------------------------------------

CONTRACT_BUILDERS: Dict[str, Callable[..., KernelContract]] = {}


def contract_builder(name: str):
    def deco(fn):
        CONTRACT_BUILDERS[name] = fn
        return fn
    return deco


def kernel_contract(name: str):
    """Annotate a `pallas_call` launcher with its contract name.

    Purely declarative (identity at runtime); the lint source pass
    requires every function containing a `pallas_call` to carry it and
    the name to resolve in :data:`CONTRACT_BUILDERS`.
    """
    def deco(fn):
        fn.__lint_contract__ = name
        return fn
    return deco


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


# -- GEMM (kernels/gemm.py) -------------------------------------------------

def _gemm_common(cfg: GemminiConfig, plan, has_bias: bool):
    in_dt, acc_dt, out_dt = (dt(cfg.input_dtype), dt(cfg.acc_dtype),
                             dt(cfg.output_dtype))
    m, n, k = plan.m, plan.n, plan.k
    tm, tn, tk = plan.tile_m, plan.tile_n, plan.tile_k
    return in_dt, acc_dt, out_dt, m, n, k, tm, tn, tk


@contract_builder("gemm_os")
def gemm_os_contract(cfg: GemminiConfig, plan, *,
                     has_bias: bool = False) -> KernelContract:
    in_dt, acc_dt, out_dt, m, n, k, tm, tn, tk = \
        _gemm_common(cfg, plan, has_bias)
    gm, gn, gk = plan.grid
    semantics = (("arbitrary",) * 3 if cfg.pipeline_depth == 1
                 else ("parallel", "parallel", "arbitrary"))
    d_spec = OperandSpec(
        "d", (m if has_bias else 1, n), (tm if has_bias else 1, tn),
        (lambda i, j, kk: (i, j)) if has_bias
        else (lambda i, j, kk: (0, j)),
        acc_dt, budget="scratchpad")
    return KernelContract(
        name="gemm_os",
        grid=(("i", gm), ("j", gn), ("kk", gk)),
        semantics=semantics,
        inputs=(
            OperandSpec("a", (m, k), (tm, tk),
                        lambda i, j, kk: (i, kk), in_dt,
                        budget="scratchpad"),
            OperandSpec("b", (k, n), (tk, tn),
                        lambda i, j, kk: (kk, j), in_dt,
                        budget="scratchpad"),
            d_spec,
        ),
        outputs=(OperandSpec("c", (m, n), (tm, tn),
                             lambda i, j, kk: (i, j), out_dt),),
        scratch=(ScratchSpec("acc", (tm, tn), acc_dt),),
        reductions=(Reduction("c", ("kk",), via="scratch", scratch="acc"),),
        dots=(DotContract(in_dt, in_dt, acc_dt),),
    )


@contract_builder("gemm_ws")
def gemm_ws_contract(cfg: GemminiConfig, plan, *,
                     has_bias: bool = False) -> KernelContract:
    in_dt, acc_dt, out_dt, m, n, k, tm, tn, tk = \
        _gemm_common(cfg, plan, has_bias)
    gm, gn, gk = plan.grid
    d_spec = OperandSpec(
        "d", (m if has_bias else 1, n), (tm if has_bias else 1, tn),
        (lambda j, i, kk: (i, j)) if has_bias
        else (lambda j, i, kk: (0, j)),
        acc_dt, budget="scratchpad")
    return KernelContract(
        name="gemm_ws",
        grid=(("j", gn), ("i", gm), ("kk", gk)),   # weight-major
        semantics=("parallel", "parallel", "arbitrary"),
        inputs=(
            OperandSpec("b", (k, n), (tk, tn),
                        lambda j, i, kk: (kk, j), in_dt,
                        budget="scratchpad"),
            OperandSpec("a", (m, k), (tm, tk),
                        lambda j, i, kk: (i, kk), in_dt,
                        budget="scratchpad"),
            d_spec,
        ),
        outputs=(OperandSpec("c", (m, n), (tm, tn),
                             lambda j, i, kk: (i, j), out_dt),),
        scratch=(ScratchSpec("acc", (tm, tn), acc_dt),),
        reductions=(Reduction("c", ("kk",), via="scratch", scratch="acc"),),
        dots=(DotContract(in_dt, in_dt, acc_dt),),
    )


@contract_builder("accumulator_epilogue")
def accumulator_epilogue_contract(cfg: GemminiConfig, plan, *,
                                  m: int, n: int) -> KernelContract:
    acc_dt, out_dt = dt(cfg.acc_dtype), dt(cfg.output_dtype)
    tm, tn = plan.tile_m, plan.tile_n
    return KernelContract(
        name="accumulator_epilogue",
        grid=(("i", m // tm), ("j", n // tn)),
        semantics=("parallel", "parallel"),
        inputs=(OperandSpec("acc", (m, n), (tm, tn),
                            lambda i, j: (i, j), acc_dt,
                            budget="scratchpad"),),
        outputs=(OperandSpec("c", (m, n), (tm, tn),
                             lambda i, j: (i, j), out_dt),),
    )


# -- attention (kernels/attention.py) ---------------------------------------

def _attn_dt(dtype) -> Tuple[str, int]:
    return dt(dtype)


@contract_builder("flash_attention")
def flash_attention_contract(cfg: GemminiConfig, *, b: int, h: int, kvh: int,
                             tq: int, tk: int, d: int, block_q: int,
                             block_k: int, dtype="bf16") -> KernelContract:
    io = _attn_dt(dtype)
    f32 = ("float", 4)
    block_q = min(block_q, max(tq, 8))
    block_k = min(block_k, max(tk, 8))
    nq, nk = _cdiv(tq, block_q), _cdiv(tk, block_k)
    rep = h // kvh
    kv_shape = (b, kvh, nk * block_k, d)
    kv_map = lambda bb, hh, i, j: (bb, hh // rep, j, 0)   # noqa: E731
    return KernelContract(
        name="flash_attention",
        grid=(("bb", b), ("hh", h), ("i", nq), ("j", nk)),
        semantics=("parallel", "parallel", "parallel", "arbitrary"),
        inputs=(
            OperandSpec("q", (b, h, nq * block_q, d), (1, 1, block_q, d),
                        lambda bb, hh, i, j: (bb, hh, i, 0), io),
            OperandSpec("k", kv_shape, (1, 1, block_k, d), kv_map, io,
                        budget="scratchpad"),
            OperandSpec("v", kv_shape, (1, 1, block_k, d), kv_map, io,
                        budget="scratchpad"),
        ),
        outputs=(OperandSpec("o", (b, h, nq * block_q, d),
                             (1, 1, block_q, d),
                             lambda bb, hh, i, j: (bb, hh, i, 0), io),),
        scratch=(ScratchSpec("m", (block_q,), f32),
                 ScratchSpec("l", (block_q,), f32),
                 ScratchSpec("acc", (block_q, d), f32)),
        reductions=(Reduction("o", ("j",), via="scratch", scratch="acc"),),
        dots=(DotContract(io, io, f32),),
    )


@contract_builder("decode_attention")
def decode_attention_contract(cfg: GemminiConfig, *, b: int, h: int,
                              kvh: int, s: int, d: int, block_k: int,
                              dtype="bf16") -> KernelContract:
    io = _attn_dt(dtype)
    f32 = ("float", 4)
    rep = h // kvh
    block_k = min(block_k, s)
    nk = _cdiv(s, block_k)
    kv_shape = (b * kvh, nk * block_k, d)
    return KernelContract(
        name="decode_attention",
        grid=(("g", b * kvh), ("j", nk)),
        semantics=("parallel", "arbitrary"),
        inputs=(
            OperandSpec("q", (b * kvh, rep, d), (1, rep, d),
                        lambda g, j: (g, 0, 0), io),
            OperandSpec("k", kv_shape, (1, block_k, d),
                        lambda g, j: (g, j, 0), io, budget="scratchpad"),
            OperandSpec("v", kv_shape, (1, block_k, d),
                        lambda g, j: (g, j, 0), io, budget="scratchpad"),
            OperandSpec("lens", (b * kvh,), (1,),
                        lambda g, j: (g,), ("int", 4), memory_space="smem"),
        ),
        outputs=(OperandSpec("o", (b * kvh, rep, d), (1, rep, d),
                             lambda g, j: (g, 0, 0), io),),
        scratch=(ScratchSpec("m", (rep,), f32),
                 ScratchSpec("l", (rep,), f32),
                 ScratchSpec("acc", (rep, d), f32)),
        reductions=(Reduction("o", ("j",), via="scratch", scratch="acc"),),
        dots=(DotContract(io, io, f32),),
    )


_PAGED_GATHER = ("K/V page index gathers through the scalar-prefetched "
                 "block table; dead steps clamp to the last live page so "
                 "the read never leaves [0, n_pages)")


@contract_builder("paged_decode_attention")
def paged_decode_attention_contract(cfg: GemminiConfig, *, b: int, h: int,
                                    kvh: int, d: int, page: int, mp: int,
                                    n_pages: int, dtype="bf16"
                                    ) -> KernelContract:
    io = _attn_dt(dtype)
    f32 = ("float", 4)
    rep = h // kvh
    pool = (kvh, n_pages, page, d)
    return KernelContract(
        name="paged_decode_attention",
        grid=(("bb", b), ("hh", kvh), ("j", mp)),
        semantics=("parallel", "parallel", "arbitrary"),
        scalar_prefetch=2,
        inputs=(
            OperandSpec("q", (b, kvh, rep, d), (1, 1, rep, d),
                        lambda bb, hh, j: (bb, hh, 0, 0), io),
            OperandSpec("k_pool", pool, (1, 1, page, d), None, io,
                        data_dependent=_PAGED_GATHER, budget="scratchpad"),
            OperandSpec("v_pool", pool, (1, 1, page, d), None, io,
                        data_dependent=_PAGED_GATHER, budget="scratchpad"),
        ),
        outputs=(OperandSpec("o", (b, kvh, rep, d), (1, 1, rep, d),
                             lambda bb, hh, j: (bb, hh, 0, 0), io),),
        scratch=(ScratchSpec("m", (rep,), f32),
                 ScratchSpec("l", (rep,), f32),
                 ScratchSpec("acc", (rep, d), f32)),
        reductions=(Reduction("o", ("j",), via="scratch", scratch="acc"),),
        dots=(DotContract(io, io, f32),),
    )


@contract_builder("paged_prefill_attention")
def paged_prefill_attention_contract(cfg: GemminiConfig, *, h: int, kvh: int,
                                     tq: int, d: int, page: int, mp: int,
                                     n_pages: int, block_q: int,
                                     dtype="bf16") -> KernelContract:
    io = _attn_dt(dtype)
    f32 = ("float", 4)
    block_q = min(block_q, max(tq, 8))
    nq = _cdiv(tq, block_q)
    pool = (kvh, n_pages, page, d)
    return KernelContract(
        name="paged_prefill_attention",
        grid=(("hh", h), ("i", nq), ("j", mp)),
        semantics=("parallel", "parallel", "arbitrary"),
        scalar_prefetch=2,
        inputs=(
            OperandSpec("q", (h, nq * block_q, d), (1, block_q, d),
                        lambda hh, i, j: (hh, i, 0), io),
            OperandSpec("k_pool", pool, (1, 1, page, d), None, io,
                        data_dependent=_PAGED_GATHER, budget="scratchpad"),
            OperandSpec("v_pool", pool, (1, 1, page, d), None, io,
                        data_dependent=_PAGED_GATHER, budget="scratchpad"),
        ),
        outputs=(OperandSpec("o", (h, nq * block_q, d), (1, block_q, d),
                             lambda hh, i, j: (hh, i, 0), io),),
        scratch=(ScratchSpec("m", (block_q,), f32),
                 ScratchSpec("l", (block_q,), f32),
                 ScratchSpec("acc", (block_q, d), f32)),
        reductions=(Reduction("o", ("j",), via="scratch", scratch="acc"),),
        dots=(DotContract(io, io, f32),),
    )


# -- conv (kernels/conv.py) -------------------------------------------------

@contract_builder("conv2d_implicit")
def conv2d_implicit_contract(cfg: GemminiConfig, *, n: int, h: int, w: int,
                             ci: int, co: int, kh: int, kw: int,
                             co_tile: int, stride: int = 1, padding: int = 0,
                             has_bias: bool = False) -> KernelContract:
    in_dt, acc_dt, out_dt = (dt(cfg.input_dtype), dt(cfg.acc_dtype),
                             dt(cfg.output_dtype))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    hp, wp = (oh - 1) * stride + kh, (ow - 1) * stride + kw
    co_tile = min(co_tile, co)
    nco = _cdiv(co, co_tile)
    inputs = [
        # whole padded input block resident across the tap stream: charged
        # to the scratchpad budget exactly as schedules._conv_fits does.
        OperandSpec("x", (n, hp, wp, ci), (1, hp, wp, ci),
                    lambda nn, cc, tt: (nn, 0, 0, 0), in_dt,
                    budget="scratchpad"),
        OperandSpec("w", (kh * kw, ci, nco * co_tile), (1, ci, co_tile),
                    lambda nn, cc, tt: (tt, 0, cc), in_dt,
                    budget="scratchpad"),
    ]
    if has_bias:
        inputs.append(OperandSpec("bias", (1, nco * co_tile), (1, co_tile),
                                  lambda nn, cc, tt: (0, cc), acc_dt,
                                  budget="scratchpad"))
    return KernelContract(
        name="conv2d_implicit",
        grid=(("nn", n), ("cc", nco), ("tt", kh * kw)),
        semantics=("parallel", "parallel", "arbitrary"),
        inputs=tuple(inputs),
        outputs=(OperandSpec("y", (n, oh, ow, nco * co_tile),
                             (1, oh, ow, co_tile),
                             lambda nn, cc, tt: (nn, 0, 0, cc), out_dt),),
        scratch=(ScratchSpec("acc", (oh * ow, co_tile), acc_dt),),
        reductions=(Reduction("y", ("tt",), via="scratch", scratch="acc"),),
        dots=(DotContract(in_dt, in_dt, acc_dt),),
    )


# -- Mamba-2 SSD (kernels/mamba2.py) ----------------------------------------

@contract_builder("ssd")
def ssd_contract(cfg: GemminiConfig, *, bsz: int, h: int, nc: int, q: int,
                 p: int, n: int, ngroups: int, dtype="bf16",
                 return_final_state: bool = False) -> KernelContract:
    io = dt(dtype)
    f32 = ("float", 4)
    hpg = h // ngroups
    bc_map = lambda bb, hh, cc: (bb, hh // hpg, cc, 0, 0)   # noqa: E731
    outputs = [OperandSpec("y", (bsz, h, nc, q, p), (1, 1, 1, q, p),
                           lambda bb, hh, cc: (bb, hh, cc, 0, 0), io)]
    reductions = []
    if return_final_state:
        outputs.append(OperandSpec(
            "fs", (bsz, h, n, p), (1, 1, n, p),
            lambda bb, hh, cc: (bb, hh, 0, 0), f32))
        reductions.append(Reduction("fs", ("cc",), via="scratch",
                                    scratch="state"))
    return KernelContract(
        name="ssd",
        grid=(("bb", bsz), ("hh", h), ("cc", nc)),
        semantics=("parallel", "parallel", "arbitrary"),
        inputs=(
            OperandSpec("x", (bsz, h, nc, q, p), (1, 1, 1, q, p),
                        lambda bb, hh, cc: (bb, hh, cc, 0, 0), io,
                        budget="scratchpad"),
            OperandSpec("dt", (bsz, h, nc, q), (1, 1, 1, q),
                        lambda bb, hh, cc: (bb, hh, cc, 0), io,
                        budget="scratchpad"),
            OperandSpec("a", (h,), (1,), lambda bb, hh, cc: (hh,),
                        ("float", 4), memory_space="smem"),
            OperandSpec("d", (h,), (1,), lambda bb, hh, cc: (hh,),
                        ("float", 4), memory_space="smem"),
            OperandSpec("b", (bsz, ngroups, nc, q, n), (1, 1, 1, q, n),
                        bc_map, io, budget="scratchpad"),
            OperandSpec("c", (bsz, ngroups, nc, q, n), (1, 1, 1, q, n),
                        bc_map, io, budget="scratchpad"),
        ),
        outputs=tuple(outputs),
        scratch=(ScratchSpec("state", (n, p), f32),),
        reductions=tuple(reductions),
        dots=(DotContract(io, io, f32),),
    )
