"""Chunked SSD (Mamba-2 state-space duality) as a Pallas TPU kernel.

The SSD decomposition splits the linear recurrence into

  * intra-chunk terms  -- (Q x N)@(N x Q) score GEMMs and (Q x Q)@(Q x P)
    output GEMMs: dense matmuls that run on the MXU; *this* is the part the
    Gemmini technique covers (the paper's thesis: GEMM is the common kernel),
  * an inter-chunk recurrence -- a length-``n_chunks`` scan over the (N x P)
    state, attention-free and sequential; carried in a VMEM scratch across
    the sequential grid axis.

Schedule: grid = (B, H, nc) with the chunk axis innermost ("arbitrary").
The (N, P) running state is the resident accumulator (Gemmini
output-stationary residency applied to the SSM state); per chunk the kernel
performs only 2-D dots:

  scores  = C (Q,N) @ B^T (N,Q)               [MXU]
  y_diag  = (scores * L * dt) (Q,Q) @ X (Q,P) [MXU]
  y_off   = exp(seg) * (C (Q,N) @ state (N,P))[MXU]
  state   = decay * state + (w * B)^T (N,Q) @ X (Q,P)  [MXU]

B/C group mapping (G groups shared GQA-style across H heads) is resolved in
the BlockSpec index maps, so no repeat/gather materializes.

Fusion audit (ROADMAP, mirroring the PR 2 conv/attention audit): the whole
chunk-scan epilogue is fused in-kernel -- the (N, P) running state lives in
a VMEM scratch across the sequential chunk axis (never HBM), the per-chunk
output write already includes the carried-state term AND the ``d_skip``
residual add (previously a post-kernel XLA pass that round-tripped y
through HBM), and the final recurrent state is emitted as a second kernel
output on the last chunk step (previously recomputed by a separate XLA
pass over the full inputs). The only HBM traffic is the streamed inputs,
one y write per chunk, and one (N, P) state write per (batch, head).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import repro.kernels as kernels_pkg
from repro.kernels.contracts import kernel_contract


def _ssd_kernel(x_ref, dt_ref, a_ref, d_ref, b_ref, c_ref, y_ref, *rest,
                nc: int, chunk: int):
    # rest = (fs_ref, state_ref) when the caller wants the final state
    # emitted, else (state_ref,): the fs output buffer only exists when
    # requested (a pallas output cannot be dead-code-eliminated).
    fs_ref = rest[0] if len(rest) == 2 else None
    state_ref = rest[-1]
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0]                                   # scalar: -exp(a_log)
    d_skip = d_ref[0]                              # scalar skip weight
    dt = dt_ref[0, 0, 0].astype(jnp.float32)       # (Q,)
    x = x_ref[0, 0, 0].astype(jnp.float32)         # (Q, P)
    b = b_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)
    c = c_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)

    dta = dt * a                                   # (Q,)
    seg = jnp.cumsum(dta)                          # inclusive cumsum

    # intra-chunk decay L[i, j] = exp(seg_i - seg_j) for i >= j else 0
    li = seg[:, None] - seg[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ldec = jnp.where(ii >= jj, jnp.exp(li), 0.0)

    # scores = C_i . B_j  (Q, Q): a GEMM on the engine schedule
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * ldec * dt[None, :], x,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # contribution of the carried-in state to every step of this chunk
    y_off = jax.lax.dot_general(c, state_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + y_off * jnp.exp(seg)[:, None]
    # fused epilogue: the d_skip residual rides the same f32 accumulator
    # (zero when the model has no skip weight -- an exact no-op)
    y_ref[0, 0, 0] = (y + d_skip * x).astype(y_ref.dtype)

    # state update: state = exp(seg_Q) * state + sum_j w_j B_j x_j^T
    decay_to_end = jnp.exp(seg[-1] - seg)          # (Q,)
    wb = b * (decay_to_end * dt)[:, None]          # (Q, N)
    ds = jax.lax.dot_general(wb, x, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, P)
    state_ref[...] = state_ref[...] * jnp.exp(seg[-1]) + ds

    if fs_ref is not None:
        @pl.when(ci == nc - 1)
        def _emit_state():
            # prefill->decode handoff: the carried VMEM state is the final
            # recurrent state (dt is zero on padded tail rows, so padding
            # neither decays nor feeds it) -- no XLA recompute pass.
            fs_ref[0, 0] = state_ref[...]


@kernel_contract("ssd")
def ssd(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray, b: jnp.ndarray,
        c: jnp.ndarray, *, d_skip: Optional[jnp.ndarray] = None,
        chunk: int = 256, interpret: bool = False,
        return_final_state: bool = False):
    """x: (B,T,H,P), dt: (B,T,H) (softplus'd), a_log: (H,), b/c: (B,T,G,N).

    Returns y: (B,T,H,P) [and the final (B,H,N,P) state if requested].
    """
    bsz, t, h, p = x.shape
    _, _, g, n = b.shape
    hpg = h // g
    q = min(chunk, t)
    pad = (-t) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tt = t + pad
    nc = tt // q

    # (B, H, nc, Q, ...) layouts so the last two dims are MXU tiles
    xt = jnp.moveaxis(x, 2, 1).reshape(bsz, h, nc, q, p)
    dtt = jnp.moveaxis(dt, 2, 1).reshape(bsz, h, nc, q)
    bt = jnp.moveaxis(b, 2, 1).reshape(bsz, g, nc, q, n)
    ct = jnp.moveaxis(c, 2, 1).reshape(bsz, g, nc, q, n)
    a = -jnp.exp(a_log.astype(jnp.float32))        # (H,)
    # d_skip rides SMEM like a_log; zeros when absent (exact no-op in the
    # fused f32 epilogue).
    d = jnp.zeros((h,), jnp.float32) if d_skip is None \
        else d_skip.astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, nc=nc, chunk=q)
    out_specs = [pl.BlockSpec((1, 1, 1, q, p),
                              lambda bb, hh, cc: (bb, hh, cc, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((bsz, h, nc, q, p), x.dtype)]
    if return_final_state:
        out_specs.append(pl.BlockSpec((1, 1, n, p),
                                      lambda bb, hh, cc: (bb, hh, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((bsz, h, n, p), jnp.float32))
    out = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bb, hh, cc: (bb, hh, cc, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bb, hh, cc: (bb, hh, cc, 0)),
            pl.BlockSpec((1,), lambda bb, hh, cc: (hh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda bb, hh, cc: (hh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, q, n),
                         lambda bb, hh, cc: (bb, hh // hpg, cc, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n),
                         lambda bb, hh, cc: (bb, hh // hpg, cc, 0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=kernels_pkg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, a, d, bt, ct)

    y = out[0]
    y = jnp.moveaxis(y.reshape(bsz, h, tt, p), 1, 2)[:, :t]   # (B,T,H,P)
    if return_final_state:
        return y, out[1]
    return y
