"""Chunked SSD (Mamba-2 state-space duality) as a Pallas TPU kernel.

The SSD decomposition splits the linear recurrence into

  * intra-chunk terms  -- (Q x N)@(N x Q) score GEMMs and (Q x Q)@(Q x P)
    output GEMMs: dense matmuls that run on the MXU; *this* is the part the
    Gemmini technique covers (the paper's thesis: GEMM is the common kernel),
  * an inter-chunk recurrence -- a length-``n_chunks`` scan over the (N x P)
    state, attention-free and sequential; carried in a VMEM scratch across
    the sequential grid axis.

Schedule: grid = (B, H, nc) with the chunk axis innermost ("arbitrary").
The (N, P) running state is the resident accumulator (Gemmini
output-stationary residency applied to the SSM state); per chunk the kernel
performs only 2-D dots:

  scores  = C (Q,N) @ B^T (N,Q)               [MXU]
  y_diag  = (scores * L * dt) (Q,Q) @ X (Q,P) [MXU]
  y_off   = exp(seg) * (C (Q,N) @ state (N,P))[MXU]
  state   = decay * state + (w * B)^T (N,Q) @ X (Q,P)  [MXU]

B/C group mapping (G groups shared GQA-style across H heads) is resolved in
the BlockSpec index maps, so no repeat/gather materializes.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import repro.kernels as kernels_pkg


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                nc: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0]                                   # scalar: -exp(a_log)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)       # (Q,)
    x = x_ref[0, 0, 0].astype(jnp.float32)         # (Q, P)
    b = b_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)
    c = c_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)

    dta = dt * a                                   # (Q,)
    seg = jnp.cumsum(dta)                          # inclusive cumsum

    # intra-chunk decay L[i, j] = exp(seg_i - seg_j) for i >= j else 0
    li = seg[:, None] - seg[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ldec = jnp.where(ii >= jj, jnp.exp(li), 0.0)

    # scores = C_i . B_j  (Q, Q): a GEMM on the engine schedule
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * ldec * dt[None, :], x,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # contribution of the carried-in state to every step of this chunk
    y_off = jax.lax.dot_general(c, state_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + y_off * jnp.exp(seg)[:, None]
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update: state = exp(seg_Q) * state + sum_j w_j B_j x_j^T
    decay_to_end = jnp.exp(seg[-1] - seg)          # (Q,)
    wb = b * (decay_to_end * dt)[:, None]          # (Q, N)
    ds = jax.lax.dot_general(wb, x, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, P)
    state_ref[...] = state_ref[...] * jnp.exp(seg[-1]) + ds


def ssd(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray, b: jnp.ndarray,
        c: jnp.ndarray, *, d_skip: Optional[jnp.ndarray] = None,
        chunk: int = 256, interpret: bool = False,
        return_final_state: bool = False):
    """x: (B,T,H,P), dt: (B,T,H) (softplus'd), a_log: (H,), b/c: (B,T,G,N).

    Returns y: (B,T,H,P) [and the final (B,H,N,P) state if requested].
    """
    bsz, t, h, p = x.shape
    _, _, g, n = b.shape
    hpg = h // g
    q = min(chunk, t)
    pad = (-t) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tt = t + pad
    nc = tt // q

    # (B, H, nc, Q, ...) layouts so the last two dims are MXU tiles
    xt = jnp.moveaxis(x, 2, 1).reshape(bsz, h, nc, q, p)
    dtt = jnp.moveaxis(dt, 2, 1).reshape(bsz, h, nc, q)
    bt = jnp.moveaxis(b, 2, 1).reshape(bsz, g, nc, q, n)
    ct = jnp.moveaxis(c, 2, 1).reshape(bsz, g, nc, q, n)
    a = -jnp.exp(a_log.astype(jnp.float32))        # (H,)

    kernel = functools.partial(_ssd_kernel, nc=nc, chunk=q)
    y = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bb, hh, cc: (bb, hh, cc, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bb, hh, cc: (bb, hh, cc, 0)),
            pl.BlockSpec((1,), lambda bb, hh, cc: (hh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, q, n),
                         lambda bb, hh, cc: (bb, hh // hpg, cc, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n),
                         lambda bb, hh, cc: (bb, hh // hpg, cc, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, q, p),
                               lambda bb, hh, cc: (bb, hh, cc, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, nc, q, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=kernels_pkg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, a, bt, ct)

    y = jnp.moveaxis(y.reshape(bsz, h, tt, p), 1, 2)[:, :t]   # (B,T,H,P)
    if d_skip is not None:
        y = (y.astype(jnp.float32) +
             d_skip[None, None, :, None] * x[:, :t].astype(jnp.float32)
             ).astype(x.dtype)
    if return_final_state:
        from repro.models.ssm import _final_state
        _, fs = _final_state(x[:, :t], dt[:, :t], a_log, b[:, :t], c[:, :t])
        return y, fs
    return y
