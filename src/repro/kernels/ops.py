"""Kernel-layer op implementations behind :class:`ExecutionContext`.

The canonical dispatch API is ``repro.core.context.ExecutionContext``:
callers hold one context value (cfg + backend + tune policy + optional
mesh) and launch ``ctx.gemm(...)``, ``ctx.flash_attention(...)``, ....
The ``*_impl`` functions here are the kernel-layer entries that registry
dispatches to; they own shape legalization (zero-padding to the elaborated
array dimension, exactly as the paper's library zero-pads operands,
section 3.3), unpadding of results, and flag-gated schedule resolution.

Backends (one per context, no longer per call):

* ``"pallas"``    -- real TPU lowering (Mosaic). Target deployment path.
* ``"interpret"`` -- pl.pallas_call(interpret=True): executes the kernel body
                     in Python on CPU. Used by all kernel tests in this repo.
* ``"xla"``       -- pure-jnp path (the ref oracle numerics) that XLA can
                     SPMD-partition; used by the 512-device multi-pod dry-run,
                     where Mosaic kernels cannot lower on the CPU backend.

Under ``ctx.mesh`` the context wraps these impls in ``shard_map``, so the
shapes they see -- and the schedules ``_resolve_plan`` /
``_resolve_attn_blocks`` fingerprint -- are PER-DEVICE shapes (what
``tune.warm_model_plans(n_shards=...)`` warms), not the global logical
shapes GSPMD would otherwise trace them with.

The PR-5 ``ops.gemm(..., backend=...)`` deprecation shims were removed in
PR 7 after their one-release grace period; lint rule GL506 forbids binding
any legacy top-level alias in this module again.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import Activation, Dataflow, GemminiConfig
from repro.core.tiling import TilePlan, plan_gemm
from repro.kernels import gemm as gemm_kernel
from repro.kernels import ref as ref_ops

Backend = str  # "pallas" | "interpret" | "xla"


def _pad2(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr < 0 or pc < 0:
        raise ValueError(f"operand {x.shape} exceeds its plan dims "
                         f"({rows}, {cols}); plan solved for a smaller GEMM?")
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _resolve_plan(cfg: GemminiConfig, m: int, n: int, k: int, *,
                  dataflow: Optional[Dataflow], has_bias: bool) -> TilePlan:
    """Plan for this GEMM, honoring the effective tune mode (the process
    ``GEMMINI_TUNE`` flag, or the dispatching context's ``tune_mode``
    override scoped around this call).

    ``tune_mode=off`` keeps the greedy analytic solver on the hot path with
    no tuner import at all; otherwise the tuner consults (and under ``full``
    populates) the persistent plan cache. Inside a mesh'd context this runs
    under ``shard_map`` tracing, so ``m`` is the PER-DEVICE row count.
    """
    from repro.core import flags
    if flags.get("tune_mode") == "off":
        return plan_gemm(cfg, m, n, k, dataflow=dataflow, has_bias=has_bias)
    from repro.tune import tuner
    return tuner.resolve_plan(cfg, m, n, k, dataflow=dataflow,
                              has_bias=has_bias)


def gemm_impl(a: jnp.ndarray, b: jnp.ndarray, d: Optional[jnp.ndarray] = None,
              *, cfg: GemminiConfig, plan: Optional[TilePlan] = None,
              dataflow: Optional[Dataflow] = None, shift: int = 0,
              activation: Activation = Activation.NONE,
              backend: Backend = "xla") -> jnp.ndarray:
    """C = act(round_shift(A @ B + D)) on the elaborated instance.

    a: (M, K), b: (K, N), d: broadcastable (1|M, N) bias at acc dtype.
    Reached as ``ctx.gemm(a, b, d, ...)``; the context supplies ``cfg``
    and ``backend`` and (under a mesh) shards M.

    backend x tune-mode matrix (``plan`` given short-circuits both):

    ==========  ===========================================================
    backend     tune_mode=off            tune_mode=cached / full
    ==========  ===========================================================
    xla         ``ref.gemm_ref``: plain XLA dot with the fused
                accumulate/shift/saturate/activation epilogue. Plan-free
                (no tiling), so the tune flag never enters -- this is the
                SPMD-partitionable reference the dry-run lowers (GSPMD,
                not shard_map, partitions it; ``ctx.mesh`` is ignored).
    pallas /    greedy analytic            persistent plan cache keyed by
    interpret   ``plan_gemm`` solve,       the GEMM fingerprint; ``full``
                no tuner import on         measures and populates misses,
                the hot path               ``cached`` degrades misses to
                                           the analytic solve.
                Under ``ctx.mesh`` both columns resolve at the PER-DEVICE
                M (the shard_map-local shape).
    ==========  ===========================================================
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    if backend == "xla":
        return ref_ops.gemm_ref(a, b, d, acc_dtype=cfg.acc_jnp,
                                out_dtype=cfg.output_jnp, shift=shift,
                                activation=activation)
    plan = plan or _resolve_plan(cfg, m, n, k, dataflow=dataflow,
                                 has_bias=d is not None)
    ap = _pad2(a, plan.m, plan.k)
    bp = _pad2(b, plan.k, plan.n)
    dp = None
    if d is not None:
        dp = _pad2(jnp.broadcast_to(d, (m, n)).astype(cfg.acc_jnp),
                   plan.m, plan.n)
    out = gemm_kernel.gemm(ap, bp, dp, plan, cfg, dataflow=dataflow,
                           shift=shift, activation=activation,
                           interpret=(backend == "interpret"))
    return out[:m, :n]


def matmul_impl(a: jnp.ndarray, b: jnp.ndarray, *, cfg: GemminiConfig,
                backend: Backend = "xla", **kw) -> jnp.ndarray:
    """Batched-LHS matmul: a may be (..., K); collapsed to 2D for the
    engine. Pure shape sugar over :func:`gemm_impl` -- backend and
    tune-mode behavior are exactly gemm's matrix with
    M = prod(leading dims)."""
    lead = a.shape[:-1]
    y = gemm_impl(a.reshape(-1, a.shape[-1]), b, cfg=cfg, backend=backend,
                  **kw)
    return y.reshape(*lead, b.shape[-1])


# -- conv2d -------------------------------------------------------------------
def _resolve_conv_co_tile(cfg: GemminiConfig, x, w, *, has_bias: bool,
                          stride: int, padding: int) -> int:
    """co_tile for this conv, honoring the effective tune mode (the conv
    twin of ``_resolve_plan``): ``off`` keeps the kernel's static default
    with no tuner import; otherwise the tuner consults the persistent
    cache."""
    from repro.core import flags
    if flags.get("tune_mode") == "off":
        # schedules is import-light (no measurement machinery): off mode
        # still never touches the tuner/cache.
        from repro.tune.schedules import DEFAULT_CO_TILE
        return DEFAULT_CO_TILE
    from repro.tune import tuner
    n, h, wd, ci = x.shape
    kh, kw, _, co = w.shape
    return tuner.resolve_conv_schedule(
        cfg, n, h, wd, ci, co, kh, kw, stride=stride, padding=padding,
        has_bias=has_bias).co_tile


def conv2d_impl(x, w, b=None, *, cfg: GemminiConfig, stride: int = 1,
                padding: int = 0, shift: int = 0,
                activation: Activation = Activation.NONE,
                backend: Backend = "xla", fused: bool = False,
                co_tile: Optional[int] = None):
    """Conv2D on the GEMM engine. Reached as ``ctx.conv2d(x, w, b, ...)``;
    under a mesh the image batch N is sharded.

    backend x fused matrix:

    ==========  ===========================================================
    backend     fused=False              fused=True
    ==========  ===========================================================
    xla         ``ref.conv2d_ref``: explicit im2col + XLA GEMM with the
                fused accumulate/shift/saturate/activation epilogue. This
                IS the fused-equivalent reference -- bit-identical to the
                fused kernel -- so ``fused`` does not change the xla path.
    pallas /    host im2col +            implicit-im2col Pallas kernel
    interpret   engine GEMM (the         (paper section 7 future work;
                paper's shipped          kernels/conv.py), ``co_tile``
                design)                  resolved via ``repro.tune`` when
                                         tuning is enabled
    ==========  ===========================================================

    ``co_tile``: explicit output-channel tile for the fused kernel;
    ``None`` resolves it through the flag-gated tuner (static default 128
    under ``tune_mode=off``).
    """
    if backend == "xla":
        # fused=True intentionally routes here too (there is no separate
        # XLA lowering): conv2d_ref is the fused-equivalent reference, not
        # a silent fallback -- see the matrix above.
        return ref_ops.conv2d_ref(x, w, b, stride=stride, padding=padding,
                                  acc_dtype=cfg.acc_jnp,
                                  out_dtype=cfg.output_jnp, shift=shift,
                                  activation=activation)
    if fused:
        from repro.kernels import conv as conv_kernel
        if co_tile is None:
            co_tile = _resolve_conv_co_tile(cfg, x, w, has_bias=b is not None,
                                            stride=stride, padding=padding)
        return conv_kernel.conv2d_implicit(
            x, w, b, cfg=cfg, stride=stride, padding=padding, shift=shift,
            activation=activation, co_tile=co_tile,
            interpret=(backend == "interpret"))
    n, h, wd, c = x.shape
    kh, kw, _, co = w.shape
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (wd + 2 * padding - kw) // stride + 1
    a = ref_ops.im2col(x, kh, kw, stride, padding)   # host-side im2col
    y = gemm_impl(a, w.reshape(-1, co), None if b is None else b[None, :],
                  cfg=cfg, shift=shift, activation=activation,
                  backend=backend)
    return y.reshape(n, oh, ow, co)


# -- attention ---------------------------------------------------------------
# Engine config the attention tuner falls back to when the caller has none:
# attention streams bf16 and accumulates f32 regardless of the GEMM engine's
# quantized datapath, so only the VMEM budgets / dim are consulted.
_ATTN_ENGINE_CFG: Optional[GemminiConfig] = None


def _attn_engine_cfg() -> GemminiConfig:
    global _ATTN_ENGINE_CFG
    if _ATTN_ENGINE_CFG is None:
        _ATTN_ENGINE_CFG = GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                         output_dtype="bf16")
    return _ATTN_ENGINE_CFG


def _resolve_attn_blocks(cfg: Optional[GemminiConfig], q, k, *, causal: bool,
                         window: Optional[int]) -> "tuple[int, int]":
    """(block_q, block_k) for this attention, honoring the effective tune
    mode (the attention twin of ``_resolve_plan``). Inside a mesh'd
    context this runs under ``shard_map`` tracing, so the fingerprinted
    batch is the PER-DEVICE batch."""
    from repro.core import flags
    if flags.get("tune_mode") == "off":
        from repro.tune.schedules import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q
        return DEFAULT_BLOCK_Q, DEFAULT_BLOCK_K
    from repro.tune import tuner
    b, tq, h, d = q.shape
    _, tk, kvh, _ = k.shape
    sched = tuner.resolve_attn_schedule(
        cfg or _attn_engine_cfg(), b, tq, tk, h, kvh, d, causal=causal,
        window=window, dtype=q.dtype)
    return sched.block_q, sched.block_k


def flash_attention_impl(q, k, v, *, causal: bool = True,
                         window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         scale: Optional[float] = None,
                         block_q: Optional[int] = None,
                         block_k: Optional[int] = None,
                         cfg: Optional[GemminiConfig] = None,
                         backend: Backend = "xla"):
    """Blockwise-softmax attention. See kernels/attention.py for the TPU
    kernel. Reached as ``ctx.flash_attention(q, k, v, ...)``; under a
    mesh the batch B is sharded.

    ``block_q``/``block_k``: explicit blocking for the Pallas kernel;
    ``None`` resolves the schedule through the flag-gated tuner (static
    512/512 defaults under ``tune_mode=off``). ``cfg`` supplies the VMEM
    budgets for schedule legality/fingerprinting (a bf16 engine default is
    used when omitted -- the value every launcher elaborates with, so
    context-supplied and defaulted cfgs fingerprint identically today).

    backend x tune-mode matrix:

    ==========  ===========================================================
    xla         ``blockwise_attention_xla``: online-softmax scan over
                1024-key blocks (clamped to a 128-multiple of Tk), exact
                oracle numerics, differentiable (the train path), ignores
                block_q/block_k/cfg and the tune mode entirely.
    pallas /    off: static 512/512        cached/full: ``AttnSchedule``
    interpret   blocks                     (block_q, block_k) from the
                                           schema-v2 plan cache, measured
                                           under ``full``; fingerprinted
                                           at the per-device batch when
                                           the context carries a mesh
    ==========  ===========================================================

    A *traced* window (gemma-style mixed local:global layers scanned as
    data) cannot parameterize a Mosaic kernel; callers route those to an
    xla-backend context (see ``models.attention._route_window``).
    """
    if backend == "xla":
        from repro.models.attention import blockwise_attention_xla
        return blockwise_attention_xla(q, k, v, causal=causal, window=window,
                                       softcap=softcap, scale=scale)
    if block_q is None or block_k is None:
        bq, bk = _resolve_attn_blocks(cfg, q, k, causal=causal, window=window)
        block_q = block_q if block_q is not None else bq
        block_k = block_k if block_k is not None else bk
    from repro.kernels import attention as attn_kernel
    return attn_kernel.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k,
        interpret=(backend == "interpret"))


# -- paged attention ---------------------------------------------------------
def paged_attention_impl(q, k_pool, v_pool, block_tables, lengths, *,
                         window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         scale: Optional[float] = None,
                         backend: Backend = "xla"):
    """Single-token decode over a paged KV cache (the serving engine's hot
    loop). q: (B, 1, H, D); k_pool/v_pool: (KVH, NP, page, D); block_tables:
    (B, MP) int32; lengths: (B,) int32 live tokens incl. the current one.
    Reached as ``ctx.paged_attention(...)``; under a mesh the decode slots
    (B) are sharded against replicated pools.

    The *page size* is the tuned schedule here -- it is baked into the pool
    shape when the serving engine sizes its cache arena through
    ``repro.tune.resolve_paged_attn_schedule``, not resolved per call (a
    pool cannot be re-blocked mid-flight).

    backend matrix (``gqa_grouped_decode`` flag applies to xla only):

    ==========  ===========================================================
    xla         ``paged_decode_attention_xla``: explicit block-table
                gather, bit-identical to the dense ``decode_attention``
                under either ``gqa_grouped_decode`` setting (the engine's
                exact-match contract); SPMD-partitionable.
    pallas /    ``kernels/attention.paged_decode_attention``: block tables
    interpret   scalar-prefetched to SMEM, one pool page DMA'd per grid
                step via the BlockSpec index map; dead pages clamp-elided
                and compute-skipped (``block_live``). The grouped-decode
                flag does not apply (the kernel is already grouped).
    ==========  ===========================================================
    """
    if backend == "xla":
        from repro.models.attention import (PagedKVCache,
                                            paged_decode_attention_xla)
        cache = PagedKVCache(k_pool, v_pool, block_tables, lengths,
                             k_pool.shape[2])
        return paged_decode_attention_xla(q, cache, window=window,
                                          softcap=softcap, scale=scale)
    from repro.kernels import attention as attn_kernel
    return attn_kernel.paged_decode_attention(
        q, k_pool, v_pool, block_tables, lengths, window=window,
        softcap=softcap, scale=scale, interpret=(backend == "interpret"))


def paged_prefill_attention_impl(q, k_pool, v_pool, block_table, start, *,
                                 window: Optional[int] = None,
                                 softcap: Optional[float] = None,
                                 scale: Optional[float] = None,
                                 kv_pages: Optional[int] = None,
                                 backend: Backend = "xla"):
    """Chunked-prefill attention over a paged KV cache: one request's fresh
    chunk of queries (q: (1, T, H, D), logical positions [start, start+T))
    attends cache pages + the chunk itself, all through the request's block
    table (``block_table``: (MP,) int32). The chunk's own KV must already
    be scattered into the pools (write first, then attend -- the decode
    discipline); ``start`` may be a traced scalar, so one compile bucket
    serves every chunk offset of a given chunk length. Reached as
    ``ctx.paged_prefill_attention(...)``; per-request (B == 1), so a mesh
    never shards it.

    ``kv_pages``: STATIC upper bound on the table prefix that can hold
    live keys -- the admission-time bound the serving engine derives from
    the request's full (padded) prompt length. The table is sliced to its
    first ``kv_pages`` entries before either backend runs, so the xla
    gather twin contracts ``kv_pages * page`` keys instead of the full
    table capacity ``MP * page`` (dead-key MACs cut for short prompts on
    long-context engines) and the kernel grid walks ``kv_pages`` logical
    pages. The caller must guarantee ``kv_pages * page >= start + T`` for
    every chunk of the request (the engine uses the whole-prompt padded
    footprint, which bounds every chunk frontier). ``None`` keeps the full
    table.

    backend matrix (no tunable flags enter here; the page size was baked
    into the pool shape at engine startup, see :func:`paged_attention_impl`):

    ==========  ===========================================================
    xla         explicit gather + ``blockwise_attention_xla`` with the same
                KV blocking anchored at position 0 as the single-pass
                prefill path -- bit-identical to the whole-prompt pass for
                the overlapping rows (the serve_decode exact-match gate
                with chunking enabled relies on this).
    pallas /    ``kernels/attention.paged_prefill_attention``: block table
    interpret   scalar-prefetched to SMEM, grid (H, nq, pages), one pool
                page DMA'd per step via the BlockSpec index map; dead pages
                beyond the causal frontier are clamp-elided and skipped.
    ==========  ===========================================================
    """
    if kv_pages is not None and kv_pages < block_table.shape[0]:
        block_table = block_table[:kv_pages]
    if backend == "xla":
        from repro.models.attention import (PagedKVCache,
                                            paged_prefill_attention_xla)
        cache = PagedKVCache(k_pool, v_pool, block_table[None],
                             jnp.zeros((1,), jnp.int32), k_pool.shape[2])
        return paged_prefill_attention_xla(q, cache, start, window=window,
                                           softcap=softcap, scale=scale)
    from repro.kernels import attention as attn_kernel
    return attn_kernel.paged_prefill_attention(
        q, k_pool, v_pool, block_table, start, window=window,
        softcap=softcap, scale=scale, interpret=(backend == "interpret"))


# -- mamba2 ssd ---------------------------------------------------------------
def ssd_impl(x, dt, a_log, b, c, *, d_skip=None, chunk: int = 256,
             initial_state=None, return_final_state: bool = False,
             backend: Backend = "xla"):
    """Mamba-2 SSD mixer. See kernels/mamba2.py for the chunked TPU kernel.
    Reached as ``ctx.ssd(...)``; under a mesh the batch B is sharded.

    ``initial_state``: (B, H, N, P) f32 recurrent state carried in from a
    previous segment (chunked prefill resumes here); ``return_final_state``
    additionally returns the (B, H, N, P) post-sequence state (the
    prefill->decode handoff).

    backend matrix (no tunable flags; ``chunk`` is the SSD decomposition
    granularity, a model hyperparameter rather than a tuned schedule):

    ==========  ===========================================================
    xla         ``models.ssm.ssd_chunked_xla``: intra-chunk einsums + the
                inter-chunk ``lax.scan``; the oracle structure and the
                serving/training reference (supports resumable
                ``initial_state`` for chunked prefill).
    pallas /    ``kernels/mamba2.ssd``: the same decomposition with the
    interpret   intra-chunk GEMMs lowered as Pallas kernels and the whole
                chunk-scan epilogue fused in-kernel (d_skip add + final
                state emitted from the VMEM state scratch -- no
                accumulator HBM round-trip). A non-None ``initial_state``
                demotes to the xla path: the kernel's VMEM scan always
                starts from zeros (resume is the serving reference's job,
                like the traced-window demotion in attention).
    ==========  ===========================================================
    """
    if backend == "xla" or initial_state is not None:
        from repro.models.ssm import _final_state, ssd_chunked_xla
        y = ssd_chunked_xla(x, dt, a_log, b, c, d_skip=d_skip, chunk=chunk,
                            initial_state=initial_state)
        if not return_final_state:
            return y
        _, fs = _final_state(x, dt, a_log, b, c, initial_state=initial_state)
        return y, fs
    from repro.kernels import mamba2 as m2
    return m2.ssd(x, dt, a_log, b, c, d_skip=d_skip, chunk=chunk,
                  interpret=(backend == "interpret"),
                  return_final_state=return_final_state)


# ---------------------------------------------------------------------------
# The PR-5 ``ops.<name>(..., backend=...)`` deprecation shims lived here for
# one release and are now gone: dispatch through
# ``repro.core.context.ExecutionContext`` (``ctx.gemm``, ``ctx.ssd``, ...).
# Lint rule GL506 (repro/analysis/lint/source.py) forbids reintroducing a
# top-level alias for any legacy name in this module.
# ---------------------------------------------------------------------------
