# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from jax.experimental.pallas import tpu as _pltpu


def tpu_compiler_params(**kw):
    """Mosaic compiler params across jax versions: the class was named
    ``TPUCompilerParams`` before jax 0.7 and ``CompilerParams`` after."""
    cls = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams
    return cls(**kw)
