"""Gemmini-generated tiled GEMM as Pallas TPU kernels.

This is the elaborated "systolic array instance": ``C = A @ B + D`` with the
paper's two dataflows, datatype genericity (int8->int32 quantized path and
bf16/fp32 float paths), fused bias, fused activation, and the
rounding/saturating-bitshift output scaling of the quantized datapath.

Dataflow mapping (DESIGN.md section 2):

* **OS (output-stationary)** -- grid (gm, gn, gk) with K innermost
  ("arbitrary" semantics). The C tile lives in a wider-bitwidth VMEM
  accumulator scratch across the K stream (the PE-resident accumulators of
  the paper), and the epilogue -- rounding bitshift, saturation, activation --
  is applied *inside the kernel* on the last K step ("within PEs (for the
  output-stationary dataflow)").

* **WS (weight-stationary)** -- grid (gn, gk, gm) with M innermost. The B
  (weight) tile's block index is constant along the inner M axis, so the
  weight block stays resident in VMEM while A tiles stream past it -- the
  preloaded PE weight buffer. Partial sums are accumulated through an
  aliased accumulator operand (read-modify-write), which is the paper's
  accumulator-SRAM-with-input-adders. The epilogue runs as a separate pass
  over the accumulator (``accumulator_epilogue``), matching "at the output of
  the accumulator (for the weight-stationary dataflow)". A bias D is applied
  by initializing the accumulator with it ("executing a mvin into the
  accumulator").

Both kernels double-buffer streamed operands through the Pallas grid pipeline
(pipeline_depth=2 in the generator config); pipeline_depth=1 ("fully
combinational" analogue) is emulated by forcing a serial grid.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.config import Activation, Dataflow, GemminiConfig
from repro.core.tiling import TilePlan
from repro.kernels import epilogue as epi


# ---------------------------------------------------------------------------
# Output-stationary kernel
# ---------------------------------------------------------------------------
def _os_kernel(a_ref, b_ref, d_ref, c_ref, acc_ref, *, nk: int,
               acc_dtype, out_dtype, shift: int, activation: Activation,
               has_bias: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        if has_bias:
            # D is preloaded into the PE accumulators (paper fig. 4, step 1).
            acc_ref[...] = d_ref[...].astype(acc_dtype)
        else:
            acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=acc_dtype
    )

    @pl.when(k == nk - 1)
    def _flush():
        c_ref[...] = epi.apply(acc_ref[...], shift=shift,
                               activation=activation, out_dtype=out_dtype)


def gemm_os(a: jnp.ndarray, b: jnp.ndarray, d: Optional[jnp.ndarray],
            plan: TilePlan, cfg: GemminiConfig, *, shift: int = 0,
            activation: Activation = Activation.NONE,
            interpret: bool = False) -> jnp.ndarray:
    """Output-stationary GEMM on padded operands (shapes divide the tiles)."""
    m, n, k = plan.m, plan.n, plan.k
    tm, tn, tk = plan.tile_m, plan.tile_n, plan.tile_k
    gm, gn, gk = plan.grid
    assert a.shape == (m, k) and b.shape == (k, n), (a.shape, b.shape)
    has_bias = d is not None
    if not has_bias:
        d = jnp.zeros((1, n), cfg.acc_jnp)  # placeholder operand (never read)

    kernel = functools.partial(
        _os_kernel, nk=gk, acc_dtype=cfg.acc_jnp, out_dtype=cfg.output_jnp,
        shift=shift, activation=activation, has_bias=has_bias)

    # pipeline_depth=1 emulation: make every axis "arbitrary" (serial), which
    # disables cross-iteration overlap in the Mosaic pipeline.
    if cfg.pipeline_depth == 1:
        semantics = ("arbitrary", "arbitrary", "arbitrary")
    else:
        semantics = ("parallel", "parallel", "arbitrary")

    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tm if has_bias else 1, tn),
                         (lambda i, j, kk: (i, j)) if has_bias
                         else (lambda i, j, kk: (0, j))),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), cfg.output_jnp),
        scratch_shapes=[pltpu.VMEM((tm, tn), cfg.acc_jnp)],
        compiler_params=pltpu.CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(a, b, d)


# ---------------------------------------------------------------------------
# Weight-stationary kernel
# ---------------------------------------------------------------------------
def _ws_kernel(b_ref, a_ref, acc_in_ref, acc_out_ref, *, acc_dtype):
    # B resident (index constant along inner m axis); A streams; partial sums
    # accumulate through the aliased accumulator (read-modify-write adders).
    acc_out_ref[...] = acc_in_ref[...] + jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype)


def gemm_ws(a: jnp.ndarray, b: jnp.ndarray, d: Optional[jnp.ndarray],
            plan: TilePlan, cfg: GemminiConfig, *, shift: int = 0,
            activation: Activation = Activation.NONE,
            interpret: bool = False) -> jnp.ndarray:
    """Weight-stationary GEMM: resident weights, streamed A, aliased acc."""
    m, n, k = plan.m, plan.n, plan.k
    tm, tn, tk = plan.tile_m, plan.tile_n, plan.tile_k
    gm, gn, gk = plan.grid
    assert a.shape == (m, k) and b.shape == (k, n)

    # mvin D into the accumulator (or zeros) before the compute stream.
    if d is not None:
        acc0 = jnp.broadcast_to(d.astype(cfg.acc_jnp), (m, n))
    else:
        acc0 = jnp.zeros((m, n), cfg.acc_jnp)

    acc = pl.pallas_call(
        functools.partial(_ws_kernel, acc_dtype=cfg.acc_jnp),
        grid=(gn, gk, gm),  # m innermost: weight tile resident across m
        in_specs=[
            pl.BlockSpec((tk, tn), lambda j, kk, i: (kk, j)),   # B (resident)
            pl.BlockSpec((tm, tk), lambda j, kk, i: (i, kk)),   # A (streams)
            pl.BlockSpec((tm, tn), lambda j, kk, i: (i, j)),    # acc in
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda j, kk, i: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), cfg.acc_jnp),
        input_output_aliases={2: 0},
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")
            if cfg.pipeline_depth > 1 else ("arbitrary",) * 3),
        interpret=interpret,
    )(b, a, acc0)

    # Epilogue at the output of the accumulator (paper: WS scaling location).
    return accumulator_epilogue(acc, plan, cfg, shift=shift,
                                activation=activation, interpret=interpret)


def _epilogue_kernel(acc_ref, c_ref, *, shift, activation, out_dtype):
    c_ref[...] = epi.apply(acc_ref[...], shift=shift, activation=activation,
                           out_dtype=out_dtype)


def accumulator_epilogue(acc: jnp.ndarray, plan: TilePlan, cfg: GemminiConfig,
                         *, shift: int = 0,
                         activation: Activation = Activation.NONE,
                         interpret: bool = False) -> jnp.ndarray:
    """Scale/saturate/activate pass over the accumulator (mvout path)."""
    m, n = acc.shape
    tm, tn = plan.tile_m, plan.tile_n
    return pl.pallas_call(
        functools.partial(_epilogue_kernel, shift=shift, activation=activation,
                          out_dtype=cfg.output_jnp),
        grid=(m // tm, n // tn),
        in_specs=[pl.BlockSpec((tm, tn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), cfg.output_jnp),
        interpret=interpret,
    )(acc)


def gemm(a, b, d, plan: TilePlan, cfg: GemminiConfig, *,
         dataflow: Optional[Dataflow] = None, shift: int = 0,
         activation: Activation = Activation.NONE,
         interpret: bool = False) -> jnp.ndarray:
    """Dispatch on the elaborated (or runtime-selected) dataflow."""
    df = dataflow or plan.dataflow
    if cfg.dataflow is not Dataflow.BOTH and df is not cfg.dataflow:
        raise ValueError(f"instance elaborated with {cfg.dataflow}, got {df}")
    fn = gemm_os if df is Dataflow.OS else gemm_ws
    return fn(a, b, d, plan, cfg, shift=shift, activation=activation,
              interpret=interpret)
