"""Gemmini-generated tiled GEMM as Pallas TPU kernels.

This is the elaborated "systolic array instance": ``C = A @ B + D`` with the
paper's two dataflows, datatype genericity (int8->int32 quantized path and
bf16/fp32 float paths), fused bias, fused activation, and the
rounding/saturating-bitshift output scaling of the quantized datapath.

Dataflow mapping (DESIGN.md section 2):

* **OS (output-stationary)** -- grid (gm, gn, gk) with K innermost
  ("arbitrary" semantics). The C tile lives in a wider-bitwidth VMEM
  accumulator scratch across the K stream (the PE-resident accumulators of
  the paper), and the epilogue -- rounding bitshift, saturation, activation --
  is applied *inside the kernel* on the last K step ("within PEs (for the
  output-stationary dataflow)").

* **WS (weight-stationary)** -- weight-major grid (gn, gm, gk): all the work
  under one weight column strip (fixed j) completes before the next weight
  tiles are touched -- the preloaded PE weight buffer's schedule. Partial
  sums accumulate in a VMEM accumulator scratch across the K stream (the
  paper's accumulator-SRAM-with-input-adders), and the epilogue is fused on
  the last K step "at the output of the accumulator (for the
  weight-stationary dataflow)" -- a single pallas_call, so the int32
  accumulator never round-trips HBM. A bias D is applied by initializing
  the accumulator with it ("executing a mvin into the accumulator");
  ``accumulator_epilogue`` remains as the explicit-mvout API for callers
  that hold a raw accumulator.

Both kernels double-buffer streamed operands through the Pallas grid pipeline
(pipeline_depth=2 in the generator config); pipeline_depth=1 ("fully
combinational" analogue) is emulated by forcing a serial grid.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import repro.kernels as kernels_pkg

from repro.core.config import Activation, Dataflow, GemminiConfig
from repro.core.tiling import TilePlan
from repro.kernels import epilogue as epi
from repro.kernels.contracts import kernel_contract


# ---------------------------------------------------------------------------
# Output-stationary kernel
# ---------------------------------------------------------------------------
def _os_kernel(a_ref, b_ref, d_ref, c_ref, acc_ref, *, nk: int,
               acc_dtype, out_dtype, shift: int, activation: Activation,
               has_bias: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        if has_bias:
            # D is preloaded into the PE accumulators (paper fig. 4, step 1).
            acc_ref[...] = d_ref[...].astype(acc_dtype)
        else:
            acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=acc_dtype
    )

    @pl.when(k == nk - 1)
    def _flush():
        c_ref[...] = epi.apply(acc_ref[...], shift=shift,
                               activation=activation, out_dtype=out_dtype)


@kernel_contract("gemm_os")
def gemm_os(a: jnp.ndarray, b: jnp.ndarray, d: Optional[jnp.ndarray],
            plan: TilePlan, cfg: GemminiConfig, *, shift: int = 0,
            activation: Activation = Activation.NONE,
            interpret: bool = False) -> jnp.ndarray:
    """Output-stationary GEMM on padded operands (shapes divide the tiles)."""
    m, n, k = plan.m, plan.n, plan.k
    tm, tn, tk = plan.tile_m, plan.tile_n, plan.tile_k
    gm, gn, gk = plan.grid
    assert a.shape == (m, k) and b.shape == (k, n), (a.shape, b.shape)
    has_bias = d is not None
    if not has_bias:
        d = jnp.zeros((1, n), cfg.acc_jnp)  # placeholder operand (never read)

    kernel = functools.partial(
        _os_kernel, nk=gk, acc_dtype=cfg.acc_jnp, out_dtype=cfg.output_jnp,
        shift=shift, activation=activation, has_bias=has_bias)

    # pipeline_depth=1 emulation: make every axis "arbitrary" (serial), which
    # disables cross-iteration overlap in the Mosaic pipeline.
    if cfg.pipeline_depth == 1:
        semantics = ("arbitrary", "arbitrary", "arbitrary")
    else:
        semantics = ("parallel", "parallel", "arbitrary")

    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tm if has_bias else 1, tn),
                         (lambda i, j, kk: (i, j)) if has_bias
                         else (lambda i, j, kk: (0, j))),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), cfg.output_jnp),
        scratch_shapes=[pltpu.VMEM((tm, tn), cfg.acc_jnp)],
        compiler_params=kernels_pkg.tpu_compiler_params(dimension_semantics=semantics),
        interpret=interpret,
    )(a, b, d)


# ---------------------------------------------------------------------------
# Weight-stationary kernel
# ---------------------------------------------------------------------------
def _ws_kernel(b_ref, a_ref, d_ref, c_ref, acc_ref, *, nk: int,
               acc_dtype, out_dtype, shift: int, activation: Activation,
               has_bias: bool):
    # Weight-major traversal: all work under one weight column strip (fixed
    # j) completes before the next weight tiles are touched. Partial sums
    # live in the VMEM accumulator scratch across the K stream -- the
    # accumulator-SRAM-with-input-adders of the paper. (The seed's
    # accumulate-through-aliased-HBM-io pattern was unsound for k_steps > 1:
    # Pallas does not guarantee read-after-write through an input/output
    # alias across separated grid revisits.)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _preload():
        if has_bias:
            # "executing a mvin into the accumulator" (paper: WS bias path).
            acc_ref[...] = d_ref[...].astype(acc_dtype)
        else:
            acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype)

    @pl.when(kk == nk - 1)
    def _flush():
        # Epilogue "at the output of the accumulator" (paper: WS scaling
        # location), fused on the last K step so the accumulator never takes
        # an HBM round-trip through a separate epilogue pass.
        c_ref[...] = epi.apply(acc_ref[...], shift=shift,
                               activation=activation, out_dtype=out_dtype)


@kernel_contract("gemm_ws")
def gemm_ws(a: jnp.ndarray, b: jnp.ndarray, d: Optional[jnp.ndarray],
            plan: TilePlan, cfg: GemminiConfig, *, shift: int = 0,
            activation: Activation = Activation.NONE,
            interpret: bool = False) -> jnp.ndarray:
    """Weight-stationary GEMM, one pallas_call end to end.

    Weight-major grid (gn outermost), VMEM-resident accumulator across the K
    stream, and the rounding-shift/saturation/activation epilogue fused on
    the final K step. The int32 accumulator never exists in HBM at all: the
    only HBM write is the finished C at output precision (the seed lowered
    WS as acc-write + acc-re-read + epilogue-write across two pallas_calls).
    """
    m, n, k = plan.m, plan.n, plan.k
    tm, tn, tk = plan.tile_m, plan.tile_n, plan.tile_k
    gm, gn, gk = plan.grid
    assert a.shape == (m, k) and b.shape == (k, n)
    has_bias = d is not None
    if not has_bias:
        d = jnp.zeros((1, n), cfg.acc_jnp)  # placeholder operand (never read)

    kernel = functools.partial(
        _ws_kernel, nk=gk, acc_dtype=cfg.acc_jnp, out_dtype=cfg.output_jnp,
        shift=shift, activation=activation, has_bias=has_bias)

    return pl.pallas_call(
        kernel,
        grid=(gn, gm, gk),  # weight-major: finish a B column strip, move on
        in_specs=[
            pl.BlockSpec((tk, tn), lambda j, i, kk: (kk, j)),   # B (weights)
            pl.BlockSpec((tm, tk), lambda j, i, kk: (i, kk)),   # A (streams)
            pl.BlockSpec((tm if has_bias else 1, tn),
                         (lambda j, i, kk: (i, j)) if has_bias
                         else (lambda j, i, kk: (0, j))),       # D (bias)
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda j, i, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), cfg.output_jnp),
        scratch_shapes=[pltpu.VMEM((tm, tn), cfg.acc_jnp)],
        compiler_params=kernels_pkg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
            if cfg.pipeline_depth > 1 else ("arbitrary",) * 3),
        interpret=interpret,
    )(b, a, d)


def _epilogue_kernel(acc_ref, c_ref, *, shift, activation, out_dtype):
    c_ref[...] = epi.apply(acc_ref[...], shift=shift, activation=activation,
                           out_dtype=out_dtype)


@kernel_contract("accumulator_epilogue")
def accumulator_epilogue(acc: jnp.ndarray, plan: TilePlan, cfg: GemminiConfig,
                         *, shift: int = 0,
                         activation: Activation = Activation.NONE,
                         interpret: bool = False) -> jnp.ndarray:
    """Scale/saturate/activate pass over the accumulator (mvout path)."""
    m, n = acc.shape
    tm, tn = plan.tile_m, plan.tile_n
    return pl.pallas_call(
        functools.partial(_epilogue_kernel, shift=shift, activation=activation,
                          out_dtype=cfg.output_jnp),
        grid=(m // tm, n // tn),
        in_specs=[pl.BlockSpec((tm, tn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), cfg.output_jnp),
        # every tile is independent: both axes pipeline freely (found by
        # lint GL503 — an undeclared grid serializes under Mosaic)
        compiler_params=kernels_pkg.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(acc)


def gemm(a, b, d, plan: TilePlan, cfg: GemminiConfig, *,
         dataflow: Optional[Dataflow] = None, shift: int = 0,
         activation: Activation = Activation.NONE,
         interpret: bool = False) -> jnp.ndarray:
    """Dispatch on the elaborated (or runtime-selected) dataflow."""
    df = dataflow or plan.dataflow
    if cfg.dataflow is not Dataflow.BOTH and df is not cfg.dataflow:
        raise ValueError(f"instance elaborated with {cfg.dataflow}, got {df}")
    fn = gemm_os if df is Dataflow.OS else gemm_ws
    return fn(a, b, d, plan, cfg, shift=shift, activation=activation,
              interpret=interpret)
