"""Pure-jnp oracles for every Pallas kernel.

Each ``*_ref`` is the mathematically-straightforward implementation the
kernels must match (bit-exactly for the integer datapath; to fp tolerance for
float paths). Kept dependency-light so tests can sweep shapes/dtypes quickly.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import Activation
from repro.kernels import epilogue as epi


# -- GEMM -------------------------------------------------------------------
def gemm_ref(a: jnp.ndarray, b: jnp.ndarray, d: Optional[jnp.ndarray],
             *, acc_dtype, out_dtype, shift: int = 0,
             activation: Activation = Activation.NONE) -> jnp.ndarray:
    """C = epilogue(A @ B + D) with accumulation in acc_dtype."""
    acc = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                              preferred_element_type=acc_dtype)
    if d is not None:
        acc = acc + d.astype(acc_dtype)
    return epi.apply(acc, shift=shift, activation=activation,
                     out_dtype=out_dtype)


# -- Conv2D (explicit im2col, the paper's shipped host-side path) ------------
def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int,
           padding: int) -> jnp.ndarray:
    """NHWC -> (N*OH*OW, KH*KW*C) patch matrix."""
    n, h, w, c = x.shape
    x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            patches.append(
                jax.lax.slice(x, (0, i, j, 0),
                              (n, i + (oh - 1) * stride + 1,
                               j + (ow - 1) * stride + 1, c),
                              (1, stride, stride, 1)))
    stacked = jnp.stack(patches, axis=3)          # (N, OH, OW, KH*KW, C)
    return stacked.reshape(n * oh * ow, kh * kw * c)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, b: Optional[jnp.ndarray],
               *, stride: int = 1, padding: int = 0, acc_dtype=jnp.int32,
               out_dtype=jnp.int8, shift: int = 0,
               activation: Activation = Activation.NONE) -> jnp.ndarray:
    """Conv2D NHWC x HWIO via explicit im2col + GEMM (paper section 3.3)."""
    n, h, wd, c = x.shape
    kh, kw, ci, co = w.shape
    assert ci == c
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (wd + 2 * padding - kw) // stride + 1
    a = im2col(x, kh, kw, stride, padding)
    bmat = w.reshape(kh * kw * c, co)
    d = None if b is None else b[None, :]
    y = gemm_ref(a, bmat, d, acc_dtype=acc_dtype, out_dtype=out_dtype,
                 shift=shift, activation=activation)
    return y.reshape(n, oh, ow, co)


# -- Flash attention oracle ---------------------------------------------------
def mha_ref(q, k, v, *, causal: bool = True, window: Optional[int] = None,
            softcap: Optional[float] = None, scale: Optional[float] = None):
    """Reference multi-head attention.

    q: (B, Tq, H, D); k/v: (B, Tk, KVH, D) with H % KVH == 0 (GQA).
    window: sliding-window size (local attention) if set.
    softcap: gemma-2 style logit soft-capping if set.
    Positions are aligned at the end: query i attends keys <= i + (Tk - Tq).
    """
    b, tq, h, dd = q.shape
    _, tk, kvh, _ = k.shape
    rep = h // kvh
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32))
    s *= (scale if scale is not None else 1.0 / jnp.sqrt(dd))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(tq)[:, None] + (tk - tq)
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype)


# -- Mamba-2 SSD oracle -------------------------------------------------------
def ssd_ref(x, dt, a_log, b, c, *, d_skip=None):
    """Naive-recurrence SSD (state-space duality) oracle.

    Shapes (all batch-first, chunk-free):
      x:     (B, T, H, P)   input heads
      dt:    (B, T, H)      softplus'd step sizes (already positive)
      a_log: (H,)           log of -A (per head, scalar SSM)
      b:     (B, T, G, N)   input->state projections (G state groups)
      c:     (B, T, G, N)   state->output projections
    Returns y: (B, T, H, P).  Head h uses group h % G... (G divides H; heads
    are grouped contiguously: group = h // (H // G)).
    """
    bsz, t, h, p = x.shape
    _, _, g, n = b.shape
    heads_per_group = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))                 # (H,)
    dt = dt.astype(jnp.float32)
    da = jnp.exp(dt * a[None, None, :])                     # (B, T, H) decay

    def step(state, inp):
        da_t, x_t, dt_t, b_t, c_t = inp
        # state: (B, H, P, N)
        b_h = jnp.repeat(b_t, heads_per_group, axis=1)      # (B, H, N)
        c_h = jnp.repeat(c_t, heads_per_group, axis=1)
        state = state * da_t[..., None, None] + \
            (dt_t[..., None] * x_t)[..., None] * b_h[:, :, None, :]
        y_t = jnp.einsum("bhpn,bhn->bhp", state, c_h)
        return state, y_t

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(da, 1, 0), jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0), jnp.moveaxis(b.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, init, xs)
    y = jnp.moveaxis(ys, 0, 1)                              # (B, T, H, P)
    if d_skip is not None:
        y = y + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype)
