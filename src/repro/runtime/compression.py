"""Gradient compression for the DP all-reduce (distributed-optimization).

Two compressors, both jit-compatible and used ahead of the data-parallel
gradient reduction:

* **Top-k sparsification with error feedback** -- Deep Gradient Compression
  (Lin, Han, Mao et al.; the paper's own reference [21]). Only the largest-k
  magnitude entries are exchanged; the residual is carried in an error-
  feedback buffer added back before the next selection, which keeps
  convergence close to dense SGD/Adam.
* **int8 stochastic-free linear quantization** -- per-tensor symmetric scale,
  the same rounding-shift numerics as the Gemmini datapath, cutting the DP
  all-reduce payload 4x vs fp32 (2x vs bf16).

Both express the *payload reduction* in pure JAX so XLA shards/overlaps the
reduced tensors like any other; the roofline collective term of a
compressed step drops proportionally (verified in the tests by byte count).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# top-k + error feedback
# ---------------------------------------------------------------------------
def topk_compress(g: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Keep the k largest-|.| entries. Returns (values, flat_indices)."""
    flat = g.reshape(-1)
    k = min(k, flat.shape[0])
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decompress(values: jnp.ndarray, idx: jnp.ndarray,
                    shape, dtype) -> jnp.ndarray:
    n = 1
    for d in shape:
        n *= d
    flat = jnp.zeros((n,), dtype).at[idx].set(values.astype(dtype))
    return flat.reshape(shape)


class ErrorFeedbackState(NamedTuple):
    residual: Any          # pytree mirroring grads


def init_error_feedback(grads: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def compress_grads_with_feedback(grads: Any, state: ErrorFeedbackState,
                                 density: float = 0.01
                                 ) -> Tuple[Any, ErrorFeedbackState]:
    """DGC step: g + residual -> top-k kept (exchanged) -> residual update.

    Returns (sparse grads to feed the optimizer/all-reduce, new state).
    """

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        k = max(1, int(density * acc.size))
        vals, idx = topk_compress(acc, k)
        kept = topk_decompress(vals, idx, acc.shape, jnp.float32)
        return kept.astype(g.dtype), acc - kept

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(state.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    kept = jax.tree.unflatten(tdef, [o[0] for o in outs])
    resid = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return kept, ErrorFeedbackState(resid)


# ---------------------------------------------------------------------------
# int8 linear quantization (per-tensor symmetric)
# ---------------------------------------------------------------------------
def int8_compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def int8_compress_tree(grads: Any) -> Any:
    return jax.tree.map(int8_compress, grads)


def int8_roundtrip_tree(grads: Any) -> Any:
    """Quantize-dequantize every leaf (models the compressed all-reduce)."""
    def one(g):
        q, s = int8_compress(g)
        return int8_decompress(q, s).astype(g.dtype)
    return jax.tree.map(one, grads)
