from repro.runtime.ft import (HeartbeatMonitor, StepWatchdog,
                              StragglerDetector, RestartPolicy,
                              run_with_restarts)
from repro.runtime.faults import (FaultInjector, FaultPlan, FaultSpec,
                                  TransientOpError)
from repro.runtime.compression import (topk_compress, topk_decompress,
                                       ErrorFeedbackState,
                                       compress_grads_with_feedback,
                                       int8_compress, int8_decompress)

__all__ = [
    "FaultInjector", "FaultPlan", "FaultSpec", "TransientOpError",
    "HeartbeatMonitor", "StepWatchdog", "StragglerDetector",
    "RestartPolicy", "run_with_restarts", "topk_compress",
    "topk_decompress", "ErrorFeedbackState",
    "compress_grads_with_feedback", "int8_compress", "int8_decompress",
]
