"""Deterministic fault injection for the serving/runtime stack.

The paper's system-level argument cuts both ways: an engine evaluated only
on the happy path is not evaluated. This module is the *provocation* half
of the robustness story -- a seeded, declarative :class:`FaultPlan` whose
every firing is reproducible from a single RNG seed, injected at the host
boundaries the engine already owns (around its jitted step calls, at the
page allocator, at checkpoint writes, and around eager
:class:`~repro.core.context.ExecutionContext` op dispatch). The *survival*
half lives in :class:`repro.serving.ServingEngine`: NaN/Inf guards with
retry-on-the-XLA-twin, bounded transient retries, schedule quarantine,
deadline shedding (docs/serving.md#robustness).

Everything here is off by default. Faults turn on either per engine
(``ServingEngine(faults=...)``) or process-wide via the ``GEMMINI_FAULTS``
environment variable / :func:`install`.

Fault kinds and their default sites::

    kind        injects                                 default site
    ----------  --------------------------------------  ------------
    nan / inf   poisoned kernel outputs (whole array)   *  (any site)
    transient   TransientOpError raised before the op   *  (any site)
    arena       page-allocator pressure (held pages)    arena
    straggler   a sleep before the engine step          step
    ckpt_io     OSError from save_checkpoint            checkpoint
    offload_io  failed KV host-offload DMA (the spill   spill
                or restore degrades to recompute)

Engine sites are ``prefill`` (whole-prompt and first-chunk calls),
``chunk`` (continuation chunks), ``decode`` (the decode step), ``step``
(once per engine iteration), ``arena`` (queried once per iteration),
``spill`` / ``restore`` (the KV host-offload copies, queried once per
attempted spill/restore when ``kv_offload`` is on), and ``op:<name>``
for eager ExecutionContext dispatch (e.g. ``op:gemm``).

Why host-level injection: the engine's model steps are jitted, so anything
injected *inside* traced code would be baked into the compiled function --
every subsequent call would fail identically and no seed could make the
fault transient. Poisoning returned arrays and raising before dispatch
keeps the compiled artifacts byte-identical to the fault-free run, which
is exactly what lets the chaos suite assert bit-equal tokens.

Determinism: spec ``i`` of a plan draws from ``default_rng([seed, i])``
with an independent draw counter per site. Fault firings are therefore a
pure function of (plan, sequence of injection-point visits) -- and the
engine's visit sequence is itself deterministic given the submitted trace.

Spec string grammar (``GEMMINI_FAULTS`` / :meth:`FaultPlan.parse`)::

    seed=7;nan@decode:p=0.25,max=2;transient@prefill:max=1;arena:pages=2

``kind[@site][:k=v,...]`` items separated by ``;``. Keys: ``p``
(probability per draw), ``start``/``stop`` (eligible draw-index window,
per site), ``max`` (max firings), ``delay`` (straggler sleep seconds),
``pages`` (arena pages withheld per step). Sites may contain colons
(``nan@op:gemm:max=1`` targets site ``op:gemm``): the k=v tail starts at
the first colon segment containing an ``=``.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

KINDS = ("nan", "inf", "transient", "arena", "straggler", "ckpt_io",
         "offload_io")

# Site a bare kind targets when the spec omits ``@site``.
DEFAULT_SITES = {"arena": "arena", "straggler": "step",
                 "ckpt_io": "checkpoint", "offload_io": "spill"}

ENV_VAR = "GEMMINI_FAULTS"


class TransientOpError(RuntimeError):
    """An injected transient failure (the retryable class: in production
    this slot is an XLA runtime error / preempted RPC, not a model bug)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One declarative fault: what to inject, where, and how often."""

    kind: str
    site: str = "*"                # exact site name, or "*" = any site
    p: float = 1.0                 # firing probability per eligible draw
    start: int = 0                 # eligible draw-index window [start, stop)
    stop: int = 1 << 30            # ...counted per site
    max_hits: int = 1 << 30        # total firings across all sites
    delay_s: float = 0.02          # straggler sleep
    pages: int = 1                 # arena pages withheld per step

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"have {KINDS}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault probability must be in [0, 1], "
                             f"got {self.p}")


_SPEC_KEYS = {"p": ("p", float), "start": ("start", int),
              "stop": ("stop", int), "max": ("max_hits", int),
              "delay": ("delay_s", float), "pages": ("pages", int)}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seed plus an ordered tuple of :class:`FaultSpec`.

    Frozen and value-like: two engines built from equal plans inject
    identical fault sequences (the reproducibility contract chaos tests
    and bug reports rely on)."""

    seed: int = 0
    specs: Tuple[FaultSpec, ...] = ()

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Parse the compact ``GEMMINI_FAULTS`` grammar (module docstring).
        An empty/whitespace string is the empty plan (no faults)."""
        seed = 0
        specs: List[FaultSpec] = []
        for item in filter(None, (s.strip() for s in text.split(";"))):
            if item.startswith("seed="):
                seed = int(item[5:])
                continue
            # Sites may themselves contain colons (``op:gemm``), so the
            # k=v tail starts at the first colon segment holding an "=".
            segs = item.split(":")
            cut = next((i for i in range(1, len(segs)) if "=" in segs[i]),
                       len(segs))
            kind, _, site = segs[0].partition("@")
            site = ":".join([site.strip()] + [s.strip()
                                              for s in segs[1:cut]]) \
                if site else ""
            tail = ":".join(segs[cut:])
            kw: Dict[str, Union[int, float, str]] = {
                "kind": kind.strip(),
                "site": site or DEFAULT_SITES.get(kind.strip(), "*")}
            for kv in filter(None, (s.strip() for s in tail.split(","))):
                key, _, val = kv.partition("=")
                if key not in _SPEC_KEYS:
                    raise ValueError(
                        f"unknown fault-spec key {key!r} in {item!r}; "
                        f"have {sorted(_SPEC_KEYS)}")
                field, cast = _SPEC_KEYS[key]
                kw[field] = cast(val)
            specs.append(FaultSpec(**kw))  # type: ignore[arg-type]
        return cls(seed=seed, specs=tuple(specs))

    @classmethod
    def from_env(cls) -> Optional["FaultPlan"]:
        """The process-wide plan from ``$GEMMINI_FAULTS``, or None when the
        variable is unset/empty (faults stay off -- the default)."""
        text = os.environ.get(ENV_VAR, "").strip()
        if not text:
            return None
        plan = cls.parse(text)
        return plan if plan.specs else None


def _is_tracer(x) -> bool:
    import jax
    return isinstance(x, jax.core.Tracer)


class FaultInjector:
    """Stateful executor of one :class:`FaultPlan`.

    Holds the per-spec RNG streams and draw/hit counters; the injection
    points below are what the engine, allocator callers, checkpoint store,
    and context dispatch invoke. ``injected`` tallies firings by
    ``kind@site`` for telemetry.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rngs = [np.random.default_rng([plan.seed, i])
                      for i in range(len(plan.specs))]
        self._draws: List[collections.Counter] = [
            collections.Counter() for _ in plan.specs]
        self._hits = [0] * len(plan.specs)
        self.injected: collections.Counter = collections.Counter()
        self.sleep = time.sleep          # injectable for tests
        # Optional repro.obs.trace.Tracer: every firing lands as a
        # cat="fault" instant. The serving engine wires its own tracer in;
        # otherwise the process-global one (obs.trace.install) is used.
        self.tracer = None

    # -- core draw ---------------------------------------------------------
    def fires(self, site: str,
              kinds: Optional[Sequence[str]] = None) -> Optional[FaultSpec]:
        """Draw every matching spec at ``site``; the first that fires wins.
        Every matching spec's per-site draw counter advances whether or not
        it fires, so firings depend only on visit order, never on which
        other spec fired first."""
        hit: Optional[FaultSpec] = None
        for i, spec in enumerate(self.plan.specs):
            if kinds is not None and spec.kind not in kinds:
                continue
            if spec.site != "*" and spec.site != site:
                continue
            idx = self._draws[i][site]
            self._draws[i][site] += 1
            if hit is not None or self._hits[i] >= spec.max_hits:
                continue
            if not spec.start <= idx < spec.stop:
                continue
            if spec.p < 1.0 and self._rngs[i].random() >= spec.p:
                continue
            self._hits[i] += 1
            self.injected[f"{spec.kind}@{site}"] += 1
            hit = spec
        if hit is not None:
            self._trace_fire(hit, site)
        return hit

    def _trace_fire(self, spec: FaultSpec, site: str) -> None:
        """Emit the firing to this injector's tracer (or the process-global
        one): cat="fault" instant on the fault track."""
        from repro.obs import trace as otrace
        tracer = self.tracer or otrace.active()
        if tracer is not None:
            tracer.instant(f"fault:{spec.kind}", cat="fault",
                           tid=otrace.TID_FAULT, site=site)

    # -- injection points --------------------------------------------------
    def check_transient(self, site: str) -> None:
        """Raise :class:`TransientOpError` when a transient spec fires --
        called immediately before the op it would have failed."""
        if self.fires(site, ("transient",)) is not None:
            raise TransientOpError(f"injected transient failure at {site!r}")

    def poison(self, site: str, out):
        """Return ``out`` NaN/Inf-poisoned when a poison spec fires (the
        observable signature of a miscompiled/mis-tiled kernel). Traced
        values and None pass through untouched -- poison is host-level
        only, so compiled artifacts stay byte-identical."""
        if out is None or _is_tracer(out):
            return out
        spec = self.fires(site, ("nan", "inf"))
        if spec is None:
            return out
        import jax.numpy as jnp
        if not jnp.issubdtype(out.dtype, jnp.inexact):
            # Integer datapaths cannot hold NaN/Inf; saturate instead
            # (the closest observable analogue of a mis-tiled int kernel).
            return jnp.full_like(out, jnp.iinfo(out.dtype).max)
        bad = jnp.nan if spec.kind == "nan" else jnp.inf
        return jnp.full_like(out, bad)

    def straggle(self, site: str = "step") -> float:
        """Sleep when a straggler spec fires; returns the injected delay."""
        spec = self.fires(site, ("straggler",))
        if spec is None:
            return 0.0
        self.sleep(spec.delay_s)
        return spec.delay_s

    def arena_pressure(self, site: str = "arena") -> int:
        """Pages the caller should withhold from its allocator this step
        (see ``PagedKVAllocator.hold_pages``); 0 = no pressure."""
        spec = self.fires(site, ("arena",))
        return spec.pages if spec is not None else 0

    def ckpt_fails(self, site: str = "checkpoint") -> bool:
        """True when a checkpoint-write spec fires (the store raises
        OSError in its place)."""
        return self.fires(site, ("ckpt_io",)) is not None

    def offload_fails(self, site: str) -> bool:
        """True when a KV host-offload DMA spec fires at ``site`` (one of
        ``spill`` / ``restore``): the engine drops the copy and the
        scheduler degrades that victim to the classic recompute restart --
        offload is an optimization, never a correctness dependency."""
        return self.fires(site, ("offload_io",)) is not None

    # -- telemetry ---------------------------------------------------------
    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def report(self) -> Dict[str, int]:
        """Firing counts by ``kind@site`` (stable ordering for logs)."""
        return {k: int(v) for k, v in sorted(self.injected.items())}


def as_injector(obj: Union[None, str, FaultPlan, FaultInjector]
                ) -> Optional[FaultInjector]:
    """Normalize the engine's ``faults=`` kwarg: None consults
    ``$GEMMINI_FAULTS`` (usually: faults off), a spec string parses, a plan
    binds a fresh injector, an injector passes through."""
    if obj is None:
        plan = FaultPlan.from_env()
        return FaultInjector(plan) if plan is not None else None
    if isinstance(obj, FaultInjector):
        return obj
    if isinstance(obj, str):
        obj = FaultPlan.parse(obj)
    if isinstance(obj, FaultPlan):
        return FaultInjector(obj) if obj.specs else None
    raise TypeError(f"cannot derive a FaultInjector from {type(obj)!r}")


# ---------------------------------------------------------------------------
# process-global injector (the ExecutionContext / checkpoint hook)
# ---------------------------------------------------------------------------
_ACTIVE: Optional[FaultInjector] = None


def install(obj: Union[str, FaultPlan, FaultInjector]) -> FaultInjector:
    """Install a process-global injector: eager ExecutionContext op
    dispatch and ``save_checkpoint`` consult it. Returns the injector
    (callers keep it for telemetry). Pair with :func:`deactivate` --
    tests should use try/finally."""
    global _ACTIVE
    inj = as_injector(obj)
    if inj is None:
        raise ValueError("install() needs a non-empty fault plan")
    _ACTIVE = inj
    return inj


def deactivate() -> None:
    """Remove the process-global injector (faults off)."""
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[FaultInjector]:
    """The process-global injector, or None (the default: no faults)."""
    return _ACTIVE
