"""Fault tolerance: heartbeats, straggler detection, restart/elastic loop.

The paper's evaluation point is that *system-level* behaviour (not the
kernel) decides delivered performance; at 1000+-node scale the dominant
system-level events are node failure and stragglers. This module provides
the control-plane pieces the launcher composes:

* :class:`HeartbeatMonitor` -- tracks per-host liveness marks; ``dead()``
  after a timeout names the lost hosts (in a real deployment the marks come
  from the cluster agent; tests drive it with a fake clock).
* :class:`StragglerDetector` -- EWMA + variance of step times; a step whose
  z-score exceeds the threshold flags a straggler so the launcher can log,
  exclude, or re-shard around the slow host.
* :class:`StepWatchdog` -- the serving-loop composition of the two:
  per-step latency telemetry + straggler flags + liveness beats
  (``ServingEngine.run`` drives it once per iteration).
* :func:`run_with_restarts` -- the restart loop: run the training callable;
  on failure restore the latest committed checkpoint and re-enter, possibly
  on a *shrunk* mesh (elastic scaling: lose a pod -> continue on the
  remaining pod; the checkpoint layer reshards transparently).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


class HeartbeatMonitor:
    """Per-host liveness marks over an injectable clock.

    Membership is explicit: hosts are declared at construction (or via
    :meth:`register`), and a beat from an undeclared host raises by
    default. The silent-register alternative is a liveness hole -- a
    typo'd host id in the beat path would keep "h0-typo" alive forever
    while the real ``h0`` quietly times out and nothing names it dead.
    ``strict=False`` downgrades the raise to a flag: the beat is dropped
    (never counted as liveness) and the offender lands in
    ``unknown_beats`` for the launcher to log.
    """

    def __init__(self, hosts: List[str], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic,
                 strict: bool = True):
        self.timeout = timeout_s
        self.clock = clock
        self.strict = strict
        now = clock()
        self.last: Dict[str, float] = {h: now for h in hosts}
        self.unknown_beats: Dict[str, int] = {}

    def register(self, host: str) -> None:
        """Declare a new member host (its clock starts now)."""
        self.last[host] = self.clock()

    def beat(self, host: str):
        if host not in self.last:
            if self.strict:
                raise KeyError(
                    f"heartbeat from unknown host {host!r}; known hosts: "
                    f"{sorted(self.last)} (register() it first)")
            self.unknown_beats[host] = self.unknown_beats.get(host, 0) + 1
            return
        self.last[host] = self.clock()

    def dead(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last.items() if now - t > self.timeout]

    def alive(self) -> List[str]:
        d = set(self.dead())
        return [h for h in self.last if h not in d]


class StragglerDetector:
    """EWMA mean/variance of step times; flags z-score outliers.

    Warmup samples prime the statistics (no flags); afterwards mean/var
    follow an EWMA, with straggler steps weighted down 4x so one hiccup
    does not poison the baseline.
    """

    def __init__(self, alpha: float = 0.1, z_threshold: float = 3.0,
                 warmup: int = 5, min_rel_std: float = 0.02):
        self.alpha = alpha
        self.z = z_threshold
        self.warmup = warmup
        self.min_rel_std = min_rel_std   # jitter floor: ignore sub-2% noise
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self._warm: list = []

    def observe(self, dt: float) -> bool:
        """Record one step time; True if it is a straggler step."""
        self.n += 1
        if self.n <= self.warmup:
            self._warm.append(dt)
            if self.n == self.warmup:
                m = sum(self._warm) / len(self._warm)
                self.mean = m
                self.var = sum((x - m) ** 2 for x in self._warm) / \
                    len(self._warm)
            return False
        std = math.sqrt(max(self.var, 0.0))
        std = max(std, self.min_rel_std * self.mean)
        is_straggler = dt > self.mean + self.z * std
        a = self.alpha * (0.25 if is_straggler else 1.0)
        self.mean = (1 - a) * self.mean + a * dt
        self.var = (1 - a) * self.var + a * (dt - self.mean) ** 2
        return is_straggler


class StepWatchdog:
    """Serving-loop step watchdog: per-step latency telemetry plus the
    straggler/liveness machinery above, composed for the engine.

    ``ServingEngine.run`` calls :meth:`observe` once per iteration with
    the step's wall time: the detector flags straggler steps (injected or
    real), an optional :class:`HeartbeatMonitor` gets a beat for this
    host (so an external supervisor watching the monitor sees a wedged
    serving loop go dead), and :meth:`stats` folds p50/p95 step latency
    into the run summary.
    """

    def __init__(self, detector: Optional[StragglerDetector] = None,
                 monitor: Optional[HeartbeatMonitor] = None,
                 host: str = "serve"):
        self.detector = detector or StragglerDetector()
        self.monitor = monitor
        self.host = host
        if monitor is not None and host not in monitor.last:
            monitor.register(host)
        self.step_times: List[float] = []
        self.straggler_steps = 0

    def observe(self, dt: float) -> bool:
        """Record one engine step; True if it was flagged a straggler."""
        self.step_times.append(dt)
        if self.monitor is not None:
            self.monitor.beat(self.host)
        flagged = self.detector.observe(dt)
        if flagged:
            self.straggler_steps += 1
        return flagged

    def stats(self) -> Dict[str, float]:
        import numpy as np
        dts = np.asarray(self.step_times or [0.0])
        return {"straggler_steps": float(self.straggler_steps),
                "step_p50_s": float(np.percentile(dts, 50)),
                "step_p95_s": float(np.percentile(dts, 95))}


@dataclasses.dataclass
class RestartPolicy:
    max_failures: int = 3
    backoff_s: float = 0.0          # tests use 0
    allow_shrink: bool = True       # elastic: retry on a smaller mesh


def run_with_restarts(
    make_runner: Callable[[int, int], Callable[[], Any]],
    policy: RestartPolicy,
    *,
    n_pods: int = 2,
    on_failure: Optional[Callable[[int, BaseException], None]] = None,
) -> Tuple[Any, int, int]:
    """Run ``make_runner(attempt, pods)()`` with restart-on-failure.

    ``make_runner`` builds a fresh runner (re-mesh, restore checkpoint,
    re-jit) for each attempt; ``pods`` shrinks after a failure when the
    policy allows (elastic scaling). Returns (result, attempts, pods_used).
    """
    pods = n_pods
    for attempt in range(policy.max_failures + 1):
        try:
            runner = make_runner(attempt, pods)
            return runner(), attempt + 1, pods
        except (KeyboardInterrupt, SystemExit):
            raise
        except BaseException as e:  # noqa: B036 - restart loop by design
            if on_failure is not None:
                on_failure(attempt, e)
            if attempt == policy.max_failures:
                raise
            if policy.allow_shrink and pods > 1:
                pods -= 1            # drop the lost pod, keep training
            if policy.backoff_s:
                time.sleep(policy.backoff_s * (2 ** attempt))
    raise RuntimeError("unreachable")
