"""Fault tolerance: heartbeats, straggler detection, restart/elastic loop.

The paper's evaluation point is that *system-level* behaviour (not the
kernel) decides delivered performance; at 1000+-node scale the dominant
system-level events are node failure and stragglers. This module provides
the control-plane pieces the launcher composes:

* :class:`HeartbeatMonitor` -- tracks per-host liveness marks; ``dead()``
  after a timeout names the lost hosts (in a real deployment the marks come
  from the cluster agent; tests drive it with a fake clock).
* :class:`StragglerDetector` -- EWMA + variance of step times; a step whose
  z-score exceeds the threshold flags a straggler so the launcher can log,
  exclude, or re-shard around the slow host.
* :func:`run_with_restarts` -- the restart loop: run the training callable;
  on failure restore the latest committed checkpoint and re-enter, possibly
  on a *shrunk* mesh (elastic scaling: lose a pod -> continue on the
  remaining pod; the checkpoint layer reshards transparently).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


class HeartbeatMonitor:
    def __init__(self, hosts: List[str], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        now = clock()
        self.last: Dict[str, float] = {h: now for h in hosts}

    def beat(self, host: str):
        self.last[host] = self.clock()

    def dead(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last.items() if now - t > self.timeout]

    def alive(self) -> List[str]:
        d = set(self.dead())
        return [h for h in self.last if h not in d]


class StragglerDetector:
    """EWMA mean/variance of step times; flags z-score outliers.

    Warmup samples prime the statistics (no flags); afterwards mean/var
    follow an EWMA, with straggler steps weighted down 4x so one hiccup
    does not poison the baseline.
    """

    def __init__(self, alpha: float = 0.1, z_threshold: float = 3.0,
                 warmup: int = 5, min_rel_std: float = 0.02):
        self.alpha = alpha
        self.z = z_threshold
        self.warmup = warmup
        self.min_rel_std = min_rel_std   # jitter floor: ignore sub-2% noise
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self._warm: list = []

    def observe(self, dt: float) -> bool:
        """Record one step time; True if it is a straggler step."""
        self.n += 1
        if self.n <= self.warmup:
            self._warm.append(dt)
            if self.n == self.warmup:
                m = sum(self._warm) / len(self._warm)
                self.mean = m
                self.var = sum((x - m) ** 2 for x in self._warm) / \
                    len(self._warm)
            return False
        std = math.sqrt(max(self.var, 0.0))
        std = max(std, self.min_rel_std * self.mean)
        is_straggler = dt > self.mean + self.z * std
        a = self.alpha * (0.25 if is_straggler else 1.0)
        self.mean = (1 - a) * self.mean + a * dt
        self.var = (1 - a) * self.var + a * (dt - self.mean) ** 2
        return is_straggler


@dataclasses.dataclass
class RestartPolicy:
    max_failures: int = 3
    backoff_s: float = 0.0          # tests use 0
    allow_shrink: bool = True       # elastic: retry on a smaller mesh


def run_with_restarts(
    make_runner: Callable[[int, int], Callable[[], Any]],
    policy: RestartPolicy,
    *,
    n_pods: int = 2,
    on_failure: Optional[Callable[[int, BaseException], None]] = None,
) -> Tuple[Any, int, int]:
    """Run ``make_runner(attempt, pods)()`` with restart-on-failure.

    ``make_runner`` builds a fresh runner (re-mesh, restore checkpoint,
    re-jit) for each attempt; ``pods`` shrinks after a failure when the
    policy allows (elastic scaling). Returns (result, attempts, pods_used).
    """
    pods = n_pods
    for attempt in range(policy.max_failures + 1):
        try:
            runner = make_runner(attempt, pods)
            return runner(), attempt + 1, pods
        except (KeyboardInterrupt, SystemExit):
            raise
        except BaseException as e:  # noqa: B036 - restart loop by design
            if on_failure is not None:
                on_failure(attempt, e)
            if attempt == policy.max_failures:
                raise
            if policy.allow_shrink and pods > 1:
                pods -= 1            # drop the lost pod, keep training
            if policy.backoff_s:
                time.sleep(policy.backoff_s * (2 ** attempt))
    raise RuntimeError("unreachable")
