"""The paper's headline system-level finding (section 8):

  "although a Gemmini baseline design was able to accelerate the first
   layer of MobileNet by 330x, it failed to accelerate the entire network
   beyond 6x using a Rocket host processor and 18x using a BOOM host
   processor, due to the presence of depthwise convolutions."

This bench reproduces the structure of that finding: single-layer speedup
vs whole-network speedup on both host CPUs, plus ResNet-50/152 whole-network
speedups (the paper reports 70x / 90x).
"""

from __future__ import annotations

from repro.core import dse, isa
from repro.core.config import PAPER_DESIGN_POINTS

BASE = PAPER_DESIGN_POINTS[1]


def first_layer_speedup() -> float:
    """MobileNet's first (standard 3x3) conv in isolation -- the *engine*
    time only, matching the paper's per-layer measurement (im2col cost is
    amortized into the network-level runs, where it belongs)."""
    wl = dse.mobilenet_v1()
    g0 = wl.gemms[0]
    first = dse.Workload("mobilenet_l1",
                         (dse.GemmShape(m=g0.m, n=g0.n, k=g0.k),))
    cpu = 2.0 * g0.m * g0.n * g0.k
    r = dse.evaluate(BASE, first, isa.ROCKET)
    return cpu / r["engine_cycles"]


def network_speedup(wl: dse.Workload, sys: isa.SystemParams,
                    host: str) -> float:
    cpu = sum(2.0 * g.m * g.n * g.k * g.repeats for g in wl.gemms) + \
        wl.host_only_flops
    r = dse.evaluate(BASE, wl, sys, host=host)
    return cpu / r["total_cycles"]


def rows():
    mob = dse.mobilenet_v1()
    out = {
        "mobilenet_first_layer_speedup": first_layer_speedup(),
        "mobilenet_net_rocket": network_speedup(mob, isa.ROCKET, "rocket"),
        "mobilenet_net_boom": network_speedup(mob, isa.BOOM, "boom"),
        "resnet50_net_rocket": network_speedup(dse.resnet(50), isa.ROCKET,
                                               "rocket"),
        "resnet152_net_rocket": network_speedup(dse.resnet(152), isa.ROCKET,
                                                "rocket"),
    }
    # paper reference values for side-by-side comparison
    out["paper_values"] = dict(first_layer=330, net_rocket=6, net_boom=18,
                               resnet50=70, resnet152=90)
    return out


def main(csv=True):
    r = rows()
    if csv:
        print("# bench_system_amdahl: layer-vs-network speedups "
              "(paper section 8)")
        print("metric,ours,paper")
        p = r["paper_values"]
        print(f"mobilenet_first_layer,{r['mobilenet_first_layer_speedup']:.0f},"
              f"{p['first_layer']}")
        print(f"mobilenet_network_rocket,{r['mobilenet_net_rocket']:.1f},"
              f"{p['net_rocket']}")
        print(f"mobilenet_network_boom,{r['mobilenet_net_boom']:.1f},"
              f"{p['net_boom']}")
        print(f"resnet50_network,{r['resnet50_net_rocket']:.0f},"
              f"{p['resnet50']}")
        print(f"resnet152_network,{r['resnet152_net_rocket']:.0f},"
              f"{p['resnet152']}")
    return r


if __name__ == "__main__":
    main()
