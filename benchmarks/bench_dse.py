"""Table 1 / Figures 6-8: the full design-space exploration.

Evaluates all 10 design points on the paper's 7 workloads (MobileNet,
ResNet-50/152, MLP1-4) through the decoupled access/execute cycle model,
with the paper's efficiency proxies:

  performance       cycles (engine queues + host Amdahl term)
  energy proxy      HBM bytes moved (the paper: external memory access
                    dominates inference energy)
  area proxy        VMEM residency + streamed working set (scratchpad +
                    accumulator provisioning)

Rows mirror Fig 8: perf-per-energy vs perf-per-area per (point, workload).
"""

from __future__ import annotations

from repro.core import dse, isa


def cpu_cycles(wl: dse.Workload) -> float:
    """Cache-blocked CPU baseline: ~1 MAC/cycle + the host-only work."""
    return sum(2.0 * g.m * g.n * g.k * g.repeats for g in wl.gemms) + \
        wl.host_only_flops


def rows():
    workloads = dict(dse.PAPER_DNNS)
    workloads.update(dse.PAPER_MLPS)
    out = []
    for wname, wl in workloads.items():
        base_cpu = cpu_cycles(wl)
        for r in dse.run_design_points(wl):
            speedup = base_cpu / r.total_cycles
            perf_per_energy = 1.0 / (r.total_cycles * max(r.hbm_bytes, 1))
            perf_per_area = 1.0 / (r.total_cycles * max(r.vmem_bytes, 1))
            out.append(dict(
                workload=wname, point=r.point,
                cycles=r.total_cycles, speedup_vs_cpu=speedup,
                bottleneck=r.bottleneck,
                host_frac=r.host_cycles / r.total_cycles,
                hbm_bytes=r.hbm_bytes, vmem_bytes=r.vmem_bytes,
                utilization=r.utilization,
                perf_per_energy=perf_per_energy,
                perf_per_area=perf_per_area))
    return out


def main(csv=True):
    rs = rows()
    if csv:
        print("# bench_dse: Table-1 design points x paper workloads "
              "(paper-native scale)")
        print("workload,point,cycles,speedup_vs_cpu,bottleneck,host_frac,"
              "hbm_bytes,vmem_bytes,utilization")
        for r in rs:
            print(f"{r['workload']},{r['point']},{r['cycles']:.0f},"
                  f"{r['speedup_vs_cpu']:.1f},{r['bottleneck']},"
                  f"{r['host_frac']:.3f},{r['hbm_bytes']:.0f},"
                  f"{r['vmem_bytes']},{r['utilization']:.3f}")
    return rs


if __name__ == "__main__":
    main()
