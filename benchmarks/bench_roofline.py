"""Roofline table: aggregates results/dryrun/*.json into the EXPERIMENTS.md
section-Roofline table (one row per arch x shape x mesh x variant)."""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def _backfill_fraction(r):
    """Rows saved before min_bytes landed: recompute roofline_fraction with
    the memory-aware ideal (max of compute and inherent-bytes roofs)."""
    if "t_ideal" in r:
        return r
    from repro import configs
    from repro.analysis import roofline as rl
    from repro.launch.steps import SHAPES
    cfg = configs.get(r["arch"])
    info = SHAPES[r["shape"]]
    n_chips = 512 if r["mesh"] == "2x16x16" else 256
    mb = rl.model_min_bytes_for(cfg, info["kind"], info["batch"],
                                info["seq"])
    t_bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
    t_ideal = max(float(r["model_flops"]) / n_chips / rl.PEAK_FLOPS_BF16,
                  mb / n_chips / rl.HBM_BW)
    r["min_bytes"] = mb
    r["t_ideal"] = t_ideal
    r["roofline_fraction"] = t_ideal / t_bound if t_bound else 0.0
    return r


def rows(variant=None):
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if variant and r.get("variant") != variant:
            continue
        out.append(_backfill_fraction(r))
    return out


def fmt_ms(s):
    return f"{float(s) * 1e3:.1f}"


def main(csv=True):
    rs = rows()
    if csv:
        print("# bench_roofline: dry-run roofline terms per cell")
        print("variant,arch,shape,mesh,kind,t_compute_ms,t_memory_ms,"
              "t_collective_ms,bottleneck,useful_ratio,roofline_fraction,"
              "hbm_gb_per_dev")
        for r in rs:
            print(f"{r.get('variant','baseline')},{r['arch']},{r['shape']},"
                  f"{r['mesh']},{r.get('kind','?')},"
                  f"{fmt_ms(r['t_compute'])},{fmt_ms(r['t_memory'])},"
                  f"{fmt_ms(r['t_collective'])},{r['bottleneck']},"
                  f"{float(r['useful_ratio']):.3f},"
                  f"{float(r['roofline_fraction']):.4f},"
                  f"{float(r['per_device_hbm'])/1e9:.2f}")
    return rs


if __name__ == "__main__":
    main()
