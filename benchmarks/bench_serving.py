"""Serving benchmark: scheduling policy A/Bs on request traces.

The system-level experiments the paper's full-stack argument calls for:
the same model, the same kernels, the same paged cache -- only the
*scheduling policy* differs.

1. **static vs continuous** on a mixed trace: static batching (admission
   barrier, no slot recycling) pays the group-max decode depth per batch
   while continuous batching recycles slots the moment a request
   finishes; tokens/s and per-request latency quantify the gap.
2. **single-pass vs chunked prefill** on a long-prompt mixed trace
   (continuous policy both sides): single-pass admission stalls every
   running decode for one whole-prompt prefill, so inter-token latency
   (ITL) p95 spikes whenever a long prompt lands; chunked prefill splits
   the same prompts into page-sized chunks interleaved with decode steps,
   bounding the stall while total throughput stays flat.

``benchmarks/run.py --smoke`` writes the rows to BENCH_serving.json (a
per-run CI artifact alongside BENCH_kernels.json); chart the accumulated
trajectory with ``benchmarks/plot_trend.py``.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

ARCH = "gemma3-1b"
# (prompt_len, gen_len) mix: short/long prompts, shallow/deep generations.
TRACE = [(9, 12), (17, 4), (5, 16), (13, 8), (21, 3), (7, 14),
         (11, 6), (15, 10)]
MAX_SLOTS = 4
PAGE_SIZE = 16
MAX_CONTEXT = 64
N_PAGES = 32

# Long-prompt mixed trace for the chunked-prefill A/B. Trace geometry is
# load-bearing for both acceptance metrics:
#
# * Prompts are long enough (768-1024 tokens) that attention COMPUTE
#   dominates per-call dispatch overhead -- at smoke-prompt lengths a
#   chunk call costs the same as a whole prefill and chunking only adds
#   calls. Past that point the chunked pass is wall-neutral-or-better on
#   prefill itself: a continuation chunk attends only the prefix, so the
#   chunked arm does FEWER total score MACs than the full T^2 pass,
#   which pays for its extra dispatches (tokens/s within noise).
# * Shorts submit first so all three observers are mid-decode when every
#   long prompt admits: each single-pass admission lands its full stall
#   on three concurrent token streams, keeping the stall population deep
#   enough that the pooled ITL p95 sits squarely inside the stalls, not
#   the decode-gap bulk.
TRACE_LONG = [(6, 24), (8, 24), (5, 24), (1024, 10), (896, 10), (768, 10)]
# Shared-prefix trace for the KV-lifecycle A/B (ISSUE 9): N requests share
# one 96-token system prompt (6 full pages at PAGE_SIZE=16) over 2 slots,
# so admissions stagger and every later request sees the published prefix.
# Geometry is eviction-tight on purpose, and the tightness is at DECODE
# time: both slots can fully prefill (2 x 7 pages = 14 = arena), but each
# decode stream must cross the next page boundary (104 + 12 - 1 = 115
# tokens -> 8 pages), so the first slot to cross finds zero free pages and
# evicts its peer. (Mid-PREFILL pressure would not preempt: the scheduler
# stalls the younger prefill head-of-line instead, serializing the trace.)
# The baseline arm recomputes the victims; the kv-offload arm restores
# them from the host pool (restored > 0); the prefix-cache arm's CoW
# sharing relieves the pressure AND skips the shared chunks
# (prefix_hit_rate > 0).
PREFIX_TRACE_N = 6
PREFIX_SHARED = 96
PREFIX_TAIL = 8
PREFIX_GEN = 12
PREFIX_SLOTS = 2
PREFIX_MAX_CONTEXT = 128
PREFIX_N_PAGES = 14
PREFIX_CHUNK = 2 * PAGE_SIZE       # page-aligned chunks: hits are per-page
PREFILL_CHUNK = 512                # 32 pages per chunk
LONG_MAX_CONTEXT = 1088
LONG_N_PAGES = 272                 # slots * max_pages: no eviction noise
LONG_BUDGET = PREFILL_CHUNK + 32   # one chunk per iteration, with headroom
                                   # so a short prompt can still admit in
                                   # the same iteration as a continuation
                                   # chunk (single-pass admissions already
                                   # overshoot the budget via the
                                   # first-always-lands rule, so this only
                                   # levels the admission latency)


_PARAMS = None


def _shared_params(model_cfg):
    """One parameter init shared by every engine construction: the weights
    are identical either way (same seed), and re-initializing them 8x per
    benchmark would be pure startup waste."""
    global _PARAMS
    if _PARAMS is None:
        import jax

        from repro.models import transformer as tf
        _PARAMS = tf.init_params(jax.random.PRNGKey(1), model_cfg)
    return _PARAMS


def _run_policy(policy: str) -> Dict:
    from repro import configs
    from repro.serving import ServingEngine
    model_cfg = configs.get_smoke(ARCH)
    rng = np.random.default_rng(0)
    engine = ServingEngine(model_cfg, max_slots=MAX_SLOTS,
                           max_context=MAX_CONTEXT, page_size=PAGE_SIZE,
                           n_pages=N_PAGES, temperature=0.0, seed=0,
                           policy=policy, params=_shared_params(model_cfg))
    for plen, glen in TRACE:
        engine.submit(rng.integers(0, model_cfg.vocab, (plen,),
                                   dtype=np.int32), glen)
    return engine.run()


def _run_long_trace(prefill_chunk: Optional[int]) -> Dict:
    """The long-prompt trace under one prefill mode (None = single-pass).
    Same engine geometry, same budget, same trace -- only the chunking
    knob differs."""
    from repro import configs
    from repro.serving import ServingEngine
    model_cfg = configs.get_smoke(ARCH)
    rng = np.random.default_rng(1)
    engine = ServingEngine(model_cfg, max_slots=MAX_SLOTS,
                           max_context=LONG_MAX_CONTEXT, page_size=PAGE_SIZE,
                           n_pages=LONG_N_PAGES, temperature=0.0, seed=0,
                           prefill_token_budget=LONG_BUDGET,
                           prefill_chunk=prefill_chunk,
                           params=_shared_params(model_cfg))
    for plen, glen in TRACE_LONG:
        engine.submit(rng.integers(0, model_cfg.vocab, (plen,),
                                   dtype=np.int32), glen)
    return engine.run()


def _run_prefix_trace(*, kv_offload: bool = False,
                      prefix_cache: bool = False) -> Dict:
    """The shared-prefix trace under one lifecycle configuration. Counters
    (prefill tokens, hits, spills, restores) are deterministic per config;
    only the wall-clock side is host-noisy."""
    from repro import configs
    from repro.serving import ServingEngine
    model_cfg = configs.get_smoke(ARCH)
    rng = np.random.default_rng(2)
    sys_prompt = rng.integers(0, model_cfg.vocab, (PREFIX_SHARED,),
                              dtype=np.int32)
    engine = ServingEngine(model_cfg, max_slots=PREFIX_SLOTS,
                           max_context=PREFIX_MAX_CONTEXT,
                           page_size=PAGE_SIZE, n_pages=PREFIX_N_PAGES,
                           temperature=0.0, seed=0,
                           prefill_chunk=PREFIX_CHUNK,
                           kv_offload=kv_offload, prefix_cache=prefix_cache,
                           params=_shared_params(model_cfg))
    for _ in range(PREFIX_TRACE_N):
        tail = rng.integers(0, model_cfg.vocab, (PREFIX_TAIL,),
                            dtype=np.int32)
        engine.submit(np.concatenate([sys_prompt, tail]), PREFIX_GEN)
    return engine.run()


def main(csv: bool = True, repeats: int = 3) -> List[Dict]:
    rows: List[Dict] = []
    summaries = {}
    for policy in ("static", "continuous"):
        # Warm-up run first: jit compilation must not be charged to either
        # policy (both share the same prefill buckets + decode step via the
        # engine's cross-instance jit cache). Then best-of-``repeats``
        # traces: shared CI hosts are noisy at the tens-of-ms level, and
        # min-wall is the same noise-robust statistic the kernel tuner
        # ranks by.
        _run_policy(policy)
        s = max((_run_policy(policy)["summary"] for _ in range(repeats)),
                key=lambda s: s["tokens_per_s"])
        summaries[policy] = s
        rows.append(dict(
            name=f"serving_{policy}_{ARCH}",
            policy=policy, arch=ARCH, requests=int(s["requests"]),
            new_tokens=int(s["new_tokens"]),
            tokens_per_s=s["tokens_per_s"],
            iterations=int(s["iterations"]),
            p50_latency_s=s["p50_latency_s"],
            p99_latency_s=s["p99_latency_s"],
            p50_ttft_s=s["p50_ttft_s"], p99_ttft_s=s["p99_ttft_s"],
            # Robustness counters (identically 0 on these fault-free
            # traces -- the trend chart alarms if a regression makes the
            # engine retry/fall back/shed on the happy path).
            preemptions=int(s["preemptions"]),
            retries=int(s["retries"]), fallbacks=int(s["fallbacks"]),
            shed=int(s["shed"]),
            straggler_steps=int(s["straggler_steps"]),
            # Registry-backed per-step gauge peaks (repro.obs.metrics):
            # memory-pressure / load shape of the run, charted alongside
            # throughput so a scheduling regression that trades tokens/s
            # for arena headroom is visible as such.
            arena_used_pages_peak=int(s.get("arena_used_pages_peak", 0)),
            live_slots_peak=int(s.get("live_slots_peak", 0)),
            queue_depth_peak=int(s.get("queue_depth_peak", 0)),
            slots=MAX_SLOTS, page_size=PAGE_SIZE))
    speedup = (summaries["continuous"]["tokens_per_s"]
               / max(summaries["static"]["tokens_per_s"], 1e-9))
    # The host-independent version of the same claim: iterations for the
    # same token count (static pays the group-max decode depth per batch).
    iter_ratio = (summaries["static"]["iterations"]
                  / max(summaries["continuous"]["iterations"], 1.0))
    rows.append(dict(name="serving_continuous_vs_static", policy="ratio",
                     arch=ARCH, tokens_per_s_speedup=speedup,
                     iteration_ratio=iter_ratio))

    # -- long-prompt trace: single-pass vs chunked prefill ----------------
    # Interleave the two arms (A B A B ...) so a host-load burst hits both
    # rather than biasing whichever arm ran during it, then take per-metric
    # noise floors (min-wall spirit): best tokens/s and best ITL tail
    # across repeats -- shared CI hosts jitter at the ms level, and one
    # stolen timeslice must not flip the A/B.
    arms = (("singlepass", None), ("chunked", PREFILL_CHUNK))
    for _mode, chunk in arms:
        _run_long_trace(chunk)            # warm-up: compile off the clock
    long_runs: Dict[str, List[Dict]] = {m: [] for m, _ in arms}
    for _ in range(max(repeats, 7)):
        for mode, chunk in arms:
            long_runs[mode].append(_run_long_trace(chunk)["summary"])
    long_best: Dict[str, Dict] = {}
    for mode, chunk in arms:
        runs = long_runs[mode]
        s = max(runs, key=lambda s: s["tokens_per_s"]).copy()
        s["p95_itl_s"] = min(r["p95_itl_s"] for r in runs)
        s["p50_itl_s"] = min(r["p50_itl_s"] for r in runs)
        long_best[mode] = s
        rows.append(dict(
            name=f"serving_longtrace_{mode}_{ARCH}",
            policy=mode, arch=ARCH, requests=int(s["requests"]),
            new_tokens=int(s["new_tokens"]),
            tokens_per_s=s["tokens_per_s"],
            iterations=int(s["iterations"]),
            p50_itl_s=s["p50_itl_s"], p95_itl_s=s["p95_itl_s"],
            p50_ttft_s=s["p50_ttft_s"], p99_ttft_s=s["p99_ttft_s"],
            prefill_chunks=int(s["prefill_chunks"]),
            preemptions=int(s["preemptions"]),
            retries=int(s["retries"]), fallbacks=int(s["fallbacks"]),
            shed=int(s["shed"]),
            prefill_chunk=chunk or 0, prefill_budget=LONG_BUDGET,
            arena_used_pages_peak=int(s.get("arena_used_pages_peak", 0)),
            live_slots_peak=int(s.get("live_slots_peak", 0)),
            queue_depth_peak=int(s.get("queue_depth_peak", 0)),
            slots=MAX_SLOTS, page_size=PAGE_SIZE))
    # Ratios of per-arm NOISE FLOORS (the long_best rows above): each arm
    # takes its best tokens/s and best ITL tail across >=5 interleaved
    # repeats, i.e. its own quietest host window -- the min-wall statistic
    # this repo's tuner and kernel benches already rank by. This is NOT a
    # max over per-pair ratios (which would systematically select the one
    # round where a load burst hit only the single-pass arm): both arms
    # get an independent quiet-window estimate, so a structural regression
    # in either metric still shows -- host bursts, which on shared CI
    # hosts dwarf the structural deltas, do not.
    itl_ratio = (long_best["singlepass"]["p95_itl_s"]
                 / max(long_best["chunked"]["p95_itl_s"], 1e-9))
    tps_ratio = (long_best["chunked"]["tokens_per_s"]
                 / max(long_best["singlepass"]["tokens_per_s"], 1e-9))
    rows.append(dict(name="serving_chunked_vs_singlepass", policy="ratio",
                     arch=ARCH, itl_p95_improvement=itl_ratio,
                     tokens_per_s_ratio=tps_ratio))

    # -- shared-prefix trace: KV-lifecycle A/B ----------------------------
    # Three arms on the same eviction-tight trace. Lifecycle counters are
    # deterministic, so one warm-up + best-of-repeats (tokens/s only, like
    # the policy A/B) is enough; the acceptance-grade claims -- bit-exact
    # tokens, exact hit accounting -- live in tests/test_kv_lifecycle.py,
    # the bench charts the RATES so a scheduler change that quietly stops
    # hitting the cache (or stops restoring) shows in the trend.
    prefix_arms = (("baseline", dict()),
                   ("prefix_cache", dict(prefix_cache=True)),
                   ("kv_offload", dict(kv_offload=True)))
    prefix_best: Dict[str, Dict] = {}
    for mode, kw in prefix_arms:
        _run_prefix_trace(**kw)           # warm-up: compile off the clock
        s = max((_run_prefix_trace(**kw)["summary"]
                 for _ in range(repeats)),
                key=lambda s: s["tokens_per_s"])
        prefix_best[mode] = s
        hit = int(s["prefix_hit_tokens"])
        computed = int(s["prefill_tokens"])
        rows.append(dict(
            name=f"serving_sharedprefix_{mode}_{ARCH}",
            policy=mode, arch=ARCH, requests=int(s["requests"]),
            new_tokens=int(s["new_tokens"]),
            tokens_per_s=s["tokens_per_s"],
            prefill_tokens=computed, prefix_hit_tokens=hit,
            prefix_hit_rate=hit / max(hit + computed, 1),
            offload_spills=int(s["offload_spills"]),
            offload_restores=int(s["offload_restores"]),
            restarts_restored=int(s["restarts_restored"]),
            restarts_recomputed=int(s["restarts_recomputed"]),
            preemptions=int(s["preemptions"]),
            arena_used_pages_peak=int(s.get("arena_used_pages_peak", 0)),
            slots=PREFIX_SLOTS, page_size=PAGE_SIZE,
            n_pages=PREFIX_N_PAGES))

    if csv:
        print("# bench_serving: one mixed prefill/decode trace, two "
              "scheduling policies (same kernels, same paged cache)")
        print("name,tokens_per_s,iterations,p50_latency_s,p99_latency_s,"
              "preemptions")
        for r in rows[:2]:
            print(f"{r['name']},{r['tokens_per_s']:.1f},{r['iterations']},"
                  f"{r['p50_latency_s']:.3f},{r['p99_latency_s']:.3f},"
                  f"{r['preemptions']}")
        print(f"# continuous vs static: {speedup:.2f}x tokens/s, "
              f"{iter_ratio:.2f}x fewer engine iterations")
        print("# long-prompt trace (chunked-prefill A/B)")
        print("name,tokens_per_s,p50_itl_s,p95_itl_s,prefill_chunks")
        for m in ("singlepass", "chunked"):
            s = long_best[m]
            print(f"serving_longtrace_{m}_{ARCH},{s['tokens_per_s']:.1f},"
                  f"{s['p50_itl_s']:.4f},{s['p95_itl_s']:.4f},"
                  f"{int(s['prefill_chunks'])}")
        print(f"# chunked vs single-pass: {itl_ratio:.2f}x lower ITL p95, "
              f"{tps_ratio:.2f}x tokens/s")
        print("# shared-prefix trace (KV-lifecycle A/B)")
        print("name,prefill_tokens,prefix_hit_rate,restored,recomputed,"
              "preemptions")
        for m, _ in prefix_arms:
            s = prefix_best[m]
            hit = int(s["prefix_hit_tokens"])
            computed = int(s["prefill_tokens"])
            print(f"serving_sharedprefix_{m}_{ARCH},{computed},"
                  f"{hit / max(hit + computed, 1):.3f},"
                  f"{int(s['restarts_restored'])},"
                  f"{int(s['restarts_recomputed'])},"
                  f"{int(s['preemptions'])}")
    return rows


if __name__ == "__main__":
    main()
