"""Serving benchmark: static batching vs continuous batching on one trace.

The system-level experiment the paper's full-stack argument calls for: the
same model, the same kernels, the same paged cache -- only the *scheduling
policy* differs. The trace mixes prompt and generation lengths, so static
batching (admission barrier, no slot recycling) pays the group-max decode
depth per batch while continuous batching recycles slots the moment a
request finishes; tokens/s and per-request latency quantify the gap.

``benchmarks/run.py --smoke`` writes the rows to BENCH_serving.json (a
per-run CI artifact alongside BENCH_kernels.json); chart the accumulated
trajectory with ``benchmarks/plot_trend.py``.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

ARCH = "gemma3-1b"
# (prompt_len, gen_len) mix: short/long prompts, shallow/deep generations.
TRACE = [(9, 12), (17, 4), (5, 16), (13, 8), (21, 3), (7, 14),
         (11, 6), (15, 10)]
MAX_SLOTS = 4
PAGE_SIZE = 16
MAX_CONTEXT = 64
N_PAGES = 32


_PARAMS = None


def _shared_params(model_cfg):
    """One parameter init shared by every engine construction: the weights
    are identical either way (same seed), and re-initializing them 8x per
    benchmark would be pure startup waste."""
    global _PARAMS
    if _PARAMS is None:
        import jax

        from repro.models import transformer as tf
        _PARAMS = tf.init_params(jax.random.PRNGKey(1), model_cfg)
    return _PARAMS


def _run_policy(policy: str) -> Dict:
    from repro import configs
    from repro.serving import ServingEngine
    model_cfg = configs.get_smoke(ARCH)
    rng = np.random.default_rng(0)
    engine = ServingEngine(model_cfg, max_slots=MAX_SLOTS,
                           max_context=MAX_CONTEXT, page_size=PAGE_SIZE,
                           n_pages=N_PAGES, temperature=0.0, seed=0,
                           policy=policy, params=_shared_params(model_cfg))
    for plen, glen in TRACE:
        engine.submit(rng.integers(0, model_cfg.vocab, (plen,),
                                   dtype=np.int32), glen)
    return engine.run()


def main(csv: bool = True, repeats: int = 3) -> List[Dict]:
    rows: List[Dict] = []
    summaries = {}
    for policy in ("static", "continuous"):
        # Warm-up run first: jit compilation must not be charged to either
        # policy (both share the same prefill buckets + decode step via the
        # engine's cross-instance jit cache). Then best-of-``repeats``
        # traces: shared CI hosts are noisy at the tens-of-ms level, and
        # min-wall is the same noise-robust statistic the kernel tuner
        # ranks by.
        _run_policy(policy)
        s = max((_run_policy(policy)["summary"] for _ in range(repeats)),
                key=lambda s: s["tokens_per_s"])
        summaries[policy] = s
        rows.append(dict(
            name=f"serving_{policy}_{ARCH}",
            policy=policy, arch=ARCH, requests=int(s["requests"]),
            new_tokens=int(s["new_tokens"]),
            tokens_per_s=s["tokens_per_s"],
            iterations=int(s["iterations"]),
            p50_latency_s=s["p50_latency_s"],
            p99_latency_s=s["p99_latency_s"],
            p50_ttft_s=s["p50_ttft_s"], p99_ttft_s=s["p99_ttft_s"],
            preemptions=int(s["preemptions"]),
            slots=MAX_SLOTS, page_size=PAGE_SIZE))
    speedup = (summaries["continuous"]["tokens_per_s"]
               / max(summaries["static"]["tokens_per_s"], 1e-9))
    # The host-independent version of the same claim: iterations for the
    # same token count (static pays the group-max decode depth per batch).
    iter_ratio = (summaries["static"]["iterations"]
                  / max(summaries["continuous"]["iterations"], 1.0))
    rows.append(dict(name="serving_continuous_vs_static", policy="ratio",
                     arch=ARCH, tokens_per_s_speedup=speedup,
                     iteration_ratio=iter_ratio))
    if csv:
        print("# bench_serving: one mixed prefill/decode trace, two "
              "scheduling policies (same kernels, same paged cache)")
        print("name,tokens_per_s,iterations,p50_latency_s,p99_latency_s,"
              "preemptions")
        for r in rows[:2]:
            print(f"{r['name']},{r['tokens_per_s']:.1f},{r['iterations']},"
                  f"{r['p50_latency_s']:.3f},{r['p99_latency_s']:.3f},"
                  f"{r['preemptions']}")
        print(f"# continuous vs static: {speedup:.2f}x tokens/s, "
              f"{iter_ratio:.2f}x fewer engine iterations")
    return rows


if __name__ == "__main__":
    main()
