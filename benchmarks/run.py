"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: kernels only,
                                                     # emits BENCH_kernels.json

The smoke kernel section covers all three tuned kernel classes -- GEMM,
one attention shape, one conv shape -- so the per-run BENCH_kernels.json
artifact (uploaded by CI per run) tracks the whole perf trajectory.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time


def _emit_json(rows, path: str) -> None:
    payload = {
        "schema": 1,
        "host": platform.node(),
        "platform": platform.platform(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True, default=str)
    print(f"# wrote {path} ({len(rows)} rows)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="kernel section only; write BENCH_kernels.json")
    ap.add_argument("--json-out", default="BENCH_kernels.json",
                    help="where --smoke writes the kernel rows")
    args = ap.parse_args(argv)

    from benchmarks import (bench_dse, bench_kernels, bench_roofline,
                            bench_system_amdahl, bench_tiling)
    t0 = time.time()
    if args.smoke:
        print("\n===== Kernel micro-benchmarks (smoke) =====")
        rows = bench_kernels.main()
        _emit_json(rows, args.json_out)
        print(f"\n# smoke benchmarks done in {time.time() - t0:.1f}s")
        return

    sections = [
        ("DSE (Table 1 / Figs 6-8)", bench_dse.main),
        ("System Amdahl (section 8 finding)", bench_system_amdahl.main),
        ("Tiling fit (Fig 7b) + scratchpad sweep", bench_tiling.main),
        ("Kernel micro-benchmarks", bench_kernels.main),
        ("Roofline table (dry-run artifacts)", bench_roofline.main),
    ]
    rows = None
    for title, fn in sections:
        print(f"\n===== {title} =====")
        try:
            out = fn()
        except Exception as e:  # noqa
            print(f"SECTION FAILED: {e!r}", file=sys.stderr)
            raise
        if fn is bench_kernels.main:
            rows = out
    if rows is not None:
        _emit_json(rows, args.json_out)
    print(f"\n# all benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
