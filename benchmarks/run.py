"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: kernels +
                                                     # serving; emits
                                                     # BENCH_kernels.json
                                                     # + BENCH_serving.json

The smoke sections cover all four tuned kernel classes (GEMM, attention,
conv, paged attention via the serving engine) plus the static-vs-continuous
scheduling comparison, so the per-run BENCH_*.json artifacts (uploaded by
CI per run, charted by benchmarks/plot_trend.py) track the whole perf
trajectory.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time


def _emit_json(rows, path: str) -> None:
    payload = {
        "schema": 1,
        "host": platform.node(),
        "platform": platform.platform(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True, default=str)
    print(f"# wrote {path} ({len(rows)} rows)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="kernel + serving sections only; write "
                         "BENCH_kernels.json and BENCH_serving.json")
    ap.add_argument("--json-out", default="BENCH_kernels.json",
                    help="where --smoke writes the kernel rows")
    ap.add_argument("--serving-json-out", default="BENCH_serving.json",
                    help="where --smoke writes the serving rows")
    args = ap.parse_args(argv)

    from benchmarks import (bench_dse, bench_kernels, bench_roofline,
                            bench_serving, bench_system_amdahl, bench_tiling)
    t0 = time.time()
    if args.smoke:
        print("\n===== Kernel micro-benchmarks (smoke) =====")
        rows = bench_kernels.main()
        _emit_json(rows, args.json_out)
        print("\n===== Serving: static vs continuous batching (smoke) =====")
        srows = bench_serving.main()
        _emit_json(srows, args.serving_json_out)
        print(f"\n# smoke benchmarks done in {time.time() - t0:.1f}s")
        return

    sections = [
        ("DSE (Table 1 / Figs 6-8)", bench_dse.main),
        ("System Amdahl (section 8 finding)", bench_system_amdahl.main),
        ("Tiling fit (Fig 7b) + scratchpad sweep", bench_tiling.main),
        ("Kernel micro-benchmarks", bench_kernels.main),
        ("Serving: static vs continuous batching", bench_serving.main),
        ("Roofline table (dry-run artifacts)", bench_roofline.main),
    ]
    rows = srows = None
    for title, fn in sections:
        print(f"\n===== {title} =====")
        try:
            out = fn()
        except Exception as e:  # noqa
            print(f"SECTION FAILED: {e!r}", file=sys.stderr)
            raise
        if fn is bench_kernels.main:
            rows = out
        elif fn is bench_serving.main:
            srows = out
    if rows is not None:
        _emit_json(rows, args.json_out)
    if srows is not None:
        _emit_json(srows, args.serving_json_out)
    print(f"\n# all benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
