"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_dse, bench_kernels, bench_roofline,
                            bench_system_amdahl, bench_tiling)
    t0 = time.time()
    sections = [
        ("DSE (Table 1 / Figs 6-8)", bench_dse.main),
        ("System Amdahl (section 8 finding)", bench_system_amdahl.main),
        ("Tiling fit (Fig 7b) + scratchpad sweep", bench_tiling.main),
        ("Kernel micro-benchmarks", bench_kernels.main),
        ("Roofline table (dry-run artifacts)", bench_roofline.main),
    ]
    for title, fn in sections:
        print(f"\n===== {title} =====")
        try:
            fn()
        except Exception as e:  # noqa
            print(f"SECTION FAILED: {e!r}", file=sys.stderr)
            raise
    print(f"\n# all benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
