"""Chart the perf trajectory from accumulated BENCH_*.json CI artifacts.

Every CI run uploads BENCH_kernels.json and BENCH_serving.json named by
run number; download a set of them into a directory and point this tool at
it to see how the tracked metrics moved across runs (the ROADMAP
"plot the perf trajectory" item):

  python benchmarks/plot_trend.py artifacts/ --metric tokens_per_s
  python benchmarks/plot_trend.py artifacts/                 # all metrics

Renders terminal-friendly sparkline tables (no display needed on CI); if
matplotlib is importable and ``--png OUT`` is given, also writes a chart.
Files are ordered by their embedded timestamp, falling back to filename.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Tuple

# (row-name field, metric field) pairs worth tracking across runs.
_TRACKED = ("us", "us_min", "tuned_us", "greedy_us", "speedup",
            "tokens_per_s", "p50_latency_s", "p99_latency_s",
            "tokens_per_s_speedup")
_SPARK = "▁▂▃▄▅▆▇█"


def _run_key(filename: str) -> str:
    """Collapse one CI run's artifact pair to a single run id: CI uploads
    BENCH_kernels*.json AND BENCH_serving*.json per run, so the kind prefix
    is stripped and the remainder (run number / sha / nothing) groups
    them. Without this, every series would show a hole at the other
    kind's file positions and '# runs' would double-count."""
    base = os.path.basename(filename)
    for kind in ("BENCH_kernels", "BENCH_serving"):
        if base.startswith(kind):
            return base[len(kind):] or base
    return base


def load_runs(paths: List[str]) -> List[Tuple[str, Dict]]:
    """[(label, payload)] ordered by payload timestamp then label, with
    same-run artifact files merged (rows concatenated)."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            files.extend(sorted(glob.glob(os.path.join(p, "BENCH_*.json"))))
        else:
            files.extend(sorted(glob.glob(p)))
    merged: Dict[str, Dict] = {}
    for f in files:
        try:
            with open(f) as fh:
                payload = json.load(fh)
        except (OSError, ValueError):
            continue
        key = _run_key(f)
        if key in merged:
            merged[key]["rows"] = (merged[key].get("rows", [])
                                   + payload.get("rows", []))
            merged[key]["timestamp"] = min(
                merged[key].get("timestamp", ""),
                payload.get("timestamp", "")) or \
                payload.get("timestamp", "")
        else:
            merged[key] = dict(payload)
    runs = list(merged.items())
    runs.sort(key=lambda r: (r[1].get("timestamp", ""), r[0]))
    return runs


def series(runs: List[Tuple[str, Dict]],
           metric_filter: str = "") -> Dict[str, List[float]]:
    """{row_name.metric: [value per run]} (None-padded for missing runs)."""
    out: Dict[str, List[float]] = {}
    for i, (_, payload) in enumerate(runs):
        for row in payload.get("rows", []):
            name = row.get("name", "?")
            for metric in _TRACKED:
                if metric not in row:
                    continue
                if metric_filter and metric != metric_filter:
                    continue
                key = f"{name}.{metric}"
                col = out.setdefault(key, [None] * len(runs))
                col[i] = float(row[metric])
    return out


def sparkline(vals: List[float]) -> str:
    xs = [v for v in vals if v is not None]
    if not xs:
        return ""
    lo, hi = min(xs), max(xs)
    rng = (hi - lo) or 1.0
    return "".join(" " if v is None else
                   _SPARK[int((v - lo) / rng * (len(_SPARK) - 1))]
                   for v in vals)


def render(runs, metric_filter: str = "") -> List[str]:
    cols = series(runs, metric_filter)
    lines = [f"# {len(runs)} runs: {runs[0][0]} .. {runs[-1][0]}"] \
        if runs else ["# no BENCH_*.json runs found"]
    for key in sorted(cols):
        vals = cols[key]
        xs = [v for v in vals if v is not None]
        if len(xs) < 1:
            continue
        first, last = xs[0], xs[-1]
        delta = (last - first) / first * 100 if first else 0.0
        lines.append(f"{key:<48} {sparkline(vals)}  "
                     f"{first:.3g} -> {last:.3g} ({delta:+.1f}%)")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+",
                    help="directories or globs of BENCH_*.json artifacts")
    ap.add_argument("--metric", default="",
                    help="only this metric (e.g. tokens_per_s)")
    ap.add_argument("--png", default="",
                    help="also write a matplotlib chart here (optional)")
    args = ap.parse_args(argv)
    runs = load_runs(args.paths)
    for line in render(runs, args.metric):
        print(line)
    if args.png and runs:
        try:
            import matplotlib
            matplotlib.use("Agg")
            import matplotlib.pyplot as plt
        except ImportError:
            print("# matplotlib not available; skipped --png")
            return 0
        cols = series(runs, args.metric)
        fig, ax = plt.subplots(figsize=(10, 6))
        for key, vals in sorted(cols.items()):
            ax.plot(range(len(vals)), vals, marker="o", label=key)
        ax.set_xlabel("run")
        ax.legend(fontsize=6)
        fig.savefig(args.png, dpi=120, bbox_inches="tight")
        print(f"# wrote {args.png}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
