"""Kernel micro-benchmarks: wall time of the XLA paths on this host +
static schedule quality (VMEM footprint / arithmetic intensity) of the
Pallas plans for the TPU target, plus the tuned-vs-static schedule
comparison for all three tuned kernel classes (GEMM, attention, conv) so
the per-run BENCH_kernels.json artifact tracks the whole perf trajectory.

On this CPU-only container the wall times are indicative (XLA:CPU), but
the derived columns -- tile shapes, VMEM working set, arithmetic intensity
-- are the TPU-relevant outputs of the generator, independent of host.

Timing discipline: ``repro.tune.measure.time_callable`` syncs every
iteration (the old local ``_time`` only synced the last dispatch, so it
measured enqueue rate, not execution) and reports min-of-iters alongside
the mean.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import Dataflow, GemminiConfig
from repro.core.tiling import plan_gemm
from repro.core.context import ExecutionContext
from repro.tune import measure as tmeasure

# The serving-shaped GEMM the tuner targets: skinny M, wide N (a 128-token
# decode batch against a 4096-wide projection) -- where greedy analytic
# tiling is furthest from optimal.
SERVING_SHAPE = (128, 4096, 1024)
# One attention and one conv shape for the tuned-schedule trajectory:
# a 1k-token prefill (b, tq, tk, h, kvh, d) and a resnet-ish mid-layer
# (n, h, w, ci, co, kh, kw, stride, pad).
ATTN_SHAPE = (1, 1024, 1024, 8, 2, 64)
CONV_SHAPE = (2, 28, 28, 64, 96, 3, 3, 1, 1)


def _time(fn, *args, iters=5):
    """min/mean microseconds with per-iteration sync."""
    return tmeasure.time_callable(fn, *args, iters=iters)


def gemm_rows():
    rng = np.random.default_rng(0)
    rows = []
    for (m, n, k) in [(512, 512, 512), (1024, 1024, 1024), SERVING_SHAPE]:
        for df in (Dataflow.OS, Dataflow.WS):
            cfg = GemminiConfig(dataflow=df)
            plan = plan_gemm(cfg, m, n, k)
            a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
            b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
            ctx = ExecutionContext(cfg=cfg, backend="xla")
            f = jax.jit(lambda a, b, ctx=ctx: ctx.gemm(a, b, None, shift=8))
            t = _time(f, a, b)
            rows.append(dict(
                name=f"gemm_{df.value}_{m}x{n}x{k}", us=t["mean_us"],
                us_min=t["min_us"],
                tile=(plan.tile_m, plan.tile_n, plan.tile_k),
                vmem_kib=(plan.vmem_streamed_bytes +
                          plan.vmem_resident_bytes) // 1024,
                ai=plan.arithmetic_intensity))
    return rows


def tuned_rows(shape=SERVING_SHAPE, iters: int = 3):
    """Static-vs-tuned schedules for all three tuned kernel classes.

    Runs the full tuner (measure + analytic tiebreak) on the skewed serving
    GEMM, one attention shape, and one conv shape; persists each winner,
    then resolves the same shape again to demonstrate the cache hit -- the
    second resolution must not re-measure.
    """
    import os
    import tempfile

    from repro.core import flags
    from repro.tune import cache as tcache
    from repro.tune import tuner

    # Never mutate the user's real plan cache from a benchmark: unless a
    # cache was explicitly configured, tune into a bench-local temp file.
    prev_cache_flag = flags.get("tune_cache")
    scoped = not prev_cache_flag and not os.environ.get("GEMMINI_TUNE_CACHE")
    if scoped:
        flags.set_flag("tune_cache", os.path.join(
            tempfile.mkdtemp(prefix="gemmini-bench-"), "tile_plans.json"))
        tcache.reset_cache()

    def _cached_resolve(fn):
        prev = flags.get("tune_mode")
        flags.set_flag("tune_mode", "cached")
        try:
            pc = tcache.get_cache()
            hits0 = pc.hits
            out = fn()
            return out, pc.hits == hits0 + 1
        finally:
            flags.set_flag("tune_mode", prev)

    m, n, k = shape
    rows = []
    try:
        for df in (Dataflow.OS, Dataflow.WS):
            cfg = GemminiConfig(dataflow=df)
            report = tuner.tune_gemm(cfg, m, n, k, iters=iters)
            again, hit = _cached_resolve(
                lambda: tuner.resolve_plan(cfg, m, n, k))
            cache_hit = hit and \
                (again.tile_m, again.tile_n, again.tile_k) == \
                (report.plan.tile_m, report.plan.tile_n, report.plan.tile_k)
            g, w = report.greedy, report.plan
            rows.append(dict(
                name=f"tune_{df.value}_{m}x{n}x{k}",
                greedy_tile=(g.plan.tile_m, g.plan.tile_n, g.plan.tile_k),
                tuned_tile=(w.tile_m, w.tile_n, w.tile_k),
                greedy_us=g.min_us,
                tuned_us=min(c.min_us for c in report.candidates),
                speedup=report.speedup_vs_greedy,
                n_candidates=len(report.candidates),
                backend=report.backend,
                cache_hit=bool(cache_hit)))

        acfg = GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                             output_dtype="bf16")
        b, tq, tk, h, kvh, d = ATTN_SHAPE
        arep = tuner.tune_attention(acfg, b, tq, tk, h, kvh, d,
                                    dtype="bfloat16", iters=iters)
        asched, hit = _cached_resolve(
            lambda: tuner.resolve_attn_schedule(acfg, b, tq, tk, h, kvh, d,
                                                dtype="bfloat16"))
        rows.append(dict(
            name=f"tune_attn_t{tq}",
            greedy_tile=(arep.default.sched.block_q,
                         arep.default.sched.block_k),
            tuned_tile=(arep.sched.block_q, arep.sched.block_k),
            greedy_us=arep.default.min_us,
            tuned_us=min(c.min_us for c in arep.candidates),
            speedup=arep.speedup_vs_default,
            n_candidates=len(arep.candidates),
            backend=arep.backend,
            cache_hit=bool(hit and asched == arep.sched)))

        ccfg = GemminiConfig()
        cn, ch, cw, ci, co, kh, kw, stride, pad = CONV_SHAPE
        crep = tuner.tune_conv(ccfg, cn, ch, cw, ci, co, kh, kw,
                               stride=stride, padding=pad, iters=iters)
        csched, hit = _cached_resolve(
            lambda: tuner.resolve_conv_schedule(ccfg, cn, ch, cw, ci, co,
                                                kh, kw, stride=stride,
                                                padding=pad))
        rows.append(dict(
            name=f"tune_conv_{ch}x{cw}x{ci}x{co}",
            greedy_tile=(crep.default.sched.co_tile,),
            tuned_tile=(crep.sched.co_tile,),
            greedy_us=crep.default.min_us,
            tuned_us=min(c.min_us for c in crep.candidates),
            speedup=crep.speedup_vs_default,
            n_candidates=len(crep.candidates),
            backend=crep.backend,
            cache_hit=bool(hit and csched == crep.sched)))
    finally:
        if scoped:
            import shutil
            shutil.rmtree(os.path.dirname(flags.get("tune_cache")),
                          ignore_errors=True)
            flags.set_flag("tune_cache", prev_cache_flag)
            tcache.reset_cache()
    return rows


def attention_rows():
    rng = np.random.default_rng(0)
    rows = []
    from repro.models.attention import blockwise_attention_xla
    for (b, t, h, kvh, d, win) in [(1, 1024, 8, 2, 64, None),
                                   (1, 2048, 8, 2, 64, 256)]:
        q = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((b, t, kvh, d)), jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((b, t, kvh, d)), jnp.bfloat16)
        f = jax.jit(lambda q, k, v, win=win: blockwise_attention_xla(
            q, k, v, causal=True, window=win))
        t_ = _time(f, q, k, v, iters=3)
        rows.append(dict(name=f"attn_b{b}_t{t}_w{win}", us=t_["mean_us"],
                         us_min=t_["min_us"], tile=None, vmem_kib=0, ai=0))
    return rows


def conv_rows():
    """One conv shape on the XLA path + the implicit-im2col plan columns."""
    rng = np.random.default_rng(0)
    from repro.tune.schedules import default_conv_schedule
    n, h, w, ci, co, kh, kw, stride, pad = CONV_SHAPE
    cfg = GemminiConfig()
    x = jnp.asarray(rng.integers(-64, 64, (n, h, w, ci)), jnp.int8)
    wt = jnp.asarray(rng.integers(-32, 32, (kh, kw, ci, co)), jnp.int8)
    ctx = ExecutionContext(cfg=cfg, backend="xla")
    f = jax.jit(lambda x, wt: ctx.conv2d(x, wt, None, stride=stride,
                                         padding=pad, shift=6))
    t = _time(f, x, wt, iters=3)
    # Implicit-im2col schedule columns for the static default co_tile.
    ct = default_conv_schedule().effective(co).co_tile
    nco = -(-co // ct)
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    hp, wp = (oh - 1) * stride + kh, (ow - 1) * stride + kw
    macs = n * nco * kh * kw * oh * ow * ci * ct
    traffic = n * nco * (hp * wp * ci + kh * kw * ci * ct) \
        + n * oh * ow * nco * ct
    return [dict(name=f"conv_{h}x{w}x{ci}x{co}", us=t["mean_us"],
                 us_min=t["min_us"], tile=(ct,),
                 vmem_kib=(oh * ow * ct * 4 + hp * wp * ci) // 1024,
                 ai=2.0 * macs / traffic)]


def ssd_rows():
    rng = np.random.default_rng(0)
    from repro.models.ssm import ssd_chunked_xla
    rows = []
    for (b, t, h, p, g, n) in [(1, 2048, 16, 64, 1, 64)]:
        x = jnp.asarray(rng.standard_normal((b, t, h, p)), jnp.float32)
        dt = jnp.abs(jnp.asarray(rng.standard_normal((b, t, h)),
                                 jnp.float32)) + .01
        al = jnp.asarray(rng.standard_normal((h,)) * .3, jnp.float32)
        bb = jnp.asarray(rng.standard_normal((b, t, g, n)) * .3, jnp.float32)
        cc = jnp.asarray(rng.standard_normal((b, t, g, n)) * .3, jnp.float32)
        f = jax.jit(lambda x, dt, bb, cc: ssd_chunked_xla(x, dt, al, bb, cc,
                                                          chunk=256))
        t_ = _time(f, x, dt, bb, cc, iters=3)
        rows.append(dict(name=f"ssd_t{t}_h{h}", us=t_["mean_us"],
                         us_min=t_["min_us"], tile=None, vmem_kib=0, ai=0))
    return rows


def main(csv=True, with_tuner: bool = True):
    rows = gemm_rows() + attention_rows() + conv_rows() + ssd_rows()
    trows = tuned_rows() if with_tuner else []
    if csv:
        print("# bench_kernels: XLA-path wall time (this host) + TPU plan "
              "quality")
        print("name,us_per_call,us_min,tile,vmem_kib,arith_intensity")
        for r in rows:
            print(f"{r['name']},{r['us']:.0f},{r['us_min']:.0f},"
                  f"\"{r['tile']}\",{r['vmem_kib']},{r['ai']:.1f}")
        if trows:
            print("# tuner: static vs tuned schedule per kernel class "
                  "(backend-aware measurement, analytic tiebreak)")
            print("name,greedy_tile,tuned_tile,greedy_us,tuned_us,speedup,"
                  "candidates,backend,cache_hit")
            for r in trows:
                print(f"{r['name']},\"{r['greedy_tile']}\","
                      f"\"{r['tuned_tile']}\",{r['greedy_us']:.0f},"
                      f"{r['tuned_us']:.0f},{r['speedup']:.3f},"
                      f"{r['n_candidates']},{r['backend']},{r['cache_hit']}")
    return rows + trows


if __name__ == "__main__":
    main()
