"""Kernel micro-benchmarks: wall time of the XLA paths on this host +
static schedule quality (VMEM footprint / arithmetic intensity) of the
Pallas plans for the TPU target.

On this CPU-only container the wall times are indicative (XLA:CPU), but
the derived columns -- tile shapes, VMEM working set, arithmetic intensity
-- are the TPU-relevant outputs of the generator, independent of host.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import Dataflow, GemminiConfig
from repro.core.tiling import plan_gemm
from repro.kernels import ops


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()            # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6   # us


def gemm_rows():
    rng = np.random.default_rng(0)
    rows = []
    for (m, n, k) in [(512, 512, 512), (1024, 1024, 1024), (128, 4096, 1024)]:
        for df in (Dataflow.OS, Dataflow.WS):
            cfg = GemminiConfig(dataflow=df)
            plan = plan_gemm(cfg, m, n, k)
            a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
            b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
            f = jax.jit(lambda a, b, cfg=cfg: ops.gemm(a, b, None, cfg=cfg,
                                                       shift=8,
                                                       backend="xla"))
            us = _time(f, a, b)
            rows.append(dict(
                name=f"gemm_{df.value}_{m}x{n}x{k}", us=us,
                tile=(plan.tile_m, plan.tile_n, plan.tile_k),
                vmem_kib=(plan.vmem_streamed_bytes +
                          plan.vmem_resident_bytes) // 1024,
                ai=plan.arithmetic_intensity))
    return rows


def attention_rows():
    rng = np.random.default_rng(0)
    rows = []
    from repro.models.attention import blockwise_attention_xla
    for (b, t, h, kvh, d, win) in [(1, 1024, 8, 2, 64, None),
                                   (1, 2048, 8, 2, 64, 256)]:
        q = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((b, t, kvh, d)), jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((b, t, kvh, d)), jnp.bfloat16)
        f = jax.jit(lambda q, k, v, win=win: blockwise_attention_xla(
            q, k, v, causal=True, window=win))
        us = _time(f, q, k, v, iters=3)
        rows.append(dict(name=f"attn_b{b}_t{t}_w{win}", us=us,
                         tile=None, vmem_kib=0, ai=0))
    return rows


def ssd_rows():
    rng = np.random.default_rng(0)
    from repro.models.ssm import ssd_chunked_xla
    rows = []
    for (b, t, h, p, g, n) in [(1, 2048, 16, 64, 1, 64)]:
        x = jnp.asarray(rng.standard_normal((b, t, h, p)), jnp.float32)
        dt = jnp.abs(jnp.asarray(rng.standard_normal((b, t, h)),
                                 jnp.float32)) + .01
        al = jnp.asarray(rng.standard_normal((h,)) * .3, jnp.float32)
        bb = jnp.asarray(rng.standard_normal((b, t, g, n)) * .3, jnp.float32)
        cc = jnp.asarray(rng.standard_normal((b, t, g, n)) * .3, jnp.float32)
        f = jax.jit(lambda x, dt, bb, cc: ssd_chunked_xla(x, dt, al, bb, cc,
                                                          chunk=256))
        us = _time(f, x, dt, bb, cc, iters=3)
        rows.append(dict(name=f"ssd_t{t}_h{h}", us=us, tile=None,
                         vmem_kib=0, ai=0))
    return rows


def main(csv=True):
    rows = gemm_rows() + attention_rows() + ssd_rows()
    if csv:
        print("# bench_kernels: XLA-path wall time (this host) + TPU plan "
              "quality")
        print("name,us_per_call,tile,vmem_kib,arith_intensity")
        for r in rows:
            print(f"{r['name']},{r['us']:.0f},\"{r['tile']}\","
                  f"{r['vmem_kib']},{r['ai']:.1f}")
    return rows


if __name__ == "__main__":
    main()
