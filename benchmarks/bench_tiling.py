"""Fig 7b's tiling-fit finding + the scratchpad sweep.

"MLP 4 outperformed MLP 3, because its dimensions, which were powers-of-2,
mapped better onto our maximum tiling factors." -- reproduced via the
tiling solver's utilization (useful MACs / padded MACs) and the resulting
cycles, plus a scratchpad-capacity sweep showing the reuse effect that
design point 7 probes.
"""

from __future__ import annotations

from repro.core import dse, isa
from repro.core.config import PAPER_DESIGN_POINTS
from repro.core.tiling import plan_gemm

BASE = PAPER_DESIGN_POINTS[1]


def mlp_fit_rows():
    out = []
    for name in ("mlp1", "mlp2", "mlp3", "mlp4"):
        wl = dse.PAPER_MLPS[name]
        r = dse.evaluate(BASE, wl, isa.ROCKET)
        cpu = sum(2.0 * g.m * g.n * g.k * g.repeats for g in wl.gemms)
        out.append(dict(workload=name, utilization=r["utilization"],
                        speedup=cpu / r["total_cycles"],
                        macs_per_cycle=r["macs"] / r["total_cycles"]))
    return out


def scratchpad_sweep(sizes=(16, 32, 64, 128, 256, 512)):
    """Arithmetic intensity of one large GEMM vs scratchpad KiB (the
    accumulator scales with it, as in the paper's physical-design configs:
    256 KiB spad / 64 KiB acc)."""
    out = []
    for kib in sizes:
        cfg = BASE.replace(scratchpad_bytes=kib * 1024,
                           accumulator_bytes=kib * 256)
        plan = plan_gemm(cfg, 1024, 1024, 1024)
        out.append(dict(scratchpad_kib=kib,
                        tile=(plan.tile_m, plan.tile_n, plan.tile_k),
                        arith_intensity=plan.arithmetic_intensity,
                        hbm_bytes=plan.hbm_read_bytes +
                        plan.hbm_write_bytes))
    return out


def main(csv=True):
    fit = mlp_fit_rows()
    sweep = scratchpad_sweep()
    if csv:
        print("# bench_tiling: MLP tiling fit (Fig 7b) + scratchpad sweep "
              "(point 7)")
        print("workload,utilization,speedup_vs_cpu,macs_per_cycle")
        for r in fit:
            print(f"{r['workload']},{r['utilization']:.3f},"
                  f"{r['speedup']:.1f},{r['macs_per_cycle']:.1f}")
        print("scratchpad_kib,tile_m,tile_n,tile_k,arith_intensity,hbm_bytes")
        for r in sweep:
            tm, tn, tk = r["tile"]
            print(f"{r['scratchpad_kib']},{tm},{tn},{tk},"
                  f"{r['arith_intensity']:.2f},{r['hbm_bytes']}")
    return dict(fit=fit, sweep=sweep)


if __name__ == "__main__":
    main()
