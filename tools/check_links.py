#!/usr/bin/env python
"""Fail CI on broken intra-repo markdown links.

Scans every ``*.md`` under the repo root for inline links/images
(``[text](target)``) and reference definitions (``[ref]: target``),
resolves relative targets against the containing file, and exits non-zero
listing every target that does not exist. External schemes (http/https/
mailto), pure in-page anchors (``#...``), and autolinks are skipped --
this is a *repo-consistency* check (docs renaming a module or a bench
artifact must update every pointer), not a web-link checker.

  python tools/check_links.py [root]
"""

from __future__ import annotations

import os
import re
import sys

# [text](target) and ![alt](target); stops at the first ')' or space
# (markdown titles like [t](x "title") keep only the path part).
_INLINE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
# [ref]: target definitions at line start
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")
_SKIP_DIRS = {".git", ".venv", "node_modules", "__pycache__"}


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def check(root: str):
    broken = []
    for path in sorted(md_files(root)):
        text = open(path, encoding="utf-8").read()
        targets = _INLINE.findall(text) + _REFDEF.findall(text)
        for t in targets:
            if t.startswith(_SKIP_SCHEMES) or t.startswith("#"):
                continue
            t = t.split("#", 1)[0]         # strip in-file anchors
            if not t:
                continue
            base = root if t.startswith("/") else os.path.dirname(path)
            resolved = os.path.normpath(os.path.join(base, t.lstrip("/")))
            if not os.path.exists(resolved):
                broken.append((os.path.relpath(path, root), t))
    return broken


def main() -> int:
    root = os.path.abspath(sys.argv[1] if len(sys.argv) > 1 else ".")
    broken = check(root)
    for path, target in broken:
        print(f"BROKEN LINK: {path} -> {target}")
    if broken:
        print(f"{len(broken)} broken intra-repo link(s)", file=sys.stderr)
        return 1
    n = sum(1 for _ in md_files(root))
    print(f"check_links: OK ({n} markdown files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
