"""Quickstart: elaborate a Gemmini instance and run quantized GEMMs.

  PYTHONPATH=src python examples/quickstart.py

Walks the paper's §2 flow end to end: configure the generator, elaborate an
accelerator instance, inspect the generated tiling "header file", move data
through a quantized GEMM with fused bias + ReLU + rounding-shift rescale on
both dataflows, and check against the oracle.
"""

import numpy as np
import jax.numpy as jnp

from repro.core.config import Activation, Dataflow, GemminiConfig
from repro.core.generator import elaborate
from repro.core.quantize import calibrate_symmetric, quantize
from repro.kernels import ref

# ---- 1. configure + elaborate (the paper's Chisel generator run) ---------
cfg = GemminiConfig(
    dataflow=Dataflow.BOTH,       # design point 3: runtime-selectable
    dim=128,                      # systolic tile granularity (MXU-aligned)
    input_dtype="int8", acc_dtype="int32", output_dtype="int8",
    scratchpad_bytes=8 << 20, accumulator_bytes=4 << 20,
)
engine = elaborate(cfg, backend="interpret")   # "pallas" on a real TPU
print("elaborated:", cfg.describe())

# ---- 2. the generated tiling header (paper section 2.3) ------------------
hdr = engine.header(1000, 512, 2048)
print("tiling header for (1000x512x2048):",
      {k: hdr[k] for k in ("DIM", "TILE_M", "TILE_N", "TILE_K", "GRID")})

# ---- 3. quantize float inputs, run both dataflows -------------------------
rng = np.random.default_rng(0)
a_f = rng.standard_normal((1000, 2048)).astype(np.float32)
b_f = rng.standard_normal((2048, 512)).astype(np.float32)
a = quantize(jnp.asarray(a_f), calibrate_symmetric(jnp.asarray(a_f)))
b = quantize(jnp.asarray(b_f), calibrate_symmetric(jnp.asarray(b_f)))
bias = jnp.asarray(rng.integers(-1000, 1000, (1, 512)), jnp.int32)

for df in (Dataflow.OS, Dataflow.WS):
    y = engine.gemm(a, b, bias, dataflow=df, shift=7,
                    activation=Activation.RELU)
    y_ref = ref.gemm_ref(a, b, bias, acc_dtype=jnp.int32,
                         out_dtype=jnp.int8, shift=7,
                         activation=Activation.RELU)
    exact = bool(jnp.all(y == y_ref))
    print(f"{df.value}: out {y.shape} {y.dtype}, bit-exact vs oracle: "
          f"{exact}")
    assert exact

# ---- 4. a conv on the engine (host-im2col and fused paths) ----------------
x = jnp.asarray(rng.integers(-64, 64, (1, 14, 14, 16)), jnp.int8)
w = jnp.asarray(rng.integers(-32, 32, (3, 3, 16, 32)), jnp.int8)
y_host = engine.conv2d(x, w, stride=1, padding=1, shift=6,
                       activation=Activation.RELU)
y_fused = engine.conv2d(x, w, stride=1, padding=1, shift=6,
                        activation=Activation.RELU, fused=True)
print("conv2d host-im2col == fused-im2col kernel:",
      bool(jnp.all(y_host == y_fused)))
print("quickstart OK")
