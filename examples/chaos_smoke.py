"""Chaos smoke: the self-healing serving invariants, exit-code gated.

  PYTHONPATH=src python examples/chaos_smoke.py

Runs the same trace twice through the continuous-batching engine -- once
fault-free, once under a seeded adversarial :class:`FaultPlan` (NaN
poison at decode + transient prefill failures + straggler delays + arena
exhaustion) with NaN guards, retries, and the xla_twin fallback enabled
-- and gates on the PR's robustness contract:

  1. no request is silently lost (every one reaches a terminal status),
  2. every completing request's greedy tokens are BIT-IDENTICAL to the
     fault-free run (degradation changes latency, never numerics),
  3. the injected faults actually fired and the recovery machinery shows
     up in telemetry (fallbacks > 0, retries > 0, injected counts match
     the plan's caps).

The process exits non-zero if any invariant fails -- CI runs this after
the perf smoke (see .github/workflows/ci.yml). docs/serving.md#robustness
explains the fault-plan grammar and the recovery ladder.
"""

import sys

import numpy as np

from repro import configs
from repro.serving import ServingEngine

FAULT_PLAN = ("seed=3;"
              "nan@decode:p=1,max=2;"
              "transient@prefill:max=1;"
              "straggler@step:delay=0.001,start=6,max=2;"
              "arena:pages=2,start=3,max=3")
EXPECTED_INJECTED = {"nan@decode": 2, "transient@prefill": 1,
                     "straggler@step": 2, "arena@arena": 3}
PROMPT_LENS = [5, 11, 19]
GEN_LENS = [6, 6, 6]


def run_trace(model_cfg, faults):
    # assert_invariants: the allocator's ownership oracle runs at every
    # step boundary -- the chaos run doubles as a lifecycle audit.
    eng = ServingEngine(model_cfg, max_slots=2, max_context=32, page_size=8,
                        n_pages=8, temperature=0.0, seed=0,
                        backend="interpret", prefill_chunk=8, faults=faults,
                        assert_invariants=True)
    rng = np.random.default_rng(0)
    for plen, glen in zip(PROMPT_LENS, GEN_LENS):
        eng.submit(rng.integers(0, model_cfg.vocab, (plen,),
                                ).astype(np.int32), glen)
    return eng.run()


def main() -> int:
    model_cfg = configs.get_smoke("gemma2-2b")
    ok = True

    print("--- reference run (faults off) ---")
    ref = run_trace(model_cfg, None)
    rs = ref["summary"]
    print(f"  {int(rs['requests'])} reqs, {int(rs['new_tokens'])} tokens, "
          f"retries={int(rs['retries'])} fallbacks={int(rs['fallbacks'])}")
    if rs["retries"] or rs["fallbacks"] or rs["injected_faults"]:
        print("  FAIL: fault-free run shows nonzero robustness counters",
              file=sys.stderr)
        ok = False

    print(f"--- chaos run: {FAULT_PLAN} ---")
    rep = run_trace(model_cfg, FAULT_PLAN)
    s = rep["summary"]
    print(f"  retries={int(s['retries'])} fallbacks={int(s['fallbacks'])} "
          f"injected={int(s['injected_faults'])} shed={int(s['shed'])} "
          f"faults={rep['faults']}")

    # 1. no silent loss: every request terminal, none dropped
    if len(rep["requests"]) != len(PROMPT_LENS):
        print(f"  FAIL: {len(rep['requests'])} request reports for "
              f"{len(PROMPT_LENS)} submissions", file=sys.stderr)
        ok = False
    for r in rep["requests"]:
        if r["status"] not in ("finished", "shed"):
            print(f"  FAIL: rid {r['rid']} non-terminal status "
                  f"{r['status']!r}", file=sys.stderr)
            ok = False

    # 2. bit-exact degradation: chaos tokens == fault-free tokens
    for rr, fr in zip(ref["requests"], rep["requests"]):
        want = np.asarray(rr["tokens"], np.int32)
        got = np.asarray(fr["tokens"], np.int32)
        if fr["status"] == "shed":       # prefix of the reference stream
            want = want[:got.shape[0]]
        if got.shape != want.shape or not np.array_equal(got, want):
            print(f"  FAIL rid={fr['rid']}: chaos tokens {got.ravel()} != "
                  f"reference {want.ravel()}", file=sys.stderr)
            ok = False
        else:
            print(f"  rid {fr['rid']}: {got.shape[0]} tokens bit-identical "
                  f"to the fault-free run ({fr['status']})")

    # 3. the machinery fired and is visible in telemetry
    if not (s["fallbacks"] > 0 and s["retries"] > 0):
        print("  FAIL: expected nonzero fallbacks and retries under the "
              "chaos plan", file=sys.stderr)
        ok = False
    if rep["faults"] != EXPECTED_INJECTED:
        print(f"  FAIL: injected-fault report {rep['faults']} != "
              f"{EXPECTED_INJECTED}", file=sys.stderr)
        ok = False

    if not ok:
        print("\nchaos_smoke FAILED", file=sys.stderr)
        return 1
    print("\nchaos_smoke OK: all streams exact, recovery visible in "
          "telemetry")
    return 0


if __name__ == "__main__":
    sys.exit(main())
