"""End-to-end training driver example: ~100M-param model, few hundred steps.

  PYTHONPATH=src python examples/train_e2e.py            # full (~100M, slow)
  PYTHONPATH=src python examples/train_e2e.py --tiny     # CI-speed variant

Uses the real launcher (repro.launch.train): synthetic Markov data pipeline,
AdamW + cosine schedule, checkpointing every 50 steps, straggler detection,
and the restart loop -- the full production path, scaled to this host.
"""

import argparse
import dataclasses

from repro import configs
from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    if args.tiny:
        argv = ["--arch", "gemma3-1b", "--smoke", "--steps",
                str(args.steps or 30), "--batch", "8", "--seq", "128"]
        ckpt = args.ckpt_dir + "_tiny"
    else:
        # ~100M-param dense config (gemma3-1b family, reduced width) --
        # registered on the fly so the launcher can select it.
        base = configs.get("gemma3-1b")
        cfg100m = dataclasses.replace(
            base, name="gemma-100m", n_layers=16, d_model=512,
            n_heads=8, n_kv_heads=4, head_dim=64, d_ff=2560,
            vocab=32768, local_window=256)
        configs._REGISTRY["gemma-100m"] = lambda: cfg100m
        n = cfg100m.param_count()
        print(f"[e2e] gemma-100m params: {n/1e6:.1f}M")
        argv = ["--arch", "gemma-100m", "--steps",
                str(args.steps or 200), "--batch", "8", "--seq", "256",
                "--lr", "1e-3"]
        ckpt = args.ckpt_dir
    argv += ["--ckpt-dir", ckpt, "--ckpt-every", "50",
             "--log-every", "10"]
    result = train_cli.main(argv)
    assert result.losses[-1] < result.losses[0], "loss did not decrease"
    print(f"[e2e] loss {result.losses[0]:.3f} -> {result.losses[-1]:.3f} "
          f"over {result.steps_done} steps")


if __name__ == "__main__":
    main()
