"""DSE example: explore a custom design space for your own workload.

  PYTHONPATH=src python examples/dse_sweep.py

The paper's core loop: define a workload (here: a transformer FFN inference
stream), sweep generator parameters one at a time from the baseline, and
pick the design point by performance-per-energy-proxy -- the same process
section 3 runs over Table 1.
"""

from repro.core import dse, isa
from repro.core.config import Dataflow, GemminiConfig


def ffn_workload(d_model=2048, d_ff=8192, batch=64, layers=24):
    gemms = []
    for _ in range(layers):
        gemms.append(dse.GemmShape(m=batch, n=d_ff, k=d_model))   # up proj
        gemms.append(dse.GemmShape(m=batch, n=d_model, k=d_ff))   # down proj
    return dse.Workload("ffn_24L", tuple(gemms))


def main():
    wl = ffn_workload()
    base = GemminiConfig(dim=16, scratchpad_bytes=64 << 10,
                         accumulator_bytes=16 << 10)
    sweeps = {
        "baseline": base,
        "ws": base.replace(dataflow=Dataflow.WS),
        "dim32": base.replace(dim=32, accumulator_bytes=64 << 10),
        "spad256k": base.replace(scratchpad_bytes=256 << 10,
                                 accumulator_bytes=64 << 10),
        "fp32_io": base.replace(input_dtype="fp32", acc_dtype="fp32",
                                output_dtype="fp32"),
    }
    print("point,cycles,bottleneck,hbm_mb,perf_per_energy(norm)")
    results = {}
    for name, cfg in sweeps.items():
        df = Dataflow.WS if cfg.dataflow is Dataflow.WS else None
        r = dse.evaluate(cfg, wl, isa.ROCKET, dataflow=df)
        results[name] = r
    base_ppe = 1.0 / (results["baseline"]["total_cycles"] *
                      results["baseline"]["hbm_bytes"])
    best, best_ppe = None, -1.0
    for name, r in results.items():
        ppe = 1.0 / (r["total_cycles"] * r["hbm_bytes"]) / base_ppe
        print(f"{name},{r['total_cycles']:.0f},{r['bottleneck']},"
              f"{r['hbm_bytes']/1e6:.1f},{ppe:.2f}")
        if ppe > best_ppe:
            best, best_ppe = name, ppe
    print(f"\nselected design point: {best} "
          f"({best_ppe:.2f}x baseline perf/energy)")


if __name__ == "__main__":
    main()
