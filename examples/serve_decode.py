"""Serving example: batched prefill + decode across architecture families.

  PYTHONPATH=src python examples/serve_decode.py

Runs the real serving path (repro.launch.serve) for one arch of each
family -- dense attention (KV cache), SSM (recurrent state cache), hybrid
(both), and multi-codebook audio -- demonstrating that a single serve_step
definition covers the full assigned-architecture pool.
"""

from repro.launch import serve as serve_cli

ARCHS = ["gemma2-2b", "mamba2-1.3b", "hymba-1.5b", "musicgen-medium"]


def main():
    for arch in ARCHS:
        print(f"\n--- serving {arch} (reduced config) ---")
        out = serve_cli.main(["--arch", arch, "--smoke", "--batch", "2",
                              "--prompt-len", "16", "--gen", "8"])
        assert out["tokens"].shape[0] == 2
    print("\nserve_decode OK")


if __name__ == "__main__":
    main()
