"""Serving example: the continuous-batching engine across model families.

  PYTHONPATH=src python examples/serve_decode.py

Drives the real serving stack (repro.serving.ServingEngine: paged KV
cache + continuous batching + chunked prefill) for one arch of each
family -- dense attention, SSM (recurrent state cache), hybrid (both),
and multi-codebook audio -- and then *gates on correctness*: every
request's greedy token stream is re-derived through the static reference
path (prefill_into_cache + decode_step, one request at a time, dense KV
cache) and the process EXITS NON-ZERO on any mismatch. Per-request
numerics are batch-invariant and the paged gather mirrors the dense
mask/softmax exactly, so the comparison is exact equality, not a
tolerance.

Chunked prefill is ON (``PREFILL_CHUNK`` cache positions per chunk, sized
so two of the three prompts split into multiple chunks): the comparison
therefore also locks in that splitting a prompt across chunk calls --
self-attention for chunk 0, block-table gather against cache + chunk for
continuations, resumed conv/SSM state for the recurrent families --
reproduces the single-pass token stream.
"""

import sys

import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.config import GemminiConfig
from repro.core.generator import elaborate
from repro.models import transformer as tf
from repro.serving import ServingEngine

ARCHS = ["gemma2-2b", "mamba2-1.3b", "hymba-1.5b", "musicgen-medium"]
PROMPT_LENS = [11, 16, 7]          # mixed lengths: distinct page counts
GEN_LENS = [6, 3, 5]               # mixed depths: slots recycle mid-run
PREFILL_CHUNK = 8                  # < the longer prompts: multi-chunk paths


def reference_tokens(model_cfg, params, prompt: np.ndarray,
                     gen_len: int) -> np.ndarray:
    """The static-batch oracle: one request, dense contiguous KV cache."""
    engine = elaborate(GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                     output_dtype="bf16"), "xla")
    t_true = len(prompt) + model_cfg.n_meta_tokens
    state = tf.init_decode_state(model_cfg, 1, t_true + gen_len,
                                 dtype=model_cfg.dtype)
    state = state._replace(pos=jnp.zeros((), jnp.int32))
    logits, state = tf.prefill_into_cache(engine, params, model_cfg,
                                          jnp.asarray(prompt[None]), state)
    toks, last = [], logits[0, t_true - 1]
    for _ in range(gen_len):
        nxt = np.asarray(jnp.argmax(last, axis=-1), np.int32)
        toks.append(nxt)
        step = nxt.reshape(1, 1) if nxt.ndim == 0 else nxt.reshape(1, 1, -1)
        logits, state = tf.decode_step(engine, params, model_cfg,
                                       jnp.asarray(step), state)
        last = logits[0, -1]
    return np.stack(toks)


def run_arch(arch: str) -> bool:
    model_cfg = configs.get_smoke(arch)
    rng = np.random.default_rng(0)
    # Pin the xla backend on both sides: exact equality is an XLA-vs-XLA
    # contract (the Pallas kernels' online-softmax accumulation is
    # tolerance-close, not bit-identical, in bf16).
    engine = ServingEngine(model_cfg, max_slots=2, max_context=64,
                           page_size=16, n_pages=24, temperature=0.0,
                           seed=0, backend="xla",
                           prefill_chunk=PREFILL_CHUNK)
    prompts = []
    for plen, glen in zip(PROMPT_LENS, GEN_LENS):
        shape = (plen, model_cfg.n_codebooks) \
            if model_cfg.n_codebooks > 1 else (plen,)
        prompt = rng.integers(0, model_cfg.vocab, shape).astype(np.int32)
        prompts.append(prompt)
        engine.submit(prompt, glen)
    report = engine.run()
    s = report["summary"]
    print(f"  engine: {int(s['requests'])} reqs, "
          f"{int(s['new_tokens'])} tokens, {s['tokens_per_s']:.1f} tok/s, "
          f"p50 latency {s['p50_latency_s']*1e3:.0f}ms, "
          f"{int(s['prefill_chunks'])} prefill chunks "
          f"(chunk={engine.prefill_chunk})")

    ok = True
    for r, prompt, glen in zip(report["requests"], prompts, GEN_LENS):
        got = np.asarray(r["tokens"], np.int32)
        want = reference_tokens(model_cfg, engine.params, prompt, glen)
        if got.shape != want.shape or not np.array_equal(got, want):
            print(f"  MISMATCH rid={r['rid']}: engine {got.ravel()} "
                  f"!= reference {want.ravel()}")
            ok = False
        else:
            print(f"  rid {r['rid']}: {got.shape[0]} tokens match the "
                  f"static reference exactly")
    return ok


def main() -> int:
    ok = True
    for arch in ARCHS:
        print(f"\n--- serving {arch} (reduced config, paged engine) ---")
        ok &= run_arch(arch)
    if not ok:
        print("\nserve_decode FAILED: engine diverged from the reference "
              "path", file=sys.stderr)
        return 1
    print("\nserve_decode OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
