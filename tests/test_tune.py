"""Empirical tile-plan autotuner: candidate lattice legality, cache
round-trip + stable fingerprints, flag-gated plan resolution, and the fused
WS epilogue (single pallas_call, bit-exact vs the ref oracle)."""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flags
from repro.core.config import Activation, Dataflow, GemminiConfig
from repro.core.tiling import enumerate_plans, make_plan, plan_gemm
from repro.kernels import gemm as gemm_kernel
from repro.core.context import ExecutionContext
from repro.kernels import ref
from repro.tune import cache as tcache
from repro.tune import measure, tuner


@pytest.fixture
def tmp_cache(tmp_path):
    """Point the plan cache at a tmp file; restore the flag afterwards."""
    path = str(tmp_path / "plans.json")
    prev_cache = flags.get("tune_cache")
    prev_mode = flags.get("tune_mode")
    flags.set_flag("tune_cache", path)
    tcache.reset_cache()
    yield path
    flags.set_flag("tune_cache", prev_cache)
    flags.set_flag("tune_mode", prev_mode)
    tcache.reset_cache()


# ---------------------------------------------------------------------------
# enumerate_plans
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("df", [Dataflow.OS, Dataflow.WS])
@pytest.mark.parametrize("shape", [(128, 4096, 1024), (100, 4000, 1000),
                                   (1068, 512, 300)])
def test_enumerate_plans_all_legal(df, shape):
    m, n, k = shape
    cfg = GemminiConfig(dataflow=df)
    plans = enumerate_plans(cfg, m, n, k, has_bias=True)
    assert len(plans) >= 2
    greedy = plan_gemm(cfg, m, n, k, has_bias=True)
    tiles = {(p.tile_m, p.tile_n, p.tile_k) for p in plans}
    assert (greedy.tile_m, greedy.tile_n, greedy.tile_k) in tiles
    assert len(tiles) == len(plans)          # deduplicated
    for p in plans:
        # every candidate satisfies the scratchpad/accumulator contract
        assert p.vmem_streamed_bytes <= cfg.scratchpad_bytes
        assert p.vmem_resident_bytes <= cfg.accumulator_bytes
        assert p.tile_m % cfg.dim == 0
        assert p.tile_n % cfg.dim == 0
        assert p.tile_k % cfg.dim == 0
        gm, gn, gk = p.grid
        assert gm * p.tile_m == p.m >= m
        assert gn * p.tile_n == p.n >= n
        assert gk * p.tile_k == p.k >= k


def test_enumerate_respects_max_candidates():
    cfg = GemminiConfig()
    plans = enumerate_plans(cfg, 2048, 2048, 2048, max_candidates=5)
    assert len(plans) <= 5
    greedy = plan_gemm(cfg, 2048, 2048, 2048)
    assert any((p.tile_m, p.tile_n, p.tile_k) ==
               (greedy.tile_m, greedy.tile_n, greedy.tile_k) for p in plans)


# ---------------------------------------------------------------------------
# cache round-trip + fingerprint stability
# ---------------------------------------------------------------------------
def test_cache_roundtrip(tmp_cache):
    cfg = GemminiConfig(dataflow=Dataflow.WS)
    plan = plan_gemm(cfg, 128, 4096, 1024)
    pc = tcache.get_cache()
    assert pc.lookup(cfg, Dataflow.WS, 128, 4096, 1024, False) is None
    pc.store(cfg, Dataflow.WS, 128, 4096, 1024, False, plan,
             source="measured", best_us=12.5)
    # write -> reload in a FRESH cache object -> hit with identical tiles
    tcache.reset_cache()
    pc2 = tcache.get_cache()
    assert pc2 is not pc
    hit = pc2.lookup(cfg, Dataflow.WS, 128, 4096, 1024, False)
    assert hit is not None
    assert (hit.tile_m, hit.tile_n, hit.tile_k) == \
        (plan.tile_m, plan.tile_n, plan.tile_k)
    assert hit.grid == plan.grid             # full plan re-derived, not stored
    # different shape still misses
    assert pc2.lookup(cfg, Dataflow.WS, 128, 4096, 512, False) is None


def test_cache_rejects_stale_illegal_entry(tmp_cache):
    cfg = GemminiConfig()
    plan = plan_gemm(cfg, 1024, 1024, 1024)
    pc = tcache.get_cache()
    pc.store(cfg, Dataflow.OS, 1024, 1024, 1024, False, plan)
    # same fingerprint inputs but tiles made illegal by a smaller budget:
    # the loader must miss, not return an illegal plan. (Budget change also
    # changes the fingerprint, so force the mismatch through the entry.)
    key = tcache.fingerprint(cfg, Dataflow.OS, 1024, 1024, 1024, False)
    with open(tmp_cache) as f:
        raw = json.load(f)
    raw["plans"][key]["tile_m"] = 100        # not dim-aligned -> illegal
    with open(tmp_cache, "w") as f:
        json.dump(raw, f)
    tcache.reset_cache()
    assert tcache.get_cache().lookup(cfg, Dataflow.OS, 1024, 1024, 1024,
                                     False) is None


def test_fingerprint_stable_across_processes(tmp_cache):
    cfg = GemminiConfig(dataflow=Dataflow.WS, scratchpad_bytes=16 << 20)
    here = tcache.fingerprint(cfg, Dataflow.WS, 128, 4096, 1024, True)
    code = (
        "from repro.core.config import Dataflow, GemminiConfig\n"
        "from repro.tune import cache as tcache\n"
        "cfg = GemminiConfig(dataflow=Dataflow.WS, scratchpad_bytes=16 << 20)\n"
        "print(tcache.fingerprint(cfg, Dataflow.WS, 128, 4096, 1024, True))\n")
    import os
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, check=True).stdout.strip()
    assert out == here
    # and it is sensitive to the knobs that change the plan lattice
    assert here != tcache.fingerprint(cfg, Dataflow.OS, 128, 4096, 1024, True)
    assert here != tcache.fingerprint(cfg.replace(dim=256), Dataflow.WS,
                                      128, 4096, 1024, True)


# ---------------------------------------------------------------------------
# resolve_plan modes
# ---------------------------------------------------------------------------
def test_resolve_off_is_greedy(tmp_cache):
    flags.set_flag("tune_mode", "off")
    cfg = GemminiConfig()
    p = tuner.resolve_plan(cfg, 300, 300, 300)
    g = plan_gemm(cfg, 300, 300, 300)
    assert (p.tile_m, p.tile_n, p.tile_k) == (g.tile_m, g.tile_n, g.tile_k)
    assert len(tcache.get_cache()) == 0      # never touched


def test_resolve_cached_uses_cache_and_never_measures(tmp_cache, monkeypatch):
    cfg = GemminiConfig(dataflow=Dataflow.WS)
    # Seed the cache with a deliberately non-greedy (but legal) plan.
    seeded = make_plan(cfg, 128, 4096, 1024, 128, 4096, 128,
                       dataflow=Dataflow.WS)
    tcache.get_cache().store(cfg, Dataflow.WS, 128, 4096, 1024, False, seeded)

    def boom(*a, **kw):
        raise AssertionError("cached mode must not measure")
    monkeypatch.setattr(measure, "measure_plan", boom)

    flags.set_flag("tune_mode", "cached")
    hit = tuner.resolve_plan(cfg, 128, 4096, 1024)
    assert (hit.tile_m, hit.tile_n, hit.tile_k) == (128, 4096, 128)
    # miss falls back to greedy, still without measuring
    miss = tuner.resolve_plan(cfg, 256, 256, 256)
    g = plan_gemm(cfg, 256, 256, 256)
    assert (miss.tile_m, miss.tile_n, miss.tile_k) == \
        (g.tile_m, g.tile_n, g.tile_k)


def test_resolve_full_tunes_once_then_hits(tmp_cache, monkeypatch):
    cfg = GemminiConfig()
    calls = {"n": 0}
    real = measure.measure_plan

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)
    monkeypatch.setattr(measure, "measure_plan", counting)

    flags.set_flag("tune_mode", "full")
    p1 = tuner.resolve_plan(cfg, 384, 384, 384)
    assert calls["n"] > 0                    # measured the lattice
    first = calls["n"]
    p2 = tuner.resolve_plan(cfg, 384, 384, 384)
    assert calls["n"] == first               # second resolve: pure cache hit
    assert (p1.tile_m, p1.tile_n, p1.tile_k) == \
        (p2.tile_m, p2.tile_n, p2.tile_k)
    # the winner is on disk for the next process
    with open(tmp_cache) as f:
        assert len(json.load(f)["plans"]) == 1


def test_ops_gemm_consults_tuner(tmp_cache):
    """ctx.gemm (the model layers' entry) picks the cached tuned plan."""
    cfg = GemminiConfig(dataflow=Dataflow.WS)
    seeded = make_plan(cfg, 128, 512, 256, 128, 512, 128,
                       dataflow=Dataflow.WS)
    tcache.get_cache().store(cfg, Dataflow.WS, 128, 512, 256, False, seeded)
    flags.set_flag("tune_mode", "cached")
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-128, 128, (128, 256)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (256, 512)), jnp.int8)
    y = ExecutionContext(cfg=cfg, backend="interpret").gemm(
        a, b, None, shift=8)
    yr = ref.gemm_ref(a, b, None, acc_dtype=jnp.int32, out_dtype=jnp.int8,
                      shift=8)
    assert bool(jnp.all(y == yr))


def test_tune_gemm_winner_never_worse_analytically(tmp_cache):
    """The tuned plan's analytic cost is <= greedy's (proxy measurements
    tie on equal padding, so the analytic tiebreak decides in CI)."""
    cfg = GemminiConfig(dataflow=Dataflow.WS)
    report = tuner.tune_gemm(cfg, 128, 4096, 1024, iters=1)
    win_cycles = tuner.analytic_cycles(report.plan, cfg)
    greedy_cycles = tuner.analytic_cycles(report.greedy.plan, cfg)
    assert win_cycles <= greedy_cycles
    assert report.cache_key


# ---------------------------------------------------------------------------
# fused WS epilogue
# ---------------------------------------------------------------------------
def test_ws_is_single_pallas_call():
    """Acceptance: gemm_ws lowers as ONE pallas_call -- the separate
    accumulator epilogue pass is gone."""
    cfg = GemminiConfig(dataflow=Dataflow.WS, max_tile_m=128,
                        max_tile_n=128, max_tile_k=128)
    plan = plan_gemm(cfg, 256, 256, 512)
    assert plan.grid[2] > 1                  # real multi-step K stream
    a = jnp.zeros((plan.m, plan.k), jnp.int8)
    b = jnp.zeros((plan.k, plan.n), jnp.int8)
    jaxpr = jax.make_jaxpr(
        lambda a, b: gemm_kernel.gemm_ws(a, b, None, plan, cfg, shift=8))(a, b)
    n_calls = sum(1 for e in jaxpr.eqns if "pallas_call" in str(e.primitive))
    assert n_calls == 1


@pytest.mark.parametrize("bias", [False, True])
def test_fused_ws_multistep_k_bitexact(rng, bias):
    """Quantized path: fused epilogue == ref oracle exactly, with a K grid
    deep enough to exercise accumulate + flush (the seed's aliased-IO
    accumulation was silently wrong for k_steps > 1)."""
    cfg = GemminiConfig(dataflow=Dataflow.WS, max_tile_m=128,
                        max_tile_n=128, max_tile_k=128)
    m, n, k = 300, 260, 700
    a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    d = jnp.asarray(rng.integers(-1000, 1000, (1, n)), jnp.int32) \
        if bias else None
    y = ExecutionContext(cfg=cfg, backend="interpret").gemm(
        a, b, d, shift=8, activation=Activation.RELU6)
    yr = ref.gemm_ref(a, b, d, acc_dtype=jnp.int32, out_dtype=jnp.int8,
                      shift=8, activation=Activation.RELU6)
    assert y.dtype == jnp.int8
    assert bool(jnp.all(y == yr))


def test_fused_ws_bf16_multistep_k(rng):
    cfg = GemminiConfig(dataflow=Dataflow.WS, input_dtype="bf16",
                        acc_dtype="fp32", output_dtype="bf16",
                        max_tile_m=128, max_tile_n=128, max_tile_k=128)
    a = jnp.asarray(rng.standard_normal((160, 384)), jnp.bfloat16)
    b = jnp.asarray(rng.standard_normal((384, 224)), jnp.bfloat16)
    y = ExecutionContext(cfg=cfg, backend="interpret").gemm(a, b, None)
    yr = ref.gemm_ref(a, b, None, acc_dtype=jnp.float32,
                      out_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=2e-2, atol=1e-2)


def test_accumulator_epilogue_explicit_mvout_api(rng):
    """The standalone epilogue pass stays available for callers holding a
    raw accumulator (the explicit-mvout path)."""
    cfg = GemminiConfig(dataflow=Dataflow.WS)
    plan = plan_gemm(cfg, 256, 256, 256)
    acc = jnp.asarray(rng.integers(-(2 ** 20), 2 ** 20,
                                   (plan.m, plan.n)), jnp.int32)
    y = gemm_kernel.accumulator_epilogue(acc, plan, cfg, shift=8,
                                         activation=Activation.RELU,
                                         interpret=True)
    from repro.kernels import epilogue as epi
    yr = epi.apply(acc, shift=8, activation=Activation.RELU,
                   out_dtype=cfg.output_jnp)
    assert bool(jnp.all(y == yr))


# ---------------------------------------------------------------------------
# timing harness
# ---------------------------------------------------------------------------
# ---------------------------------------------------------------------------
# attention / conv schedule tuning (the kernel-agnostic layer)
# ---------------------------------------------------------------------------
ACFG = dict(input_dtype="bf16", acc_dtype="fp32", output_dtype="bf16")


def test_static_defaults_agree_with_kernel_signatures():
    """The schedules constants ARE the off-mode schedule: they must match
    the kernels' own keyword defaults, or GEMMINI_TUNE=off would launch a
    different blocking than a direct kernel call."""
    import inspect
    from repro.kernels import attention as ak
    from repro.kernels import conv as ck
    from repro.tune import schedules
    sig = inspect.signature(ak.flash_attention)
    assert sig.parameters["block_q"].default == schedules.DEFAULT_BLOCK_Q
    assert sig.parameters["block_k"].default == schedules.DEFAULT_BLOCK_K
    sig = inspect.signature(ck.conv2d_implicit)
    assert sig.parameters["co_tile"].default == schedules.DEFAULT_CO_TILE


def test_attn_key_ignores_engine_gemm_dtypes_and_caps(tmp_cache):
    """Attention consults only budgets/dim: a quantized engine config and
    the bf16 default must key the SAME attention entry (the has_bias
    warm-mismatch bug, as a class), while budget changes still miss."""
    from repro.tune import schedules
    quant = GemminiConfig()                       # int8/int32/int8 + no caps
    bf16 = GemminiConfig(**ACFG)
    capped = GemminiConfig(max_tile_m=128, max_tile_n=128, max_tile_k=128)
    kw = dict(causal=True, window=None, dtype="bfloat16")
    k_quant = schedules.attn_cache_key(quant, 1, 64, 64, 4, 2, 32, **kw)
    assert k_quant == schedules.attn_cache_key(bf16, 1, 64, 64, 4, 2, 32,
                                               **kw)
    assert k_quant == schedules.attn_cache_key(capped, 1, 64, 64, 4, 2, 32,
                                               **kw)
    smaller = GemminiConfig(scratchpad_bytes=1 << 20)
    assert k_quant != schedules.attn_cache_key(smaller, 1, 64, 64, 4, 2, 32,
                                               **kw)


def test_attn_enumerate_legal_and_has_default():
    cfg = GemminiConfig(**ACFG)
    from repro.tune import schedules
    cands = schedules.enumerate_attn_schedules(cfg, 1, 8, 2, 1024, 1024, 64)
    assert len(cands) >= 2
    default = schedules.default_attn_schedule().effective(1024, 1024)
    assert default in cands
    for s in cands:
        assert s.block_q > 0 and s.block_k > 0
        assert s.block_q <= 1024 and s.block_k <= 1024


def test_conv_enumerate_legal_and_has_default():
    cfg = GemminiConfig()
    from repro.tune import schedules
    cands = schedules.enumerate_conv_schedules(cfg, 1, 28, 28, 64, 96, 3, 3,
                                               stride=1, padding=1)
    assert len(cands) >= 2
    assert schedules.default_conv_schedule().effective(96) in cands
    for s in cands:
        assert 0 < s.co_tile <= 96


def test_attn_fingerprint_stable_across_processes(tmp_cache):
    from repro.tune import schedules
    cfg = GemminiConfig(**ACFG)
    here = schedules.attn_cache_key(cfg, 2, 128, 512, 8, 2, 64, causal=True,
                                    window=256, dtype="bfloat16")
    code = (
        "from repro.core.config import GemminiConfig\n"
        "from repro.tune import schedules\n"
        "cfg = GemminiConfig(input_dtype='bf16', acc_dtype='fp32', "
        "output_dtype='bf16')\n"
        "print(schedules.attn_cache_key(cfg, 2, 128, 512, 8, 2, 64, "
        "causal=True, window=256, dtype='bfloat16'))\n")
    import os
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, check=True).stdout.strip()
    assert out == here
    # sensitive to masking structure, shape, and dtype
    assert here != schedules.attn_cache_key(cfg, 2, 128, 512, 8, 2, 64,
                                            causal=False, window=256,
                                            dtype="bfloat16")
    assert here != schedules.attn_cache_key(cfg, 2, 128, 512, 8, 2, 64,
                                            causal=True, window=None,
                                            dtype="bfloat16")
    assert here != schedules.attn_cache_key(cfg, 2, 128, 512, 8, 2, 64,
                                            causal=True, window=256,
                                            dtype="float32")
    # and distinct from a conv/gemm key built on the same config
    assert here != schedules.conv_cache_key(cfg, 2, 128, 512, 8, 2, 6, 4,
                                            stride=1, padding=0,
                                            has_bias=True)


def test_conv_fingerprint_stable_across_processes(tmp_cache):
    from repro.tune import schedules
    cfg = GemminiConfig()
    here = schedules.conv_cache_key(cfg, 2, 28, 28, 64, 96, 3, 3, stride=2,
                                    padding=1, has_bias=True)
    code = (
        "from repro.core.config import GemminiConfig\n"
        "from repro.tune import schedules\n"
        "print(schedules.conv_cache_key(GemminiConfig(), 2, 28, 28, 64, 96, "
        "3, 3, stride=2, padding=1, has_bias=True))\n")
    import os
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, check=True).stdout.strip()
    assert out == here
    assert here != schedules.conv_cache_key(cfg, 2, 28, 28, 64, 96, 3, 3,
                                            stride=1, padding=1,
                                            has_bias=True)


def test_resolve_attn_cached_never_measures(tmp_cache, monkeypatch):
    from repro.tune import schedules, tuner
    cfg = GemminiConfig(**ACFG)
    key = schedules.attn_cache_key(cfg, 1, 256, 256, 8, 2, 64, causal=True,
                                   window=None, dtype="bfloat16")
    tcache.get_cache().store_schedule(key, {"block_q": 128, "block_k": 64})

    def boom(*a, **kw):
        raise AssertionError("cached mode must not measure")
    monkeypatch.setattr(measure, "measure_attn_schedule", boom)
    monkeypatch.setattr(measure, "measure_conv_schedule", boom)

    flags.set_flag("tune_mode", "cached")
    hit = tuner.resolve_attn_schedule(cfg, 1, 256, 256, 8, 2, 64,
                                      dtype="bfloat16")
    assert (hit.block_q, hit.block_k) == (128, 64)
    # miss falls back to the static default, still without measuring
    miss = tuner.resolve_attn_schedule(cfg, 1, 512, 512, 8, 2, 64,
                                       dtype="bfloat16")
    assert (miss.block_q, miss.block_k) == \
        (schedules.DEFAULT_BLOCK_Q, schedules.DEFAULT_BLOCK_K)
    cmiss = tuner.resolve_conv_schedule(cfg, 1, 28, 28, 64, 96, 3, 3)
    assert cmiss.co_tile == schedules.DEFAULT_CO_TILE


def test_resolve_attn_full_tunes_once_then_hits(tmp_cache, monkeypatch):
    from repro.tune import tuner
    cfg = GemminiConfig(**ACFG)
    calls = {"n": 0}
    real = measure.measure_attn_schedule

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)
    monkeypatch.setattr(measure, "measure_attn_schedule", counting)

    flags.set_flag("tune_mode", "full")
    s1 = tuner.resolve_attn_schedule(cfg, 1, 64, 64, 4, 2, 32,
                                     dtype="float32")
    assert calls["n"] > 0
    first = calls["n"]
    s2 = tuner.resolve_attn_schedule(cfg, 1, 64, 64, 4, 2, 32,
                                     dtype="float32")
    assert calls["n"] == first           # second resolve: pure cache hit
    assert s1 == s2
    with open(tmp_cache) as f:
        raw = json.load(f)
    assert any("block_q" in e for e in raw["plans"].values())


def test_resolve_conv_full_tunes_once_then_hits(tmp_cache, monkeypatch):
    from repro.tune import tuner
    cfg = GemminiConfig()
    calls = {"n": 0}
    real = measure.measure_conv_schedule

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)
    monkeypatch.setattr(measure, "measure_conv_schedule", counting)

    flags.set_flag("tune_mode", "full")
    s1 = tuner.resolve_conv_schedule(cfg, 1, 10, 10, 8, 20, 3, 3, padding=1)
    assert calls["n"] > 0
    first = calls["n"]
    s2 = tuner.resolve_conv_schedule(cfg, 1, 10, 10, 8, 20, 3, 3, padding=1)
    assert calls["n"] == first
    assert s1 == s2


def test_ops_flash_attention_consults_tuner_ragged(tmp_cache):
    """ctx.flash_attention resolves a tuned (block_q, block_k) from the
    cache and matches the oracle on a ragged tq != tk shape."""
    from repro.kernels import ref as kref
    from repro.tune import schedules
    cfg = GemminiConfig(**ACFG)
    rng = np.random.default_rng(0)
    b, tq, tk, h, kvh, d = 1, 100, 192, 4, 2, 32
    key = schedules.attn_cache_key(cfg, b, tq, tk, h, kvh, d, causal=True,
                                   window=None, dtype="float32")
    tcache.get_cache().store_schedule(key, {"block_q": 32, "block_k": 64})
    flags.set_flag("tune_mode", "cached")
    q = jnp.asarray(rng.standard_normal((b, tq, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, tk, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, tk, kvh, d)), jnp.float32)
    pc = tcache.get_cache()
    hits0 = pc.hits
    y = ExecutionContext(cfg=cfg, backend="interpret").flash_attention(
        q, k, v, causal=True)
    assert pc.hits == hits0 + 1          # resolved from the seeded entry
    yr = kref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_ops_conv_consults_tuner_ragged_co(tmp_cache):
    """ctx.conv2d(fused=True) resolves a tuned co_tile from the cache and
    matches the oracle with co % co_tile != 0."""
    from repro.kernels import ref as kref
    from repro.core.config import Activation
    from repro.tune import schedules
    cfg = GemminiConfig()
    rng = np.random.default_rng(0)
    n, h, w, ci, co, kh, kw = 1, 10, 10, 8, 20, 3, 3
    key = schedules.conv_cache_key(cfg, n, h, w, ci, co, kh, kw, stride=1,
                                   padding=1, has_bias=True)
    tcache.get_cache().store_schedule(key, {"co_tile": 8})
    flags.set_flag("tune_mode", "cached")
    x = jnp.asarray(rng.integers(-64, 64, (n, h, w, ci)), jnp.int8)
    wt = jnp.asarray(rng.integers(-32, 32, (kh, kw, ci, co)), jnp.int8)
    bias = jnp.asarray(rng.integers(-500, 500, (co,)), jnp.int32)
    pc = tcache.get_cache()
    hits0 = pc.hits
    y = ExecutionContext(cfg=cfg, backend="interpret").conv2d(
        x, wt, bias, stride=1, padding=1, shift=7,
        activation=Activation.RELU, fused=True)
    assert pc.hits == hits0 + 1
    yr = kref.conv2d_ref(x, wt, bias, stride=1, padding=1,
                         acc_dtype=jnp.int32, out_dtype=jnp.int8, shift=7,
                         activation=Activation.RELU)
    assert bool(jnp.all(y == yr))


def test_warm_then_serve_zero_misses(tmp_cache):
    """Acceptance: full-mode warm, then the serve request path -- a real
    model forward through the engine (biased qwen QKV included) plus the
    routed attention op -- reports zero cache misses.

    Regression for the warm-path has_bias bug: warming without the bias
    flag populated fingerprints the request path never hits."""
    from repro import configs, tune
    from repro.core.generator import elaborate
    from repro.models import transformer as tf
    from repro.models.transformer import model_gemm_shapes

    model_cfg = configs.get_smoke("qwen1.5-4b")
    cfg = GemminiConfig(**ACFG)
    # The regression precondition: biased projections exist and are flagged.
    gshapes = model_gemm_shapes(model_cfg, 2, 16)
    assert any(bias for (_, _, _, bias) in gshapes)

    flags.set_flag("tune_mode", "full")
    stats = tune.warm_model_plans(cfg, model_cfg, batch=2, seq=16)
    assert stats["cache_misses"] == stats["shapes"]   # cold: everything tuned

    flags.set_flag("tune_mode", "cached")
    pc = tcache.get_cache()
    h0, m0 = pc.hits, pc.misses
    engine = elaborate(cfg, "interpret")
    params = tf.init_params(jax.random.PRNGKey(0), model_cfg)
    toks = jnp.zeros((2, 16), jnp.int32)
    logits = tf.forward(engine, params, model_cfg, toks)
    assert bool(jnp.all(jnp.isfinite(jnp.asarray(logits, jnp.float32))))
    # decode-shaped GEMMs (M = batch) were warmed too
    for (m, n, k, bias) in gshapes:
        tuner.resolve_plan(cfg, m, n, k, has_bias=bias)
    # the routed attention op resolves its warmed schedule
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 16, model_cfg.n_heads,
                                         model_cfg.head_dim)), jnp.bfloat16)
    kv = jnp.asarray(rng.standard_normal((2, 16, model_cfg.n_kv_heads,
                                          model_cfg.head_dim)), jnp.bfloat16)
    ExecutionContext(cfg=cfg, backend="interpret").flash_attention(
        q, kv, kv, causal=True)
    assert pc.misses == m0, "request path missed a warmed schedule"
    assert pc.hits > h0


def test_time_callable_syncs_and_reports_min_and_mean():
    t = measure.time_callable(lambda x: x * 2, jnp.ones((8, 8)), iters=4)
    assert t["min_us"] > 0
    assert t["mean_us"] >= t["min_us"]
    assert int(t["iters"]) == 4


def test_warm_model_plans_smoke(tmp_cache):
    """Whole-model warm pass touches every projection + attention shape
    exactly once."""
    from repro import configs, tune
    flags.set_flag("tune_mode", "cached")
    model_cfg = configs.get_smoke("gemma3-1b")
    cfg = GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                        output_dtype="bf16")
    stats = tune.warm_model_plans(cfg, model_cfg, batch=2, seq=16)
    assert stats["gemm_shapes"] > 0
    assert stats["attn_shapes"] > 0       # gemma3: local + global layers
    assert stats["shapes"] == stats["gemm_shapes"] + stats["attn_shapes"]
    assert stats["cache_misses"] == stats["shapes"]  # cold cache, no tuning


def test_warm_model_plans_shard_aware(tmp_cache):
    """n_shards warms the per-device M (mesh-split batch), not the global."""
    from repro import configs, tune
    from repro.models.transformer import model_gemm_shapes
    flags.set_flag("tune_mode", "cached")
    model_cfg = configs.get_smoke("gemma3-1b")
    cfg = GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                        output_dtype="bf16")
    stats = tune.warm_model_plans(cfg, model_cfg, batch=8, seq=16,
                                  n_shards=4, include_decode=False)
    # identical to warming the per-device batch directly
    per_dev = model_gemm_shapes(model_cfg, 2, 16, include_decode=False)
    assert stats["gemm_shapes"] == len(per_dev)
    assert all(m == 2 * 16 for (m, _, _, _) in per_dev)
