"""Mamba-2 SSD: Pallas chunked kernel + XLA chunked path vs naive scan."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import mamba2 as m2
from repro.kernels import ref
from repro.models import ssm


def _inputs(rng, bsz, t, h, p, g, n):
    x = jnp.asarray(rng.standard_normal((bsz, t, h, p)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.standard_normal((bsz, t, h)) * 0.5,
                             jnp.float32)) + 0.01
    a_log = jnp.asarray(rng.standard_normal((h,)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((bsz, t, g, n)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((bsz, t, g, n)) * 0.3, jnp.float32)
    d_skip = jnp.asarray(rng.standard_normal((h,)) * 0.5, jnp.float32)
    return x, dt, a_log, b, c, d_skip


CASES = [
    # bsz, t, h, p, g, n, chunk
    (2, 64, 4, 16, 2, 32, 16),
    (1, 100, 2, 8, 1, 16, 32),     # ragged T / chunk
    (1, 48, 8, 32, 8, 64, 48),     # G == H (no grouping)
    (2, 33, 4, 8, 1, 8, 16),       # small + ragged
]


@pytest.mark.parametrize("case", CASES)
def test_pallas_ssd_vs_naive(rng, case):
    bsz, t, h, p, g, n, chunk = case
    x, dt, a_log, b, c, d_skip = _inputs(rng, bsz, t, h, p, g, n)
    y = m2.ssd(x, dt, a_log, b, c, d_skip=d_skip, chunk=chunk,
               interpret=True)
    yr = ref.ssd_ref(x, dt, a_log, b, c, d_skip=d_skip)
    rel = float(jnp.max(jnp.abs(y - yr))) / float(jnp.max(jnp.abs(yr)))
    assert rel < 1e-4


@pytest.mark.parametrize("chunk", [8, 32, 128])
def test_xla_chunked_chunk_size_invariant(rng, chunk):
    x, dt, a_log, b, c, d_skip = _inputs(rng, 1, 96, 4, 16, 2, 32)
    y = ssm.ssd_chunked_xla(x, dt, a_log, b, c, d_skip=d_skip, chunk=chunk)
    yr = ref.ssd_ref(x, dt, a_log, b, c, d_skip=d_skip)
    rel = float(jnp.max(jnp.abs(y - yr))) / float(jnp.max(jnp.abs(yr)))
    assert rel < 1e-4


def test_decode_step_matches_scan(rng):
    """Sequential single-token decode == full-sequence recurrence."""
    bsz, t, h, p, g, n = 2, 24, 4, 8, 2, 16
    x, dt, a_log, b, c, d_skip = _inputs(rng, bsz, t, h, p, g, n)
    y_full = ref.ssd_ref(x, dt, a_log, b, c, d_skip=d_skip)
    state = jnp.zeros((bsz, h, n, p), jnp.float32)
    ys = []
    for i in range(t):
        y_t, state = ssm.ssd_decode_step(state, x[:, i], dt[:, i], a_log,
                                         b[:, i], c[:, i], d_skip=d_skip)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_full),
                               rtol=1e-4, atol=1e-5)


def test_prefill_state_handoff(rng):
    """_final_state after prefill == state after running decode over the
    prompt (the prefill->decode cache handoff is exact)."""
    bsz, t, h, p, g, n = 1, 40, 2, 8, 1, 16
    x, dt, a_log, b, c, _ = _inputs(rng, bsz, t, h, p, g, n)
    _, fs = ssm._final_state(x, dt, a_log, b, c)
    state = jnp.zeros((bsz, h, n, p), jnp.float32)
    for i in range(t):
        _, state = ssm.ssd_decode_step(state, x[:, i], dt[:, i], a_log,
                                       b[:, i], c[:, i])
    np.testing.assert_allclose(np.asarray(fs), np.asarray(state),
                               rtol=1e-4, atol=1e-5)


def test_kernel_final_state(rng):
    bsz, t, h, p, g, n = 1, 32, 2, 8, 1, 16
    x, dt, a_log, b, c, _ = _inputs(rng, bsz, t, h, p, g, n)
    _, fs_ref = ssm._final_state(x, dt, a_log, b, c)
    _, fs = m2.ssd(x, dt, a_log, b, c, chunk=16, interpret=True,
                   return_final_state=True)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fs_ref),
                               rtol=1e-4, atol=1e-5)


def test_causal_conv1d_state(rng):
    """Segmented conv (with carried state) == full-sequence conv."""
    from repro.models.layers import causal_conv1d
    b, t, c, k = 2, 20, 6, 4
    x = jnp.asarray(rng.standard_normal((b, t, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, c)), jnp.float32)
    y_full, _ = causal_conv1d(x, w)
    y1, st = causal_conv1d(x[:, :12], w)
    y2, _ = causal_conv1d(x[:, 12:], w, st)
    y_seg = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(y_seg), np.asarray(y_full),
                               rtol=1e-5, atol=1e-6)
