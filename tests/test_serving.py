"""Serving subsystem: paged KV allocator, paged-attention kernels, the
continuous-batching engine vs the static reference path, and the paged
schedule's ride through the tuner cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flags
from repro.core.config import GemminiConfig
from repro.core.generator import elaborate
from repro.kernels import attention as ak
from repro.kernels import ref
from repro.models import attention as mattn
from repro.models import transformer as tf
from repro.serving import ContinuousScheduler, PagedKVAllocator, Request
from repro.serving.engine import ServingEngine
from repro.serving.paged_cache import pages_for


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------
def test_alloc_free_reuse():
    al = PagedKVAllocator(n_pages=8, page_size=4, max_pages_per_seq=4)
    a = al.alloc_slot(0, 9)                    # 3 pages
    assert a is not None and len(a) == 3
    assert al.used_pages == 3 and al.free_pages == 5
    b = al.alloc_slot(1, 4)                    # 1 page
    assert len(b) == 1 and set(a).isdisjoint(b)
    assert al.free_slot(0) == 3
    assert al.free_pages == 7
    # LIFO free list: the just-freed pages are handed out next (reuse)
    c = al.alloc_slot(2, 12)
    assert set(c) == set(a)


def test_alloc_capacity_exhaustion():
    al = PagedKVAllocator(n_pages=4, page_size=4, max_pages_per_seq=4)
    assert al.alloc_slot(0, 12) is not None    # 3 of 4 pages
    assert not al.can_admit(8)
    assert al.alloc_slot(1, 8) is None         # needs 2, only 1 free
    assert al.alloc_slot(1, 3) is not None     # 1 page still fits
    assert al.free_pages == 0
    assert al.extend_slot(1) is None           # arena dry
    # per-sequence cap is a distinct failure: pages exist but the request
    # is at its context limit
    al2 = PagedKVAllocator(n_pages=8, page_size=4, max_pages_per_seq=2)
    al2.alloc_slot(0, 8)
    assert al2.extend_slot(0) is None and al2.free_pages == 6
    assert pages_for(0, 4) == 0 and pages_for(1, 4) == 1


def test_defrag_compacts_and_rewrites_tables():
    al = PagedKVAllocator(n_pages=8, page_size=2, max_pages_per_seq=4)
    al.alloc_slot(0, 4)
    al.alloc_slot(1, 4)
    al.alloc_slot(2, 2)
    al.free_slot(1)                            # hole in the middle
    before = {s: al.slot_pages(s) for s in (0, 2)}
    perm = al.defrag()
    after = {s: al.slot_pages(s) for s in (0, 2)}
    # live pages now occupy [0, used) and tables follow the permutation
    live = sorted(p for pages in after.values() for p in pages)
    assert live == list(range(al.used_pages))
    for s in (0, 2):
        assert [int(perm[p]) for p in before[s]] == after[s]
    # allocator still functional post-defrag
    assert al.alloc_slot(3, 6) is not None


def test_alloc_hold_release():
    """hold_pages withholds free pages from every admission/alloc path
    (the fault injector's arena-pressure lever) and release_held restores
    them exactly; holds clamp to the free list and stack."""
    al = PagedKVAllocator(n_pages=8, page_size=4, max_pages_per_seq=8)
    al.alloc_slot(0, 8)                        # 2 pages live, 6 free
    assert al.hold_pages(4) == 4
    assert al.held_pages == 4 and al.free_pages == 2
    assert not al.can_admit(12)                # 3 pages > 2 visible
    assert al.alloc_slot(1, 12) is None
    assert al.alloc_slot(1, 8) is not None     # 2 pages still fit
    # stacking + clamping: only 0 free left, an oversized hold is bounded
    assert al.hold_pages(5) == 0 == al.free_pages
    assert al.extend_slot(1) is None           # arena looks dry
    assert al.release_held() == 4
    assert al.held_pages == 0 and al.free_pages == 4
    assert al.extend_slot(1) is not None       # pressure gone
    # no page was lost or duplicated across the hold cycle
    live = {p for s in (0, 1) for p in al.slot_pages(s)}
    assert len(live) == al.used_pages == 5
    assert al.free_pages + al.used_pages == 8


def test_alloc_defrag_releases_holds():
    """Defrag mid-pressure: held pages are returned before the free list
    is rebuilt (a surviving hold would alias re-issued pages), the
    permutation stays valid for the live slots, and the whole arena is
    accounted for afterwards."""
    al = PagedKVAllocator(n_pages=8, page_size=2, max_pages_per_seq=4)
    al.alloc_slot(0, 4)
    al.alloc_slot(1, 4)
    al.alloc_slot(2, 2)
    al.free_slot(1)                            # hole mid-arena
    assert al.hold_pages(2) == 2               # eviction-era pressure
    before = {s: al.slot_pages(s) for s in (0, 2)}
    perm = al.defrag()
    assert al.held_pages == 0                  # holds released, not leaked
    after = {s: al.slot_pages(s) for s in (0, 2)}
    live = sorted(p for pages in after.values() for p in pages)
    assert live == list(range(al.used_pages))
    for s in (0, 2):
        assert [int(perm[p]) for p in before[s]] == after[s]
    assert al.free_pages + al.used_pages == 8
    # every formerly-held page is allocatable again
    assert al.alloc_slot(3, 8) is not None     # needs 4 of the 5 free


def test_defrag_with_held_and_refcount_shared_pages():
    """PR-6 alias-safe defrag/free-list rebuild, now against the ISSUE-9
    lifecycle state: a physical page shared CoW across two tables (and the
    prefix index) must move exactly ONCE -- not be split into two copies
    or double-counted -- and a held page must not be resurrected into the
    rebuilt free list while pressure is on the old ids."""
    al = PagedKVAllocator(n_pages=8, page_size=4, max_pages_per_seq=8)
    a = al.alloc_slot(0, 12)                   # 3 pages
    assert al.publish_prefix(b"k0", a[0]) and al.publish_prefix(b"k1", a[1])
    hits = al.match_prefix([b"k0", b"k1"])
    assert hits == a[:2]
    b = al.alloc_slot_shared(1, 16, hits)      # shares 2, allocs 2 fresh
    assert b is not None and b[:2] == a[:2]
    al.free_slot(0)                            # a[2] freed; a[:2] survive
    al.check()
    assert al.hold_pages(1) == 1               # pressure during defrag
    before = al.slot_pages(1)
    perm = al.defrag()
    al.check()                                 # partition + refcounts exact
    assert al.held_pages == 0                  # released, never resurrected
    after = al.slot_pages(1)
    # the shared pages moved once: table follows the permutation, stays
    # a single physical page per logical position (no split, no dupe)
    assert [int(perm[p]) for p in before] == after
    assert len(set(after)) == 4
    assert sorted(after) == list(range(4))     # compacted to the front
    # the prefix index was remapped with the same permutation: a match
    # still lands on the (moved) shared pages
    assert al.match_prefix([b"k0", b"k1"]) == after[:2]
    # eviction refuses to free the still-indexed pages; index retains them
    al.free_slot(1)
    al.check()
    assert al.prefix_index_pages == 2
    assert al.free_pages == al.n_pages - 2
    # and they remain reclaimable: a full-arena ask flushes the index
    assert al.can_admit(8 * 4)
    assert al.alloc_slot(2, 8 * 4) is not None
    al.check()
    assert al.prefix_index_pages == 0


# ---------------------------------------------------------------------------
# paged attention numerics
# ---------------------------------------------------------------------------
def _scattered_case(rng, b, h, kvh, d, page, mp, lens, poison=np.nan):
    """Contiguous per-request K/V plus the equivalent shuffled page pools."""
    n_pool = b * mp + 2
    pool_k = np.full((kvh, n_pool, page, d), poison, np.float32)
    pool_v = np.full((kvh, n_pool, page, d), poison, np.float32)
    tables = np.zeros((b, mp), np.int32)
    free = list(rng.permutation(n_pool))
    kc = rng.standard_normal((b, mp * page, kvh, d)).astype(np.float32)
    vc = rng.standard_normal((b, mp * page, kvh, d)).astype(np.float32)
    for bb in range(b):
        for j in range(pages_for(int(lens[bb]), page)):
            pid = free.pop()
            tables[bb, j] = pid
            pool_k[:, pid] = kc[bb, j * page:(j + 1) * page].transpose(1, 0, 2)
            pool_v[:, pid] = vc[bb, j * page:(j + 1) * page].transpose(1, 0, 2)
    return kc, vc, pool_k, pool_v, tables


@pytest.mark.parametrize("h,kvh,win,cap", [(4, 2, None, None), (4, 1, 24, None),
                                           (8, 8, None, 30.0)])
def test_paged_kernel_vs_oracle(rng, h, kvh, win, cap):
    """The Pallas paged-decode kernel (interpret mode) matches the dense
    oracle on scattered, NaN-poisoned pools: dead pages are skipped, the
    partial tail page is masked."""
    b, d, page, mp = 3, 32, 16, 5
    lens = np.array([37, 1, 80], np.int32)       # partial / tiny / full
    kc, vc, pk, pv, tables = _scattered_case(rng, b, h, kvh, d, page, mp,
                                             lens)
    q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
    y = ak.paged_decode_attention(q, jnp.asarray(pk), jnp.asarray(pv),
                                  jnp.asarray(tables), jnp.asarray(lens),
                                  window=win, softcap=cap, interpret=True)
    for bb in range(b):
        L = int(lens[bb])
        yr = ref.mha_ref(q[bb:bb + 1], jnp.asarray(kc[bb:bb + 1, :L]),
                         jnp.asarray(vc[bb:bb + 1, :L]), causal=True,
                         window=win, softcap=cap)
        np.testing.assert_allclose(np.asarray(y[bb]), np.asarray(yr[0]),
                                   rtol=2e-5, atol=2e-5)


def test_paged_xla_equals_dense_decode(rng):
    """The explicit-gather XLA path is exactly the dense decode_attention
    computation (same einsums/mask/softmax), request by request -- zeros
    in unwritten pool entries, as the engine allocates them."""
    b, h, kvh, d, page, mp = 2, 4, 2, 16, 8, 4
    lens = np.array([19, 27], np.int32)
    kc, vc, pk, pv, tables = _scattered_case(rng, b, h, kvh, d, page, mp,
                                             lens, poison=0.0)
    q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
    cache = mattn.PagedKVCache(jnp.asarray(pk), jnp.asarray(pv),
                               jnp.asarray(tables), jnp.asarray(lens), page)
    y = mattn.paged_decode_attention_xla(q, cache, window=8)
    for bb in range(b):
        dense = mattn.KVCache(jnp.asarray(kc[bb:bb + 1]),
                              jnp.asarray(vc[bb:bb + 1]))
        yd = mattn.decode_attention(q[bb:bb + 1], dense,
                                    jnp.int32(int(lens[bb]) - 1), window=8)
        np.testing.assert_array_equal(np.asarray(y[bb]), np.asarray(yd[0]))


def test_paged_xla_grouped_decode_flag_parity(rng):
    """The gqa_grouped_decode flag branch of the paged gather path stays
    bit-identical to dense decode_attention under the same flag (the
    engine-vs-reference exact-match contract must hold either way)."""
    b, h, kvh, d, page, mp = 2, 4, 2, 16, 8, 3
    lens = np.array([11, 20], np.int32)
    kc, vc, pk, pv, tables = _scattered_case(rng, b, h, kvh, d, page, mp,
                                             lens, poison=0.0)
    q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
    cache = mattn.PagedKVCache(jnp.asarray(pk), jnp.asarray(pv),
                               jnp.asarray(tables), jnp.asarray(lens), page)
    prev = flags.get("gqa_grouped_decode")
    flags.set_flag("gqa_grouped_decode", True)
    try:
        y = mattn.paged_decode_attention_xla(q, cache)
        for bb in range(b):
            dense = mattn.KVCache(jnp.asarray(kc[bb:bb + 1]),
                                  jnp.asarray(vc[bb:bb + 1]))
            yd = mattn.decode_attention(q[bb:bb + 1], dense,
                                        jnp.int32(int(lens[bb]) - 1))
            np.testing.assert_array_equal(np.asarray(y[bb]),
                                          np.asarray(yd[0]))
    finally:
        flags.set_flag("gqa_grouped_decode", prev)


def test_paged_update_roundtrip(rng):
    """Prefill scatter + decode scatter land tokens at the right logical
    positions; inactive slots spill to the trash page only."""
    kvh, d, page, np_pages, mp, slots = 2, 8, 4, 6, 3, 2
    pool = jnp.zeros((kvh, np_pages + 1, page, d), jnp.float32)
    cache = mattn.PagedKVCache(pool, pool, jnp.asarray([[3, 1, 0], [2, 4, 0]],
                                                       jnp.int32),
                               jnp.asarray([5, 0], jnp.int32), page)
    kc = jnp.asarray(rng.standard_normal((1, 6, kvh, d)), jnp.float32)
    up = mattn.paged_update_prefill(cache, kc, kc, cache.tables[0])
    # position 5 -> page tables[0][1]=1, offset 1
    np.testing.assert_array_equal(np.asarray(up.k[:, 1, 1]),
                                  np.asarray(kc[0, 5]))
    # decode write: slot0 at len=5 -> page 1 offset 1; slot1 inactive ->
    # trash page (id np_pages), lengths frozen
    k1 = jnp.asarray(rng.standard_normal((slots, 1, kvh, d)), jnp.float32)
    dec = mattn.paged_update_decode(
        cache._replace(lengths=jnp.asarray([5, 0], jnp.int32)), k1, k1,
        jnp.asarray([True, False]), np_pages)
    np.testing.assert_array_equal(np.asarray(dec.k[:, 1, 1]),
                                  np.asarray(k1[0, 0]))
    np.testing.assert_array_equal(np.asarray(dec.k[:, np_pages, 0]),
                                  np.asarray(k1[1, 0]))
    assert list(np.asarray(dec.lengths)) == [6, 0]


# ---------------------------------------------------------------------------
# scheduler policy
# ---------------------------------------------------------------------------
def _mk_req(rid, plen, gen=4):
    return Request(rid=rid, prompt=np.zeros((plen,), np.int32),
                   max_new_tokens=gen)


def test_admission_token_budget():
    al = PagedKVAllocator(n_pages=64, page_size=4, max_pages_per_seq=16)
    sc = ContinuousScheduler(al, n_slots=4, prefill_token_budget=10)
    for i in range(4):
        sc.submit(_mk_req(i, 8))
    admitted = sc.admissions()
    # first admission always lands; the second (8 + 8 > 10) must wait
    assert [r.rid for (r, _, _) in admitted] == [0, ]
    assert len(sc.queue) == 3
    assert [r.rid for (r, _, _) in sc.admissions()] == [1, ]


def test_preemption_evicts_youngest_and_requeues():
    al = PagedKVAllocator(n_pages=4, page_size=4, max_pages_per_seq=4)
    sc = ContinuousScheduler(al, n_slots=2, prefill_token_budget=1 << 20)
    sc.submit(_mk_req(0, 8))                   # 2 pages
    sc.submit(_mk_req(1, 8))                   # 2 pages
    (r0, s0, _), (r1, s1, _) = sc.admissions()
    r0.cache_len, r1.cache_len = 8, 8          # both at a page boundary
    new_pages, evicted, truncated = sc.ensure_decode_capacity()
    # arena dry: the youngest (r1) is evicted so the oldest can grow
    assert evicted == [r1] and not truncated
    assert r1.state == "queued" and r1.n_preempted == 1
    assert [slot for (slot, _) in new_pages] == [s0]
    assert al.slot_pages(s0) and len(al.slot_pages(s0)) == 3


def test_unservable_request_rejected_not_livelocked():
    """A request whose recompute prompt regrew past the arena is rejected
    at admission (engine finishes it truncated) instead of head-of-line
    blocking the queue forever."""
    al = PagedKVAllocator(n_pages=2, page_size=4, max_pages_per_seq=8)
    sc = ContinuousScheduler(al, n_slots=2, prefill_token_budget=1 << 20)
    grown = _mk_req(0, 4)
    grown.generated = [1] * 8              # preempted after 8 tokens: 12 > 8
    ok = _mk_req(1, 4)
    sc.submit(grown)
    sc.submit(ok)
    admitted = sc.admissions()
    assert [r.rid for (r, _, _) in admitted] == [1]
    assert sc.rejected == [grown]


def test_sole_runner_truncates_at_capacity():
    al = PagedKVAllocator(n_pages=2, page_size=4, max_pages_per_seq=8)
    sc = ContinuousScheduler(al, n_slots=2, prefill_token_budget=1 << 20)
    sc.submit(_mk_req(0, 8))
    (req, _, _), = sc.admissions()
    req.cache_len = 8
    _, evicted, truncated = sc.ensure_decode_capacity()
    assert truncated == [req] and not evicted


def test_admission_policy_priority_order():
    """priority admits highest class first (deadline, then age tiebreak);
    FIFO (default) is untouched."""
    al = PagedKVAllocator(n_pages=64, page_size=4, max_pages_per_seq=16)
    sc = ContinuousScheduler(al, n_slots=4, prefill_token_budget=1 << 20,
                             admission_policy="priority")
    r0, r1, r2 = _mk_req(0, 4), _mk_req(1, 4), _mk_req(2, 4)
    r1.priority, r2.priority = 5, 5
    r2.deadline = 10.0                       # same class, tighter SLO
    for r in (r0, r1, r2):
        sc.submit(r)
    assert [r.rid for (r, _, _) in sc.admissions()] == [2, 1, 0]


def test_admission_policy_deadline_edf_and_preempted_first():
    """deadline = earliest-deadline-first, deadline-less requests last;
    a preempted request outranks every queued one under any policy."""
    al = PagedKVAllocator(n_pages=64, page_size=4, max_pages_per_seq=16)
    sc = ContinuousScheduler(al, n_slots=4, prefill_token_budget=1 << 20,
                             admission_policy="deadline")
    r0, r1, r2 = _mk_req(0, 4), _mk_req(1, 4), _mk_req(2, 4)
    r0.deadline, r1.deadline = 50.0, 20.0    # r2: best-effort
    for r in (r0, r1, r2):
        sc.submit(r)
    (a, _, _), (b, _, _), (c, _, _) = sc.admissions()
    assert [a.rid, b.rid, c.rid] == [1, 0, 2]
    sc.preempt(c)                            # best-effort, but holds debt
    order = sc.admissions()
    assert [r.rid for (r, _, _) in order] == [2]


def test_admission_tie_break_deterministic_rid_order():
    """Equal-deadline EDF and equal-priority classes tie-break on rid:
    with identical logical timestamps (the model checker's LogicalClock
    makes timestamp collisions the common case, and batch submitters hit
    it in production too) the admission order must be invariant under
    queue permutation."""
    rng = np.random.default_rng(1234)
    for policy in ("priority", "deadline"):
        for _ in range(5):
            al = PagedKVAllocator(n_pages=64, page_size=4,
                                  max_pages_per_seq=16)
            sc = ContinuousScheduler(al, n_slots=8,
                                     prefill_token_budget=1 << 20,
                                     admission_policy=policy,
                                     clock=lambda: 0.0)
            reqs = [_mk_req(i, 4) for i in range(6)]
            for r in reqs:
                r.priority, r.deadline = 3, 42.0
            for i in rng.permutation(6):
                sc.submit(reqs[i])
            assert [r.rid for (r, _, _) in sc.admissions()] == list(range(6))


def test_admission_policy_unknown_rejected():
    al = PagedKVAllocator(n_pages=8, page_size=4, max_pages_per_seq=4)
    with pytest.raises(ValueError):
        ContinuousScheduler(al, n_slots=2, admission_policy="sjf")


def test_engine_priority_admission_end_to_end(rng):
    """Under a 1-slot engine a high-priority late submission decodes first
    and produces exactly the same tokens as its FIFO run (admission order
    changes scheduling, never numerics)."""
    params = tf.init_params(jax.random.PRNGKey(3), _TINY)
    prompts = [rng.integers(0, _TINY.vocab, (12,)).astype(np.int32)
               for _ in range(3)]
    by_policy = {}
    for policy in ("fifo", "priority"):
        eng = ServingEngine(_TINY, max_slots=1, max_context=64,
                            page_size=8, params=params,
                            admission_policy=policy)
        reqs = [eng.submit(p, 4, priority=i) for i, p in enumerate(prompts)]
        eng.run()
        by_policy[policy] = {r.rid: list(np.asarray(r.generated).ravel())
                             for r in reqs}
        if policy == "priority":
            # highest priority (last submitted) finished first
            finish = sorted(reqs, key=lambda r: r.t_finished)
            assert [r.rid for r in finish] == [2, 1, 0]
    assert by_policy["fifo"] == by_policy["priority"]


# ---------------------------------------------------------------------------
# engine end-to-end vs the static reference path
# ---------------------------------------------------------------------------
_TINY = tf.ModelConfig(name="tiny-serve", family="dense", n_layers=2,
                       d_model=32, vocab=64, n_heads=2, n_kv_heads=1,
                       head_dim=16, d_ff=64, dtype=jnp.float32)


def _reference_tokens(model_cfg, params, prompt, gen):
    engine = elaborate(GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                     output_dtype="bf16"), "xla")
    t = len(prompt) + model_cfg.n_meta_tokens
    st = tf.init_decode_state(model_cfg, 1, t + gen, dtype=model_cfg.dtype)
    st = st._replace(pos=jnp.zeros((), jnp.int32))
    logits, st = tf.prefill_into_cache(engine, params, model_cfg,
                                       jnp.asarray(prompt[None]), st)
    toks, last = [], logits[0, t - 1]
    for _ in range(gen):
        nxt = int(jnp.argmax(last))
        toks.append(nxt)
        logits, st = tf.decode_step(engine, params, model_cfg,
                                    jnp.asarray([[nxt]], jnp.int32), st)
        last = logits[0, -1]
    return np.asarray(toks, np.int32)


def _run_vs_reference(eng, prompts, gens):
    for p, g in zip(prompts, gens):
        eng.submit(p, g)
    rep = eng.run()
    for r, p, g in zip(rep["requests"], prompts, gens):
        want = _reference_tokens(eng.model_cfg, eng.params, p, g)
        np.testing.assert_array_equal(np.asarray(r["tokens"]).ravel(), want)
    return rep


@pytest.mark.slow
def test_engine_matches_reference_greedy(rng):
    eng = ServingEngine(_TINY, max_slots=2, max_context=48, page_size=8,
                        n_pages=16, temperature=0.0, seed=0)
    prompts = [rng.integers(0, 64, (n,)).astype(np.int32)
               for n in (5, 11, 3)]
    rep = _run_vs_reference(eng, prompts, [4, 2, 6])
    s = rep["summary"]
    assert s["requests"] == 3 and s["tokens_per_s"] > 0
    assert s["p50_latency_s"] <= s["p99_latency_s"] + 1e-9
    assert s["p50_ttft_s"] <= s["p50_latency_s"] + 1e-9


@pytest.mark.slow
def test_engine_correct_under_eviction(rng):
    """A starved arena forces preemption-by-eviction mid-decode; the
    recompute restart must still produce the exact reference stream."""
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=4, temperature=0.0, seed=0)
    prompts = [rng.integers(0, 64, (n,)).astype(np.int32)
               for n in (7, 9, 6)]
    rep = _run_vs_reference(eng, prompts, [10, 9, 8])
    assert rep["summary"]["preemptions"] > 0
    assert rep["summary"]["truncated"] == 0


def test_engine_static_policy_matches_reference(rng):
    eng = ServingEngine(_TINY, max_slots=2, max_context=48, page_size=8,
                        n_pages=16, temperature=0.0, seed=0,
                        policy="static")
    prompts = [rng.integers(0, 64, (n,)).astype(np.int32)
               for n in (5, 8, 4)]
    _run_vs_reference(eng, prompts, [3, 6, 2])


def test_engine_interpret_backend_routes_pallas(rng):
    """backend="interpret" drives the Pallas flash-attention (prefill) and
    paged-decode kernels end-to-end; greedy tokens agree with the xla
    engine (f32 model, identical masked math)."""
    prompts = [rng.integers(0, 64, (n,)).astype(np.int32) for n in (5, 9)]
    reps = {}
    for backend in ("xla", "interpret"):
        eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                            n_pages=8, temperature=0.0, seed=0,
                            backend=backend)
        for p in prompts:
            eng.submit(p, 3)
        reps[backend] = [np.asarray(r["tokens"])
                         for r in eng.run()["requests"]]
    for a, b in zip(reps["xla"], reps["interpret"]):
        np.testing.assert_array_equal(a, b)


def test_engine_defrag_preserves_live_requests(rng):
    """Defrag mid-flight: pools permute, tables rewrite, decode continues
    to the exact reference stream."""
    eng = ServingEngine(_TINY, max_slots=2, max_context=48, page_size=8,
                        n_pages=16, temperature=0.0, seed=0)
    prompts = [rng.integers(0, 64, (n,)).astype(np.int32) for n in (9, 6)]
    for p in prompts:
        eng.submit(p, 5)
    eng.step()                                  # prefill + first decode
    eng.defrag()
    while eng.sched.has_work:
        eng.step()
    for r, p in zip(eng.requests, prompts):
        want = _reference_tokens(_TINY, eng.params, p, 5)
        np.testing.assert_array_equal(
            np.asarray([int(t) for t in r.generated]), want)


@pytest.mark.slow
def test_engine_defrag_under_arena_pressure(rng):
    """Defrag interleaved with injected arena exhaustion (plus the
    eviction pressure a small arena already produces): holds never leak
    into the rebuilt free list, and every stream still matches the
    fault-free reference exactly."""
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=6, temperature=0.0, seed=0,
                        faults="seed=7;arena:pages=2,start=1,max=4")
    prompts = [rng.integers(0, 64, (n,)).astype(np.int32) for n in (9, 6, 7)]
    for p in prompts:
        eng.submit(p, 6)
    eng.step()
    eng.defrag()                               # between pressured steps
    steps = 0
    while eng.sched.has_work:
        eng.step()
        steps += 1
        if steps == 2:
            eng.defrag()
        assert eng.alloc.held_pages == 0       # pressure is per-step only
    assert eng.faults.report().get("arena@arena", 0) == 4
    for r, p in zip(eng.requests, prompts):
        assert r.state == "finished"
        want = _reference_tokens(_TINY, eng.params, p, 6)
        np.testing.assert_array_equal(
            np.asarray([int(t) for t in r.generated]), want)


# ---------------------------------------------------------------------------
# chunked prefill: kernel / twin numerics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("start,tq,h,kvh,win", [(16, 8, 4, 2, None),
                                                (24, 11, 4, 1, 16),
                                                (0, 7, 8, 8, None)])
def test_paged_prefill_kernel_vs_oracle(rng, start, tq, h, kvh, win):
    """The chunked-prefill Pallas kernel (interpret mode) matches the dense
    oracle on scattered, NaN-poisoned pools: a chunk of queries at
    [start, start+tq) attends exactly the live prefix, dead pages beyond
    the frontier are skipped."""
    d, page, mp = 32, 8, 6
    lens = np.array([start + tq], np.int32)
    kc, vc, pk, pv, tables = _scattered_case(rng, 1, h, kvh, d, page, mp,
                                             lens)
    q = jnp.asarray(rng.standard_normal((1, tq, h, d)), jnp.float32)
    y = ak.paged_prefill_attention(q, jnp.asarray(pk), jnp.asarray(pv),
                                   jnp.asarray(tables[0]), jnp.int32(start),
                                   window=win, interpret=True)
    ln = int(lens[0])
    yr = ref.mha_ref(q, jnp.asarray(kc[:, :ln]), jnp.asarray(vc[:, :ln]),
                     causal=True, window=win)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_paged_prefill_xla_twin_bitwise(rng):
    """The explicit-gather XLA twin is bit-identical to the single-pass
    blockwise path for a continuation chunk's rows: same KV blocking
    anchored at 0, same op staging (the serve_decode exact-match gate with
    chunking on rests on this)."""
    h, kvh, d, page, mp, start, tq = 4, 2, 16, 8, 4, 13, 9
    lens = np.array([start + tq], np.int32)
    kc, vc, pk, pv, tables = _scattered_case(rng, 1, h, kvh, d, page, mp,
                                             lens, poison=0.0)
    q = jnp.asarray(rng.standard_normal((1, tq, h, d)), jnp.float32)
    cache = mattn.PagedKVCache(jnp.asarray(pk), jnp.asarray(pv),
                               jnp.asarray(tables),
                               jnp.asarray(lens), page)
    y = mattn.paged_prefill_attention_xla(q, cache, jnp.int32(start),
                                          window=8)
    # the single-pass reference: full-prefix blockwise, rows [start, ...)
    ln = int(lens[0])
    qfull = jnp.asarray(
        np.concatenate([rng.standard_normal((1, start, h, d)),
                        np.asarray(q)], axis=1), jnp.float32)
    yf = mattn.blockwise_attention_xla(qfull, jnp.asarray(kc[:, :ln]),
                                       jnp.asarray(vc[:, :ln]), causal=True,
                                       window=8)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yf[:, start:]))


def test_chunked_ssm_state_continuity(rng):
    """Resuming the SSD recurrent state across chunk boundaries reproduces
    the single-pass outputs and final state (tolerance: exp-of-sums
    reassociates across the boundary)."""
    from repro.models import ssm
    b, t, h, g, n, p = 2, 24, 4, 2, 8, 16
    x = jnp.asarray(rng.standard_normal((b, t, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (b, t, h)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 0.5, (h,)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, t, g, n)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, t, g, n)), jnp.float32)
    y_full = ssm.ssd_chunked_xla(x, dt, a_log, bb, cc, chunk=8)
    _, s_full = ssm._final_state(x, dt, a_log, bb, cc)
    state, ys = None, []
    for lo in (0, 9, 17):                  # non-aligned chunk boundaries
        hi = {0: 9, 9: 17, 17: t}[lo]
        sl = slice(lo, hi)
        ys.append(ssm.ssd_chunked_xla(x[:, sl], dt[:, sl], a_log,
                                      bb[:, sl], cc[:, sl], chunk=8,
                                      initial_state=state))
        _, state = ssm._final_state(x[:, sl], dt[:, sl], a_log, bb[:, sl],
                                    cc[:, sl], initial_state=state)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, axis=1)),
                               np.asarray(y_full), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_full),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# chunked prefill: scheduler chunk queue
# ---------------------------------------------------------------------------
def test_prefill_schedule_chunk_queue_budget_and_order():
    """Continuation chunks come before new admissions; the token budget
    bounds the iteration's prefill work (first item always lands)."""
    al = PagedKVAllocator(n_pages=64, page_size=4, max_pages_per_seq=16)
    sc = ContinuousScheduler(al, n_slots=4, prefill_token_budget=8,
                             prefill_chunk=8)
    sc.submit(_mk_req(0, 20))
    w = sc.prefill_schedule()
    assert [(c.req.rid, c.start, c.true_end, c.first, c.last)
            for c in w] == [(0, 0, 8, True, False)]
    sc.submit(_mk_req(1, 4))
    w = sc.prefill_schedule()              # rid0's continuation wins the
    assert [(c.req.rid, c.start) for c in w] == [(0, 8)]   # whole budget
    # rid0's last chunk charges 4 of the 8-token budget; rid1's 4-token
    # prompt fits in the remainder and admits in the same iteration
    w = sc.prefill_schedule()
    assert [(c.req.rid, c.start, c.first, c.last) for c in w] == \
        [(0, 16, False, True), (1, 0, True, True)]
    for c in w:
        assert not sc.running[c.slot].prefilling
    assert sc.prefill_schedule() == []


def test_prefill_schedule_admit_new_false_still_continues():
    """The static barrier blocks admissions, never in-flight chunks.
    Admission always emits just the first chunk; continuations drain on
    later iterations (under a generous budget, several per iteration)."""
    al = PagedKVAllocator(n_pages=64, page_size=4, max_pages_per_seq=16)
    sc = ContinuousScheduler(al, n_slots=2, prefill_token_budget=1 << 20,
                             prefill_chunk=8)
    sc.submit(_mk_req(0, 20))
    w = sc.prefill_schedule()
    assert [(c.req.rid, c.start, c.first) for c in w] == [(0, 0, True)]
    sc.submit(_mk_req(1, 20))
    w = sc.prefill_schedule(admit_new=False)     # barrier: rid0 continues,
    assert [(c.req.rid, c.start) for c in w] == [(0, 8), (0, 16)]
    assert sc.prefill_schedule(admit_new=False) == []   # rid1 stays queued
    assert [(c.req.rid, c.start) for c in sc.prefill_schedule()] == [(1, 0)]


def test_chunk_spans_non_aligned_and_short():
    al = PagedKVAllocator(n_pages=8, page_size=8, max_pages_per_seq=8)
    sc = ContinuousScheduler(al, n_slots=1, pad_to=8, prefill_chunk=6)
    # shorter than one chunk: single span, classic bucket padding
    assert sc._chunk_spans(_mk_req(0, 5)) == [(0, 5, 8)]
    # non-page-aligned chunks; last span padded to the compile bucket,
    # capped at the single-pass footprint (roundup(14, 8) = 16, not 20)
    assert sc._chunk_spans(_mk_req(1, 14)) == [(0, 6, 6), (6, 12, 12),
                                               (12, 14, 16)]


# ---------------------------------------------------------------------------
# chunked prefill: engine end-to-end
# ---------------------------------------------------------------------------
_TINY_SSM = tf.ModelConfig(name="tiny-serve-ssm", family="ssm", n_layers=2,
                           d_model=32, vocab=64, d_state=8, ssm_head_dim=8,
                           ssm_chunk=8, dtype=jnp.float32)


@pytest.mark.slow
@pytest.mark.parametrize("chunk,page", [(8, 8), (6, 8)])
def test_chunked_engine_matches_reference(rng, chunk, page):
    """Exact token match vs the single-pass static reference with chunking
    on: prompts shorter than one chunk, exactly one chunk, and multi-chunk
    -- page-aligned and not."""
    eng = ServingEngine(_TINY, max_slots=2, max_context=48, page_size=page,
                        n_pages=16, temperature=0.0, seed=0,
                        prefill_chunk=chunk)
    prompts = [rng.integers(0, 64, (n,)).astype(np.int32)
               for n in (19, 3, chunk, 11)]
    rep = _run_vs_reference(eng, prompts, [4, 6, 3, 5])
    by_rid = {r["rid"]: r for r in rep["requests"]}
    assert by_rid[0]["prefill_chunks"] == -(-19 // chunk)
    assert by_rid[1]["prefill_chunks"] == 1          # short: classic path
    assert rep["summary"]["prefill_chunks"] >= 6
    assert rep["summary"]["p50_itl_s"] >= 0.0


def test_chunked_single_token_final_chunk(rng):
    """A final chunk of exactly ONE token (recurrent families never pad,
    so total % chunk == 1 happens) must route through the chunk path, not
    the t == 1 decode branch (whose cache has no active mask here)."""
    eng = ServingEngine(_TINY, max_slots=2, max_context=48, page_size=8,
                        n_pages=16, temperature=0.0, seed=0,
                        prefill_chunk=8)
    # force pad_to=1 so the last span is exactly one position long
    eng.sched.pad_to = 1
    prompts = [rng.integers(0, 64, (17,)).astype(np.int32)]
    rep = _run_vs_reference(eng, prompts, [4])
    assert rep["requests"][0]["prefill_chunks"] == 3


@pytest.mark.slow
def test_chunked_engine_ssm_matches_reference(rng):
    """SSM-family chunked prefill resumes the recurrent state per chunk (no
    padding, exact-length chunks) and still reproduces the reference
    stream."""
    eng = ServingEngine(_TINY_SSM, max_slots=2, max_context=48, page_size=8,
                        n_pages=16, temperature=0.0, seed=0,
                        prefill_chunk=7)
    prompts = [rng.integers(0, 64, (n,)).astype(np.int32)
               for n in (17, 4, 10)]
    rep = _run_vs_reference(eng, prompts, [5, 3, 4])
    assert rep["summary"]["prefill_chunks"] > 3


@pytest.mark.slow
def test_chunked_eviction_mid_prefill_recompute(rng):
    """A starved arena evicts the youngest runner MID-PREFILL (its pages
    and carried state are gone); the chunk-zero recompute restart still
    produces the exact reference stream."""
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=3, temperature=0.0, seed=0,
                        prefill_chunk=8)
    prompts = [rng.integers(0, 64, (7,)).astype(np.int32),
               rng.integers(0, 64, (20,)).astype(np.int32)]
    rep = _run_vs_reference(eng, prompts, [10, 4])
    assert rep["summary"]["preemptions"] > 0
    assert rep["summary"]["truncated"] == 0
    assert rep["requests"][1]["prefill_chunks"] > 3   # restarted chunks


def test_chunked_engine_interpret_backend(rng):
    """backend="interpret" drives the chunked-prefill Pallas kernel
    (block-table gather) end-to-end; greedy tokens agree with the xla
    engine."""
    prompts = [rng.integers(0, 64, (n,)).astype(np.int32) for n in (13, 4)]
    reps = {}
    for backend in ("xla", "interpret"):
        eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                            n_pages=8, temperature=0.0, seed=0,
                            backend=backend, prefill_chunk=8)
        for p in prompts:
            eng.submit(p, 3)
        reps[backend] = [np.asarray(r["tokens"])
                         for r in eng.run()["requests"]]
    for a, b in zip(reps["xla"], reps["interpret"]):
        np.testing.assert_array_equal(a, b)


def test_engine_ssm_interpret_backend_fused_kernel(rng):
    """backend="interpret" drives the fused SSD kernel on the SSM-family
    fresh-prefill path (d_skip + final recurrent state emitted in-kernel;
    SSMCache(conv, None) fresh marker); greedy tokens agree with the xla
    engine. The engine cfg is pinned f32 end-to-end so the two backends
    differ only by the kernel's (1e-7-level) reassociation -- the default
    bf16 engine would round every projection on the interpret path."""
    f32 = GemminiConfig(input_dtype="fp32", acc_dtype="fp32",
                        output_dtype="fp32")
    prompts = [rng.integers(0, 64, (n,)).astype(np.int32) for n in (9, 5)]
    reps = {}
    for backend in ("xla", "interpret"):
        eng = ServingEngine(_TINY_SSM, max_slots=2, max_context=32,
                            page_size=8, n_pages=8, temperature=0.0,
                            seed=0, backend=backend, engine_cfg=f32)
        for p in prompts:
            eng.submit(p, 3)
        reps[backend] = [np.asarray(r["tokens"])
                         for r in eng.run()["requests"]]
    for a, b in zip(reps["xla"], reps["interpret"]):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# paged schedule through the tuner
# ---------------------------------------------------------------------------
@pytest.fixture
def tmp_cache(tmp_path):
    from repro.tune import cache as tcache
    path = str(tmp_path / "plans.json")
    prev_cache = flags.get("tune_cache")
    prev_mode = flags.get("tune_mode")
    flags.set_flag("tune_cache", path)
    tcache.reset_cache()
    yield path
    flags.set_flag("tune_cache", prev_cache)
    flags.set_flag("tune_mode", prev_mode)
    tcache.reset_cache()


def test_paged_schedule_lattice_legal():
    from repro.tune import schedules
    cfg = GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                        output_dtype="bf16")
    cands = schedules.enumerate_paged_schedules(cfg, 4, 8, 2, 64, 2048)
    assert cands
    default = schedules.default_paged_schedule().effective(2048)
    assert default in cands
    for s in cands:
        assert 8 <= s.page_size <= 2048
        assert schedules.paged_attn_cycles(
            s, cfg, 4, 8, 2, 64, 2048, window=None, in_bytes=2) > 0
    # a sliding window shrinks the live page count, never breaks ranking
    c1 = schedules.paged_attn_cycles(cands[0], cfg, 4, 8, 2, 64, 2048,
                                     window=128, in_bytes=2)
    c2 = schedules.paged_attn_cycles(cands[0], cfg, 4, 8, 2, 64, 2048,
                                     window=None, in_bytes=2)
    assert c1 <= c2


def test_paged_schedule_cache_roundtrip(tmp_cache):
    from repro.tune import cache as tcache
    from repro.tune import tuner
    cfg = GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                        output_dtype="bf16")
    flags.set_flag("tune_mode", "full")
    rep = tuner.tune_paged_attention(cfg, 2, 4, 2, 32, 256, iters=1)
    assert rep.cache_key
    # a fresh cache object resolves the persisted winner without measuring
    tcache.reset_cache()
    flags.set_flag("tune_mode", "cached")
    pc = tcache.get_cache()
    hits0 = pc.hits
    sched = tuner.resolve_paged_attn_schedule(cfg, 2, 4, 2, 32, 256)
    assert pc.hits == hits0 + 1
    assert sched == rep.sched
    # a different context misses and degrades to the static default
    from repro.tune import schedules
    other = tuner.resolve_paged_attn_schedule(cfg, 2, 4, 2, 32, 512)
    assert other == schedules.default_paged_schedule().effective(512)


def test_warm_model_plans_covers_paged(tmp_cache):
    from repro import tune
    flags.set_flag("tune_mode", "full")
    cfg = GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                        output_dtype="bf16")
    stats = tune.warm_model_plans(cfg, _TINY, 1, 16, include_decode=False,
                                  paged_slots=2, paged_max_context=64)
    assert stats["paged_shapes"] == 1          # one distinct window (global)
    # warm-then-serve: the engine's page-size resolution is a pure hit
    flags.set_flag("tune_mode", "cached")
    pc = tune.get_cache()
    hits0 = pc.hits
    tune.resolve_paged_attn_schedule(cfg, 2, _TINY.n_heads,
                                     _TINY.n_kv_heads, _TINY.head_dim, 64,
                                     dtype=_TINY.dtype)
    assert pc.hits == hits0 + 1
