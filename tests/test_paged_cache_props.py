"""Property suite for the paged KV allocator's lifecycle invariants.

Random interleavings of every allocator operation -- alloc / grow /
extend / free / hold / release / defrag / publish / CoW-share / host
spill / restore -- must preserve the ownership invariants the serving
engine builds on:

* every arena page is owned by exactly one of {free list, mapped set,
  held set}, and a mapped page's refcount equals its reference count
  (#slot tables holding it + 1 if the prefix index does) and is >= 1;
* free-page accounting is exact (no page lost, duplicated, resurrected);
* defrag preserves every slot's logical slot->contents mapping and the
  prefix index's key->contents mapping (CoW aliases move exactly once);
* the host pool never exceeds its page capacity.

The first two and the last are asserted by ``PagedKVAllocator.check()``
(the in-tree oracle) after EVERY operation; contents preservation is
asserted against a shadow model: each physical page carries a stamp when
written, each slot records the stamp sequence it logically holds, and a
shared (CoW) or defrag-moved page must keep presenting the stamp it was
written with. 200+ generated op sequences (ISSUE 9 acceptance floor).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # container has no hypothesis
    from _hyp import given, settings, strategies as st

from repro.serving.paged_cache import PagedKVAllocator

N_PAGES = 12
PAGE = 4
MAX_PER_SEQ = 6
HOST_POOL = 8
SLOTS = 4
OPS_PER_SEQ = 60


class _Shadow:
    """Contents model: stamps written per physical page, logical per-slot
    views, and published key -> stamp expectations."""

    def __init__(self):
        self.mem = {}          # physical page -> stamp last written
        self.views = {}        # slot -> [stamp, ...] (logical order)
        self.pub = {}          # chain key -> stamp at publication
        self.stamp = 0

    def fresh(self):
        self.stamp += 1
        return self.stamp


def _check_contents(al: PagedKVAllocator, sh: _Shadow) -> None:
    """Every slot's physical pages must still present the stamps the slot
    logically wrote (CoW sharing and defrag must be content-invisible)."""
    for slot, stamps in sh.views.items():
        pages = al.slot_pages(slot)
        assert len(pages) == len(stamps), \
            f"slot {slot}: table length drifted"
        got = [sh.mem.get(p) for p in pages]
        assert got == stamps, f"slot {slot}: contents drifted"


def _step(al: PagedKVAllocator, sh: _Shadow, rng: np.random.Generator,
          rid_counter: list) -> None:
    op = rng.choice(["alloc", "alloc", "alloc_shared", "grow", "extend",
                     "free", "free", "publish", "publish", "match",
                     "hold", "defrag", "host_put", "host_take",
                     "host_drop"])
    free_slots = [s for s in range(SLOTS) if s not in sh.views]
    live_slots = sorted(sh.views)
    if op == "alloc" and free_slots:
        slot = int(rng.choice(free_slots))
        n_tok = int(rng.integers(1, MAX_PER_SEQ * PAGE + 1))
        pages = al.alloc_slot(slot, n_tok)
        if pages is not None:
            stamps = []
            for p in pages:                # simulate the prefill writes
                sh.mem[p] = sh.fresh()
                stamps.append(sh.mem[p])
            sh.views[slot] = stamps
    elif op == "alloc_shared" and free_slots and sh.pub:
        slot = int(rng.choice(free_slots))
        keys = list(sh.pub)
        k = int(rng.integers(1, len(keys) + 1))
        ks = [keys[i] for i in sorted(
            rng.choice(len(keys), size=k, replace=False))]
        hits = al.match_prefix(ks)
        # a hit run's contents must be exactly what was published
        for i, p in enumerate(hits):
            assert sh.mem.get(p) == sh.pub[ks[i]], \
                "prefix hit returned a rewritten page"
        lo = len(hits) * PAGE
        n_tok = int(rng.integers(lo + 1, MAX_PER_SEQ * PAGE + 1)) \
            if lo < MAX_PER_SEQ * PAGE else lo
        pages = al.alloc_slot_shared(slot, n_tok, hits)
        if pages is not None:
            stamps = [sh.mem[p] for p in hits]      # CoW: inherited content
            for p in pages[len(hits):]:
                sh.mem[p] = sh.fresh()
                stamps.append(sh.mem[p])
            sh.views[slot] = stamps
    elif op == "grow" and live_slots:
        slot = int(rng.choice(live_slots))
        n_tok = int(rng.integers(1, MAX_PER_SEQ * PAGE + 1))
        before = al.slot_pages(slot)
        new = al.grow_slot(slot, n_tok)
        if new:
            assert al.slot_pages(slot) == before + new
            for p in new:
                sh.mem[p] = sh.fresh()
                sh.views[slot].append(sh.mem[p])
        elif new is None:
            assert al.slot_pages(slot) == before, \
                "failed grow must allocate nothing"
    elif op == "extend" and live_slots:
        slot = int(rng.choice(live_slots))
        pid = al.extend_slot(slot)
        if pid is not None:
            sh.mem[pid] = sh.fresh()
            sh.views[slot].append(sh.mem[pid])
    elif op == "free" and live_slots:
        slot = int(rng.choice(live_slots))
        n = al.free_slot(slot)
        assert n == len(sh.views.pop(slot))
    elif op == "publish" and live_slots:
        slot = int(rng.choice(live_slots))
        pages = al.slot_pages(slot)
        i = int(rng.integers(0, len(pages)))
        key = rng.bytes(16)
        if al.publish_prefix(key, pages[i]):
            sh.pub[key] = sh.views[slot][i]
    elif op == "match" and sh.pub:
        keys = list(sh.pub)
        hits = al.match_prefix(keys)
        for i, p in enumerate(hits):
            assert sh.mem.get(p) == sh.pub[keys[i]]
    elif op == "hold":
        k = al.hold_pages(int(rng.integers(0, N_PAGES + 1)))
        assert al.held_pages == k
        al.check()
        assert al.release_held() == k
    elif op == "defrag":
        perm = al.defrag()
        assert sorted(int(p) for p in perm) == list(range(N_PAGES)), \
            "defrag perm is not a permutation"
        sh.mem = {int(perm[p]): s for p, s in sh.mem.items()}
    elif op == "host_put":
        rid = rid_counter[0]
        rid_counter[0] += 1
        n = int(rng.integers(1, HOST_POOL + 3))
        ok = al.host_put(rid, n, n * PAGE, {"blob": n})
        assert ok == (n <= HOST_POOL), "pool admission contract"
        if ok:
            sp = al.host_peek(rid)
            assert sp is not None and sp.n_pages == n
    elif op == "host_take":
        if rng.random() < 0.5 and al.host_used_pages:
            # take the most recent spill that still exists
            for rid in range(rid_counter[0] - 1, -1, -1):
                if al.host_peek(rid) is not None:
                    sp = al.host_take(rid)
                    assert sp is not None and al.host_peek(rid) is None
                    break
        else:
            assert al.host_take(10 ** 9) is None   # unknown rid: no-op
    elif op == "host_drop":
        al.host_drop(int(rng.integers(0, max(1, rid_counter[0]))))
    al.check()
    _check_contents(al, sh)


@settings(max_examples=200, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_lifecycle_op_interleavings(seed):
    """>= 200 random op sequences preserve every lifecycle invariant."""
    rng = np.random.default_rng(seed)
    al = PagedKVAllocator(N_PAGES, PAGE, MAX_PER_SEQ,
                          host_pool_pages=HOST_POOL)
    sh = _Shadow()
    rid_counter = [0]
    al.check()
    for _ in range(OPS_PER_SEQ):
        _step(al, sh, rng, rid_counter)
    # drain: every slot freed returns the arena to a consistent end state
    for slot in list(sh.views):
        al.free_slot(slot)
        sh.views.pop(slot)
        al.check()
    # only prefix-index residents may keep pages out of the free list now
    assert al.free_pages == N_PAGES - al.prefix_index_pages


def test_check_catches_refcount_drift():
    """The oracle itself must fail loudly on a corrupted allocator --
    otherwise the 200 green sequences above prove nothing."""
    al = PagedKVAllocator(8, PAGE, 8)
    al.alloc_slot(0, 8)
    al._ref[al.slot_pages(0)[0]] += 1      # simulate a leak
    with pytest.raises(AssertionError):
        al.check()


def test_reclaim_prefers_lru_and_spares_shared():
    """Index-only pages evict LRU-first under pressure; pages a table
    still references are never reclaimed (refcount > 1)."""
    al = PagedKVAllocator(4, PAGE, 4)
    pages = al.alloc_slot(0, 4 * PAGE)     # whole arena
    for i, p in enumerate(pages):
        assert al.publish_prefix(f"k{i}".encode(), p)
    al.free_slot(0)                        # all 4 become index-only
    al.check()
    assert al.free_pages == 0 and al.can_admit(2 * PAGE)
    # k1 is refreshed (MRU); k0 is LRU and must be reclaimed first
    hits = al.match_prefix([b"k1"])
    got = al.alloc_slot(1, PAGE)           # needs 1 page -> reclaims k0
    assert got is not None
    al.check()
    assert al.match_prefix([b"k0"]) == []
    assert al.match_prefix([b"k1"]) == hits
    # CoW-map k1 into a table: now unreclaimable; a full-arena ask fails
    shared = al.alloc_slot_shared(2, 2 * PAGE, hits)
    assert shared is not None
    al.check()
    assert not al.can_admit(3 * PAGE)
