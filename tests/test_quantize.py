"""Gemmini quantized-datapath numerics: bit-exact round/saturate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # container has no hypothesis
    from _hyp import given, settings, strategies as st

from repro.core import quantize as q


@settings(max_examples=200, deadline=None)
@given(x=st.integers(min_value=-(2**30), max_value=2**30),
       shift=st.integers(min_value=0, max_value=20))
def test_rounding_shift_is_round_half_even(x, shift):
    got = int(q.rounding_shift(jnp.int32(x), shift))
    want = int(np.round(x / (2 ** shift)).astype(np.int64)) if shift else x
    # np.round is round-half-even on .5 ties, same convention
    assert got == want


def test_rounding_shift_tie_cases():
    # 2.5 -> 2, 3.5 -> 4 (ties to even), -2.5 -> -2
    assert int(q.rounding_shift(jnp.int32(5), 1)) == 2
    assert int(q.rounding_shift(jnp.int32(7), 1)) == 4
    assert int(q.rounding_shift(jnp.int32(-5), 1)) == -2
    assert int(q.rounding_shift(jnp.int32(-7), 1)) == -4


def test_saturate():
    x = jnp.asarray([300, -300, 127, -128, 0], jnp.int32)
    y = q.saturate(x, jnp.int8)
    assert y.dtype == jnp.int8
    assert list(np.asarray(y)) == [127, -128, 127, -128, 0]


@settings(max_examples=100, deadline=None)
@given(scale=st.floats(min_value=1e-6, max_value=0.9999))
def test_quantize_multiplier_roundtrip(scale):
    mult, shift = q.quantize_multiplier(scale)
    assert (1 << 30) <= mult <= (1 << 31)
    approx = mult * 2.0 ** (-shift)
    assert abs(approx - scale) / scale < 1e-6


@settings(max_examples=100, deadline=None)
@given(acc=st.integers(min_value=-(2**22), max_value=2**22),
       scale=st.floats(min_value=1e-4, max_value=0.5))
def test_fixed_point_rescale_matches_float(acc, scale):
    mult, shift = q.quantize_multiplier(scale)
    got = int(q.fixed_point_rescale(jnp.int32(acc), mult, shift))
    want = acc * scale
    assert abs(got - want) <= 1.0   # within one ulp of the float product


def test_calibrate_quantize_dequantize(rng):
    x = jnp.asarray(rng.standard_normal(1000) * 3, jnp.float32)
    scale = q.calibrate_symmetric(x)
    xq = q.quantize(x, scale)
    xd = q.dequantize(xq, scale)
    assert xq.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(xd - x))) <= scale * 0.5 + 1e-6


def test_fake_quant_straight_through_grad(rng):
    x = jnp.asarray(rng.standard_normal(64), jnp.float32)
    g = jax.grad(lambda v: jnp.sum(q.fake_quant(v, 0.1)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(64), rtol=0)
