"""Golden tests for the static kernel-contract linter (PR 7).

Three layers, three locks:

* **Known-bad contracts** — hand-built `KernelContract`s each carrying
  exactly one defect (including the seed's silently-wrong WS GEMM,
  resurrected as a fixture) must produce exactly their diagnostic.
* **Known-bad source** — `tests/fixtures/bad_kernels.py` is AST-scanned
  (never imported) and must trip every source rule.
* **The repo is clean** — `lint_repo()` over the real kernels and the
  full tuner schedule lattice returns zero findings, so the shipped
  baseline stays empty.
"""

import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import GemminiConfig
from repro.kernels.contracts import (CONTRACT_BUILDERS, DotContract,
                                     KernelContract, OperandSpec, Reduction,
                                     ScratchSpec, dt)
from repro.analysis.lint import (apply_baseline, lint_repo, load_baseline,
                                 write_baseline)
from repro.analysis.lint import affine, checks, feasibility, jit_audit, source
from repro.analysis.lint.affine import Ix, NonAffine, eval_index_map
from repro.analysis.lint.findings import dedupe, finding, to_report

FIXTURE = Path(__file__).parent / "fixtures" / "bad_kernels.py"
F32 = ("float", 4)


def codes(findings):
    return sorted(f.code for f in findings)


# ---------------------------------------------------------------------------
# affine domain
# ---------------------------------------------------------------------------
def test_ix_arithmetic_and_range():
    i = Ix.var("i", 4)
    e = 2 * i + 1
    assert e.range() == (1, 7)
    assert (i - 1).range() == (-1, 2)
    assert (-i).range() == (-3, 0)
    assert e.support == ("i",)


def test_ix_floordiv_contiguity():
    i = Ix.var("i", 8)
    e = i // 2
    assert e.range() == (0, 3)
    assert e.covers(4)            # floor of a contiguous range is contiguous
    assert not e.covers(8)


def test_ix_mixed_radix_coverage():
    i, j = Ix.var("i", 2), Ix.var("j", 3)
    assert (i * 3 + j).covers(6)  # decode's fused b*kvh axis
    assert not (i * 2 + j).covers(6)   # overlapping radix
    assert (i * 3 + j).injective_in(("i", "j"))
    assert not (i + j).injective_in(("i", "j"))


def test_ix_nonaffine_rejections():
    i, j = Ix.var("i", 2), Ix.var("j", 3)
    with pytest.raises(NonAffine):
        _ = i * j
    with pytest.raises(NonAffine):
        _ = i % 2
    with pytest.raises(NonAffine):
        _ = (i + j) // 2          # compound floordiv is not exact
    with pytest.raises(NonAffine):
        Ix.lift(object())


def test_eval_index_map_lifts_scalars():
    grid = (("i", 4), ("j", 2))
    idx = eval_index_map(lambda i, j: (j, 0), grid)
    assert idx[0].support == ("j",) and idx[1] == Ix.lift(0)


# ---------------------------------------------------------------------------
# contract fixtures: one defect, one diagnostic
# ---------------------------------------------------------------------------
def _op(name, shape, block, index_map, **kw):
    return OperandSpec(name=name, shape=shape, block=block,
                       index_map=index_map, **kw)


def test_gl101_out_of_bounds_block_index():
    c = KernelContract(
        name="fix_oob", grid=(("i", 4),), semantics=("parallel",),
        inputs=(_op("a", (512, 128), (128, 128), lambda i: (i + 1, 0)),),
        outputs=(_op("o", (512, 128), (128, 128), lambda i: (i, 0)),))
    assert codes(checks.check_contract(c, GemminiConfig())) == ["GL101"]


def test_gl102_coverage_gap():
    # the grid only writes blocks 0..1 of a 4-block output
    c = KernelContract(
        name="fix_gap", grid=(("i", 2),), semantics=("parallel",),
        inputs=(),
        outputs=(_op("o", (512, 128), (128, 128), lambda i: (i, 0)),))
    assert codes(checks.check_contract(c, GemminiConfig())) == ["GL102"]


def test_gl103_nonaffine_undeclared():
    table = [0, 2, 1, 3]
    c = KernelContract(
        name="fix_gather", grid=(("i", 4),), semantics=("arbitrary",),
        inputs=(_op("a", (512, 128), (128, 128),
                    lambda i: (table[i], 0)),),   # real maps read scalar refs
        outputs=(_op("o", (512, 128), (128, 128), lambda i: (i, 0)),))
    assert "GL103" in codes(checks.check_contract(c, GemminiConfig()))


def test_gl201_parallel_write_race():
    c = KernelContract(
        name="fix_race", grid=(("i", 2), ("kk", 4)),
        semantics=("parallel", "parallel"),
        inputs=(),
        outputs=(_op("o", (256, 128), (128, 128), lambda i, kk: (i, 0)),))
    assert codes(checks.check_contract(c, GemminiConfig())) == ["GL201"]


def test_gl202_undeclared_revisit():
    c = KernelContract(
        name="fix_revisit", grid=(("i", 2), ("kk", 4)),
        semantics=("parallel", "arbitrary"),
        inputs=(),
        outputs=(_op("o", (256, 128), (128, 128), lambda i, kk: (i, 0)),))
    assert codes(checks.check_contract(c, GemminiConfig())) == ["GL202"]


def test_gl203_seed_ws_aliased_accumulation():
    """The resurrected seed bug: the pre-rewrite WS GEMM accumulated
    partial sums through an input/output alias across separated K-step
    revisits — silently wrong for k_steps > 1 (no RAW guarantee through
    an alias). Declaring exactly that pattern must be rejected outright,
    not warned."""
    c = KernelContract(
        name="fix_seed_ws", grid=(("j", 2), ("i", 2), ("kk", 4)),
        semantics=("parallel", "parallel", "arbitrary"),
        inputs=(
            _op("b", (512, 256), (128, 128), lambda j, i, kk: (kk, j)),
            _op("a", (256, 512), (128, 128), lambda j, i, kk: (i, kk)),
            _op("c_in", (256, 256), (128, 128), lambda j, i, kk: (i, j)),
        ),
        outputs=(_op("c", (256, 256), (128, 128), lambda j, i, kk: (i, j)),),
        reductions=(Reduction(out="c", axes=("kk",), via="alias",
                              alias_input="c_in"),),
        io_aliases=((2, 0),))
    fs = checks.check_contract(c, GemminiConfig())
    assert codes(fs) == ["GL203"]
    assert fs[0].severity == "error"
    assert "alias" in fs[0].message


def test_gl203_sound_scratch_pattern_is_clean():
    # same geometry, accumulation via VMEM scratch: the sound rewrite
    c = KernelContract(
        name="fix_ws_ok", grid=(("j", 2), ("i", 2), ("kk", 4)),
        semantics=("parallel", "parallel", "arbitrary"),
        inputs=(
            _op("b", (512, 256), (128, 128), lambda j, i, kk: (kk, j)),
            _op("a", (256, 512), (128, 128), lambda j, i, kk: (i, kk)),
        ),
        outputs=(_op("c", (256, 256), (128, 128), lambda j, i, kk: (i, j)),),
        scratch=(ScratchSpec("acc", (128, 128)),),
        reductions=(Reduction(out="c", axes=("kk",), via="scratch",
                              scratch="acc"),))
    assert checks.check_contract(c, GemminiConfig()) == []


def test_gl204_reduction_names_missing_scratch():
    c = KernelContract(
        name="fix_noscratch", grid=(("i", 2), ("kk", 4)),
        semantics=("parallel", "arbitrary"),
        inputs=(),
        outputs=(_op("o", (256, 128), (128, 128), lambda i, kk: (i, 0)),),
        reductions=(Reduction(out="o", axes=("kk",), via="scratch",
                              scratch="acc"),))
    assert codes(checks.check_contract(c, GemminiConfig())) == ["GL204"]


def test_gl301_streamed_blocks_overflow_scratchpad():
    cfg = GemminiConfig()
    c = KernelContract(
        name="fix_spad", grid=(("kk", 4),), semantics=("arbitrary",),
        inputs=(_op("a", (8192, 2048), (2048, 2048),   # 16 MiB f32 block
                    lambda kk: (kk, 0)),),
        outputs=(_op("o", (1, 1), (1, 1), lambda kk: (0, 0)),),
        scratch=(ScratchSpec("acc", (8, 8)),),
        reductions=(Reduction(out="o", axes=("kk",), via="scratch",
                              scratch="acc"),))
    fs = checks.check_contract(c, cfg)
    assert codes(fs) == ["GL301"]
    assert not checks.fits_budgets(c, cfg)


def test_gl302_resident_plus_scratch_overflow_accumulator():
    cfg = GemminiConfig()
    c = KernelContract(
        name="fix_acc", grid=(("i", 2),), semantics=("parallel",),
        inputs=(),
        outputs=(_op("o", (2048, 1024), (1024, 1024),  # 4 MiB resident
                     lambda i: (i, 0)),),
        scratch=(ScratchSpec("acc", (1024, 1024)),))   # + 4 MiB scratch
    fs = checks.check_contract(c, cfg)
    assert codes(fs) == ["GL302"]
    assert not checks.fits_budgets(c, cfg)


def test_gl401_narrow_dot_needs_wide_accumulator():
    base = dict(grid=(("i", 1),), semantics=("parallel",), inputs=(),
                outputs=(_op("o", (8, 8), (8, 8), lambda i: (0, 0)),))
    bad = KernelContract(
        name="fix_dot", dots=(DotContract(dt("bf16"), dt("bf16"),
                                          dt("bf16")),), **base)
    assert codes(checks.check_contract(bad, GemminiConfig())) == ["GL401"]
    # int8 x int8 -> f32 is also wrong (kind mismatch) ...
    kind = KernelContract(
        name="fix_dot2", dots=(DotContract(dt("int8"), dt("int8"),
                                           dt("fp32")),), **base)
    assert codes(checks.check_contract(kind, GemminiConfig())) == ["GL401"]
    # ... while the two sound pairings pass.
    ok = KernelContract(
        name="fix_dot3", dots=(DotContract(dt("bf16"), dt("bf16"),
                                           dt("fp32")),
                               DotContract(dt("int8"), dt("int8"),
                                           dt("int32")),), **base)
    assert checks.check_contract(ok, GemminiConfig()) == []


def test_gl402_scalar_block_not_in_smem():
    c = KernelContract(
        name="fix_smem", grid=(("i", 1),), semantics=("parallel",),
        inputs=(_op("lens", (1,), (1,), lambda i: (0,)),),
        outputs=(_op("o", (8, 8), (8, 8), lambda i: (0, 0)),))
    fs = checks.check_contract(c, GemminiConfig())
    assert codes(fs) == ["GL402"] and fs[0].severity == "warning"
    smem = KernelContract(
        name="fix_smem2", grid=(("i", 1),), semantics=("parallel",),
        inputs=(_op("lens", (1,), (1,), lambda i: (0,),
                    memory_space="smem"),),
        outputs=(_op("o", (8, 8), (8, 8), lambda i: (0, 0)),))
    assert checks.check_contract(smem, GemminiConfig()) == []


# ---------------------------------------------------------------------------
# fingerprints, dedupe, baseline
# ---------------------------------------------------------------------------
def test_fingerprint_stable_across_instantiations():
    c = KernelContract(
        name="fix_gap", grid=(("i", 2),), semantics=("parallel",),
        inputs=(),
        outputs=(_op("o", (512, 128), (128, 128), lambda i: (i, 0)),))
    a = checks.check_contract(c, GemminiConfig(), inst="t128")
    b = checks.check_contract(c, GemminiConfig(), inst="t256")
    assert a[0].fingerprint == b[0].fingerprint   # inst stays out of the fp
    assert dict(a[0].data)["instantiation"] == "t128"
    merged = dedupe(a + b)
    assert len(merged) == 1
    assert dict(merged[0].data)["occurrences"] == 2


def test_baseline_roundtrip(tmp_path):
    f1 = finding("GL102", "error", "contract:x", "msg", key="o:0")
    f2 = finding("GL501", "error", "k.py::f", "msg2")
    path = tmp_path / "baseline.json"
    write_baseline(path, [f1])
    bl = load_baseline(path)
    assert f1.fingerprint in bl
    new, suppressed = apply_baseline([f1, f2], bl)
    assert [f.code for f in new] == ["GL501"]
    assert [f.code for f in suppressed] == ["GL102"]
    assert load_baseline(tmp_path / "missing.json") == {}
    rep = to_report(new, suppressed=suppressed)
    assert rep["counts"] == {"error": 1, "warning": 0, "info": 0,
                             "total": 1, "suppressed": 1}


# ---------------------------------------------------------------------------
# source rules over the known-bad fixture (AST only, never imported)
# ---------------------------------------------------------------------------
def test_fixture_trips_every_source_rule():
    fs = source.check_kernel_file(FIXTURE)
    got = codes(fs)
    assert got.count("GL501") == 2        # unannotated + unregistered
    for code in ("GL502", "GL503", "GL504", "GL505"):
        assert code in got, f"{code} missing from {got}"
    shim = source.check_shim_ban([FIXTURE])
    assert codes(shim) == ["GL506"]
    assert "_deprecated_shim" in shim[0].message


def test_gl506_legacy_toplevel_name_in_ops(tmp_path):
    ops = tmp_path / "src" / "repro" / "kernels" / "ops.py"
    ops.parent.mkdir(parents=True)
    ops.write_text("def gemm(a, b):\n    return a\n"
                   "matmul = gemm\n"
                   "def gemm_impl(a, b):\n    return a\n")
    fs = source.check_shim_ban([ops])
    assert codes(fs) == ["GL506", "GL506"]         # gemm + matmul; not *_impl
    assert {f.key for f in fs} == {"gemm", "matmul"}


def test_real_kernels_are_annotated():
    # every launcher carries a registered contract (the GL501 invariant)
    import repro.kernels.gemm as g
    import repro.kernels.attention as att
    for fn in (g.gemm_os, g.gemm_ws, g.accumulator_epilogue,
               att.flash_attention, att.decode_attention):
        assert fn.__lint_contract__ in CONTRACT_BUILDERS


# ---------------------------------------------------------------------------
# the repo itself is lint-clean (satellite: fix findings, don't baseline)
# ---------------------------------------------------------------------------
def test_repo_is_lint_clean():
    fs = lint_repo()
    assert fs == [], "\n".join(
        f"{f.code} {f.site}: {f.message}" for f in fs)


def test_shipped_baseline_is_empty():
    path = Path(__file__).resolve().parents[1] / "tools" / "lint_baseline.json"
    assert load_baseline(path) == {}


def test_cli_json_gate(tmp_path):
    from repro.analysis.lint.__main__ import main
    out = tmp_path / "lint.json"
    rc = main(["--no-baseline", "--format", "json", "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["counts"]["total"] == 0 and rep["schema"] == 1


# ---------------------------------------------------------------------------
# tuner feasibility hook
# ---------------------------------------------------------------------------
def test_default_schedules_are_feasible():
    from repro.core.tiling import plan_gemm
    from repro.tune import schedules
    cfg = GemminiConfig()
    plan = plan_gemm(cfg, 512, 512, 512)
    assert feasibility.gemm_plan_feasible(cfg, plan, has_bias=True)
    assert feasibility.attn_schedule_feasible(
        cfg, schedules.default_attn_schedule(), b=2, h=8, kvh=2,
        tq=1024, tk=1024, d=128)
    assert feasibility.paged_schedule_feasible(
        cfg, schedules.default_paged_schedule(), b=4, h=8, kvh=2, d=128,
        max_context=2048)
    assert feasibility.conv_schedule_feasible(
        cfg, schedules.default_conv_schedule(), n=2, h=16, w=16, ci=64,
        co=256, kh=3, kw=3, padding=1)


def test_feasibility_is_total_on_garbage():
    cfg = GemminiConfig()
    assert feasibility.gemm_plan_feasible(cfg, object()) is False
    assert feasibility.conv_schedule_feasible(
        cfg, object(), n=1, h=1, w=1, ci=1, co=1, kh=1, kw=1) is False


def test_contract_filter_always_keeps_reference():
    from repro.tune.tuner import _contract_filter
    cands = ["default", "a", "b"]
    kept = _contract_filter(cands, lambda c: c == "default",
                            lambda c: False)
    assert kept == ["default"]            # reference survives a veto of all
    kept = _contract_filter(cands, lambda c: False, lambda c: c != "a")
    assert kept == ["default", "b"]
    # a predicate that raises keeps the candidate (advisory, never fatal)
    kept = _contract_filter(cands, lambda c: False,
                            lambda c: (_ for _ in ()).throw(RuntimeError()))
    assert kept == cands
    # filtering to nothing falls back to the original lattice
    kept = _contract_filter(["a", "b"], lambda c: False, lambda c: False)
    assert kept == ["a", "b"]


# ---------------------------------------------------------------------------
# trace-time jit audit
# ---------------------------------------------------------------------------
from repro.models import transformer as tf          # noqa: E402
from repro.serving import ServingEngine             # noqa: E402

_TINY = tf.ModelConfig(name="tiny-lint", family="dense", n_layers=1,
                       d_model=32, vocab=64, n_heads=2, n_kv_heads=1,
                       head_dim=16, d_ff=64, dtype=jnp.float32)


def _engine(**kw):
    return ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                         n_pages=8, temperature=0.0, seed=0,
                         backend="interpret", prefill_chunk=8, **kw)


def test_bucket_census_geometry():
    eng = _engine()
    census = jit_audit.expected_bucket_census(eng)
    assert census["prefill"] == 32 // eng.prefill_pad
    assert census["decode"] == 1
    # chunk lengths x (kv_pages values + the None fallback)
    assert census["chunk"] == (32 // 8) * (eng.max_pages_per_seq + 1)


def test_fresh_engine_audits_clean_and_run_stays_in_census():
    eng = _engine()
    assert eng.audit() == []
    rng = np.random.default_rng(0)
    eng.submit(rng.integers(0, 64, (5,), dtype=np.int32), 3)
    eng.submit(rng.integers(0, 64, (11,), dtype=np.int32), 3)
    eng.run()
    assert eng.audit() == []              # real traffic stays inside census
    stats = eng.jit_cache_stats()
    assert stats and all(isinstance(v, int) for v in stats.values())
    census = jit_audit.expected_bucket_census(eng)
    for which, seen in eng.observed_buckets.items():
        assert len(seen) <= census[which]


def test_gl601_dispatched_bucket_explosion():
    eng = _engine()
    # simulate an unquantized argument leaking into the trace: more
    # distinct prefill bucket keys than prompt-length quantization allows
    eng.observed_buckets["prefill"] = {(n,) for n in range(1, 64)}
    fs = eng.audit()
    assert codes(fs) == ["GL601"]
    assert dict(fs[0].data)["expected"] == 32 // eng.prefill_pad


def test_gl602_post_donation_reuse():
    x = jnp.arange(4)
    x.delete()                            # stand-in for a donated buffer
    fs = jit_audit.audit_donation({"state": {"w": x, "ok": jnp.arange(2)}})
    assert codes(fs) == ["GL602"]
    assert "w" in fs[0].key
