"""Minimal deterministic stand-in for ``hypothesis`` when it isn't installed.

The container this repo tests in does not ship hypothesis and cannot pip
install it, so the property tests fall back to this shim: each strategy can
produce boundary examples plus seeded-pseudorandom draws, and ``@given``
expands into a deterministic loop over ``max_examples`` drawn example sets.
The API surface is exactly what this repo's tests use: ``given`` with keyword
strategies, ``settings(max_examples=, deadline=)``, and
``strategies.{integers,floats,booleans,sampled_from}``.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib


class _Strategy:
    def __init__(self, draw, boundary=()):
        self._draw = draw
        self._boundary = tuple(boundary)

    def example(self, index: int, rnd: random.Random):
        if index < len(self._boundary):
            return self._boundary[index]
        return self._draw(rnd)


class strategies:  # noqa: N801 - mimics the hypothesis module name
    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(lambda r: r.randint(min_value, max_value),
                         boundary=(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value),
                         boundary=(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: bool(r.getrandbits(1)),
                         boundary=(False, True))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: r.choice(seq), boundary=seq[:2])


st = strategies


class settings:  # noqa: N801
    def __init__(self, max_examples=20, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._hyp_max_examples = self.max_examples
        return fn


def given(**strategy_kw):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hyp_max_examples", 20)
            # crc32, not hash(): builtin str hashing is salted per process,
            # which would make "deterministic" draws differ across runs.
            fn_seed = zlib.crc32(fn.__name__.encode())
            for i in range(n):
                rnd = random.Random(0xC0FFEE + 1013 * i + fn_seed)
                drawn = {name: s.example(i, rnd)
                         for name, s in strategy_kw.items()}
                fn(*args, **kwargs, **drawn)

        # Hide the strategy-driven parameters from pytest's fixture resolution
        # (real hypothesis does the same signature surgery).
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in strategy_kw]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper

    return deco
