"""Sharding rules + miniature dry-runs (multi-device via subprocess).

The production 512-device dry-run is exercised by launch/dryrun.py; here we
lower representative cells on an 8-device mesh so the sharding rules are
covered by the regular test suite.
"""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import sharding as shd
from repro.launch import steps as steps_lib


class _FakeMesh:
    """Shape-only mesh stand-in for pure spec tests (no devices needed)."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


@pytest.mark.parametrize("arch", configs.names())
def test_param_specs_divisible(arch):
    """Every sharded dim must be divisible by its mesh axis for the real
    (16, 16) mesh -- the guarantee the dry-run relies on."""
    mesh = _FakeMesh(data=16, model=16)
    cfg = configs.get(arch)
    pshapes = steps_lib.param_shapes(cfg)
    specs = shd.param_specs(pshapes, mesh)

    def check(path, leaf, spec):
        for dim, ax in enumerate(tuple(spec)):
            if ax is None:
                continue
            n = mesh.shape[ax] if isinstance(ax, str) else \
                int(jnp.prod(jnp.asarray([mesh.shape[a] for a in ax])))
            assert leaf.shape[dim] % n == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), pshapes, specs)


@pytest.mark.parametrize("arch", configs.names())
def test_opt_specs_divisible_multipod(arch):
    mesh = _FakeMesh(pod=2, data=16, model=16)
    cfg = configs.get(arch)
    pshapes = steps_lib.param_shapes(cfg)
    specs = shd.opt_state_specs(pshapes, mesh)

    def size_of(ax):
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= mesh.shape[a]
            return n
        return mesh.shape[ax]

    def check(path, leaf, spec):
        for dim, ax in enumerate(tuple(spec)):
            if ax is not None:
                assert leaf.shape[dim] % size_of(ax) == 0, \
                    (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), pshapes, specs["m"])


def test_attention_heads_sharded_for_llava():
    """llava 56 heads on model=16: head dim not divisible -> the rule must
    fall back to another dim or replicate, never crash."""
    mesh = _FakeMesh(data=16, model=16)
    cfg = configs.get("llava-next-34b")
    pshapes = steps_lib.param_shapes(cfg)
    specs = shd.param_specs(pshapes, mesh)
    wq_spec = specs["blocks"]["attn"]["wq"]
    # (L, d_model, H*Dh) = (60, 7168, 7168): last dim 7168 % 16 == 0
    assert tuple(wq_spec)[-1] == "model"


def test_mini_dryrun_train_and_decode(run_subprocess):
    """Lower + compile a train cell and a decode cell on a (2, 4) mesh."""
    code = """
import jax
from repro import configs
from repro.launch.mesh import activate_mesh, make_mesh
from repro.core.config import GemminiConfig
from repro.core.generator import elaborate
from repro.launch import steps as steps_lib
from repro.optim import adamw

mesh = make_mesh((2, 4), ("data", "model"))
engine = elaborate(GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                 output_dtype="bf16"), "xla")
for arch, shape in [("gemma3-1b", "train_4k"), ("mamba2-1.3b", "decode_32k"),
                    ("granite-moe-3b-a800m", "train_4k")]:
    cfg = configs.get_smoke(arch)
    # shrink the cell: tiny batch/seq but the real step + sharding pipeline
    steps_lib.SHAPES["train_4k"] = dict(kind="train", seq=64, batch=8)
    steps_lib.SHAPES["decode_32k"] = dict(kind="decode", seq=256, batch=8)
    spec = steps_lib.input_specs(cfg, shape, mesh)
    with activate_mesh(mesh):
        if spec["kind"] == "train":
            fn = steps_lib.make_train_step(engine, cfg, adamw.AdamWConfig(),
                                           mesh, batch=spec["batch"],
                                           seq=spec["seq"])
        else:
            fn = steps_lib.make_serve_step(engine, cfg, mesh,
                                           batch=spec["batch"],
                                           max_seq=spec["seq"])
        compiled = jax.jit(fn).lower(*spec["args"]).compile()
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        assert ca["flops"] > 0
    print("OK", arch, shape)
print("MINI DRYRUN OK")
"""
    out = run_subprocess(code, n_devices=8, timeout=480)
    assert "MINI DRYRUN OK" in out


def test_pipeline_parallel_stage_loop(run_subprocess):
    """GPipe stage loop: fwd + grad == sequential (4 stages)."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.pipeline import pipeline_apply, split_stages
from repro.launch.mesh import activate_mesh, make_mesh

mesh = make_mesh((4,), ("stage",))
rng = np.random.default_rng(0)
L, D = 8, 32
w = jnp.asarray(rng.standard_normal((L, D, D)) * 0.1, jnp.float32)

def stage_fn(wp, h):
    h, _ = jax.lax.scan(lambda h, wl: (jnp.tanh(h @ wl), None), h, wp)
    return h

x = jnp.asarray(rng.standard_normal((6, 4, D)), jnp.float32)
stages = split_stages(w, 4)

def ploss(w_st, x):
    return jnp.sum(pipeline_apply(stage_fn, w_st, x, mesh=mesh) ** 2)

with activate_mesh(mesh):
    y = pipeline_apply(stage_fn, stages, x, mesh=mesh)
    g1 = jax.grad(ploss)(stages, x).reshape(L, D, D)

def seq(xx):
    h = xx
    for l in range(L):
        h = jnp.tanh(h @ w[l])
    return h
yr = jax.vmap(seq)(x)
g2 = jax.grad(lambda wf, x: jnp.sum(jax.vmap(
    lambda xx: jax.lax.scan(lambda h, wl: (jnp.tanh(h @ wl), None),
                            xx, wf)[0])(x) ** 2))(w, x)
assert float(jnp.max(jnp.abs(y - yr))) < 1e-5
assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-4
print("PP OK")
"""
    out = run_subprocess(code, n_devices=4)
    assert "PP OK" in out
