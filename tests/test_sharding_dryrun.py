"""Sharding rules + miniature dry-runs (multi-device via subprocess).

The production 512-device dry-run is exercised by launch/dryrun.py; here we
lower representative cells on an 8-device mesh so the sharding rules are
covered by the regular test suite.
"""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import sharding as shd
from repro.launch import steps as steps_lib


class _FakeMesh:
    """Shape-only mesh stand-in for pure spec tests (no devices needed)."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


@pytest.mark.parametrize("arch", configs.names())
def test_param_specs_divisible(arch):
    """Every sharded dim must be divisible by its mesh axis for the real
    (16, 16) mesh -- the guarantee the dry-run relies on."""
    mesh = _FakeMesh(data=16, model=16)
    cfg = configs.get(arch)
    pshapes = steps_lib.param_shapes(cfg)
    specs = shd.param_specs(pshapes, mesh)

    def check(path, leaf, spec):
        for dim, ax in enumerate(tuple(spec)):
            if ax is None:
                continue
            n = mesh.shape[ax] if isinstance(ax, str) else \
                int(jnp.prod(jnp.asarray([mesh.shape[a] for a in ax])))
            assert leaf.shape[dim] % n == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), pshapes, specs)


@pytest.mark.parametrize("arch", configs.names())
def test_opt_specs_divisible_multipod(arch):
    mesh = _FakeMesh(pod=2, data=16, model=16)
    cfg = configs.get(arch)
    pshapes = steps_lib.param_shapes(cfg)
    specs = shd.opt_state_specs(pshapes, mesh)

    def size_of(ax):
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= mesh.shape[a]
            return n
        return mesh.shape[ax]

    def check(path, leaf, spec):
        for dim, ax in enumerate(tuple(spec)):
            if ax is not None:
                assert leaf.shape[dim] % size_of(ax) == 0, \
                    (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), pshapes, specs["m"])


def test_attention_heads_sharded_for_llava():
    """llava 56 heads on model=16: head dim not divisible -> the rule must
    fall back to another dim or replicate, never crash."""
    mesh = _FakeMesh(data=16, model=16)
    cfg = configs.get("llava-next-34b")
    pshapes = steps_lib.param_shapes(cfg)
    specs = shd.param_specs(pshapes, mesh)
    wq_spec = specs["blocks"]["attn"]["wq"]
    # (L, d_model, H*Dh) = (60, 7168, 7168): last dim 7168 % 16 == 0
    assert tuple(wq_spec)[-1] == "model"


@pytest.mark.slow
def test_mini_dryrun_train_and_decode(run_subprocess):
    """Lower + compile a train cell and a decode cell on a (2, 4) mesh."""
    code = """
import jax
from repro import configs
from repro.launch.mesh import activate_mesh, make_mesh
from repro.core.config import GemminiConfig
from repro.core.generator import elaborate
from repro.launch import steps as steps_lib
from repro.optim import adamw

mesh = make_mesh((2, 4), ("data", "model"))
engine = elaborate(GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                 output_dtype="bf16"), "xla")
for arch, shape in [("gemma3-1b", "train_4k"), ("mamba2-1.3b", "decode_32k"),
                    ("granite-moe-3b-a800m", "train_4k")]:
    cfg = configs.get_smoke(arch)
    # shrink the cell: tiny batch/seq but the real step + sharding pipeline
    steps_lib.SHAPES["train_4k"] = dict(kind="train", seq=64, batch=8)
    steps_lib.SHAPES["decode_32k"] = dict(kind="decode", seq=256, batch=8)
    spec = steps_lib.input_specs(cfg, shape, mesh)
    with activate_mesh(mesh):
        if spec["kind"] == "train":
            fn = steps_lib.make_train_step(engine, cfg, adamw.AdamWConfig(),
                                           mesh, batch=spec["batch"],
                                           seq=spec["seq"])
        else:
            fn = steps_lib.make_serve_step(engine, cfg, mesh,
                                           batch=spec["batch"],
                                           max_seq=spec["seq"])
        compiled = jax.jit(fn).lower(*spec["args"]).compile()
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        assert ca["flops"] > 0
    print("OK", arch, shape)
print("MINI DRYRUN OK")
"""
    out = run_subprocess(code, n_devices=8, timeout=480)
    assert "MINI DRYRUN OK" in out


def test_sharded_context_parity_and_warm(run_subprocess):
    """Acceptance for the ExecutionContext mesh-aware dispatch:

    1. a jit+GSPMD step whose ops dispatch through
       ``ExecutionContext(mesh=...)`` runs the *interpret* engine path
       (real Pallas kernel bodies) under shard_map, resolving plans at
       PER-DEVICE shapes, and bit-exactly matches the single-host context
       on a forced-8-device CPU;
    2. ``warm_model_plans(n_shards=8)`` then a sharded model forward
       ("serve") reports 0 plan-cache misses -- warm-vs-serve fingerprint
       parity when resolution happens inside shard_map.
    """
    code = """
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs, tune
from repro.core import flags
from repro.core.config import GemminiConfig
from repro.core.context import ExecutionContext
from repro.core.generator import elaborate
from repro.launch.mesh import activate_mesh, make_mesh
from repro.models import transformer as tfm

assert jax.device_count() == 8
mesh = make_mesh((8,), ("data",))
cfg = GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                    output_dtype="bf16")
ctx = ExecutionContext(cfg=cfg, backend="interpret", mesh=mesh, axis="data")
assert ctx.sharded and ctx.n_shards == 8
single = ctx.unsharded()

# ---- 1. jit+GSPMD step: sharded ctx == single-host ctx, bit-exact ------
rng = np.random.default_rng(0)
B, T, D, FF, H, KVH, HD = 8, 16, 64, 128, 4, 2, 16
x  = jnp.asarray(rng.standard_normal((B, T, D)), jnp.bfloat16)
w1 = jnp.asarray(rng.standard_normal((D, FF)) * 0.1, jnp.bfloat16)
w2 = jnp.asarray(rng.standard_normal((FF, D)) * 0.1, jnp.bfloat16)
q  = jnp.asarray(rng.standard_normal((B, T, H, HD)), jnp.bfloat16)
k  = jnp.asarray(rng.standard_normal((B, T, KVH, HD)), jnp.bfloat16)
v  = jnp.asarray(rng.standard_normal((B, T, KVH, HD)), jnp.bfloat16)

def step(c, x, w1, w2, q, k, v):
    h = c.matmul(x, w1)                     # engine GEMM (per-device M)
    h = c.matmul(h, w2)
    o = c.flash_attention(q, k, v, causal=True)
    return h, o

bsh = NamedSharding(mesh, P("data"))
rep = NamedSharding(mesh, P())
xs, qs, ks, vs = (jax.device_put(a, bsh) for a in (x, q, k, v))
w1s, w2s = jax.device_put(w1, rep), jax.device_put(w2, rep)
with activate_mesh(mesh):
    h_sh, o_sh = jax.jit(lambda *a: step(ctx, *a))(xs, w1s, w2s, qs, ks, vs)
h_1, o_1 = step(single, x, w1, w2, q, k, v)
assert np.array_equal(np.asarray(h_sh, np.float32),
                      np.asarray(h_1, np.float32)), "gemm parity"
assert np.array_equal(np.asarray(o_sh, np.float32),
                      np.asarray(o_1, np.float32)), "attention parity"
print("PARITY OK")

# ---- 2. warm(n_shards=8) then sharded serve: 0 plan-cache misses -------
tmp = tempfile.mkdtemp()
flags.set_flag("tune_cache", os.path.join(tmp, "plans.json"))
from repro.tune import cache as tcache
tcache.reset_cache()
model_cfg = configs.get_smoke("qwen1.5-4b")   # qkv_bias: bias fingerprints
flags.set_flag("tune_mode", "full")
stats = tune.warm_model_plans(cfg, model_cfg, batch=B, seq=T,
                              n_shards=8, include_decode=False)
assert stats["cache_misses"] > 0              # cold cache: warm tuned it
flags.set_flag("tune_mode", "cached")
pc = tcache.get_cache()
h0, m0 = pc.hits, pc.misses
engine = elaborate(cfg, "interpret").with_mesh(mesh)
params = tfm.init_params(jax.random.PRNGKey(0), model_cfg)
toks = jax.device_put(jnp.zeros((B, T), jnp.int32), bsh)
with activate_mesh(mesh):
    logits = jax.jit(
        lambda p, t: tfm.forward(engine, p, model_cfg, t))(params, toks)
assert bool(jnp.all(jnp.isfinite(jnp.asarray(logits, jnp.float32))))
assert pc.misses == m0, f"sharded serve missed {pc.misses - m0} schedules"
assert pc.hits > h0
print("WARM OK", pc.hits - h0, "hits")
print("SHARDED CONTEXT OK")
"""
    out = run_subprocess(code, n_devices=8, timeout=480)
    assert "PARITY OK" in out
    assert "WARM OK" in out
    assert "SHARDED CONTEXT OK" in out


def test_pipeline_parallel_stage_loop(run_subprocess):
    """GPipe stage loop: fwd + grad == sequential (4 stages)."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.pipeline import pipeline_apply, split_stages
from repro.launch.mesh import activate_mesh, make_mesh

mesh = make_mesh((4,), ("stage",))
rng = np.random.default_rng(0)
L, D = 8, 32
w = jnp.asarray(rng.standard_normal((L, D, D)) * 0.1, jnp.float32)

def stage_fn(wp, h):
    h, _ = jax.lax.scan(lambda h, wl: (jnp.tanh(h @ wl), None), h, wp)
    return h

x = jnp.asarray(rng.standard_normal((6, 4, D)), jnp.float32)
stages = split_stages(w, 4)

def ploss(w_st, x):
    return jnp.sum(pipeline_apply(stage_fn, w_st, x, mesh=mesh) ** 2)

with activate_mesh(mesh):
    y = pipeline_apply(stage_fn, stages, x, mesh=mesh)
    g1 = jax.grad(ploss)(stages, x).reshape(L, D, D)

def seq(xx):
    h = xx
    for l in range(L):
        h = jnp.tanh(h @ w[l])
    return h
yr = jax.vmap(seq)(x)
g2 = jax.grad(lambda wf, x: jnp.sum(jax.vmap(
    lambda xx: jax.lax.scan(lambda h, wl: (jnp.tanh(h @ wl), None),
                            xx, wf)[0])(x) ** 2))(w, x)
assert float(jnp.max(jnp.abs(y - yr))) < 1e-5
assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-4
print("PP OK")
"""
    out = run_subprocess(code, n_devices=4)
    assert "PP OK" in out
