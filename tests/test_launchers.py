"""End-to-end launcher integration: train (with failure injection +
checkpoint restart) and serve, run as real CLI subprocesses."""

import os
import subprocess
import sys

import pytest

from tests.conftest import REPO, SRC


def _run(args, timeout=480):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-m"] + args, env=env,
                       capture_output=True, text=True, timeout=timeout,
                       cwd=REPO)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.mark.slow
def test_train_with_failure_and_restart(tmp_path):
    out = _run(["repro.launch.train", "--arch", "gemma3-1b", "--smoke",
                "--steps", "10", "--batch", "4", "--seq", "64",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
                "--log-every", "5", "--fail-at", "6"])
    assert "FAILURE (attempt 0): injected failure at step 6" in out
    assert "restored checkpoint step=4" in out
    assert "done: 10 steps" in out
    assert "attempts=2" in out


@pytest.mark.slow
def test_train_moe_arch(tmp_path):
    out = _run(["repro.launch.train", "--arch", "granite-moe-3b-a800m",
                "--smoke", "--steps", "4", "--batch", "4", "--seq", "32",
                "--log-every", "2"])
    assert "done: 4 steps" in out


def test_serve_ssm(tmp_path):
    out = _run(["repro.launch.serve", "--arch", "mamba2-1.3b", "--smoke",
                "--batch", "2", "--prompt-len", "16", "--gen", "4"])
    assert "out shape (2, 4)" in out


def test_serve_multicodebook(tmp_path):
    out = _run(["repro.launch.serve", "--arch", "musicgen-medium",
                "--smoke", "--batch", "2", "--prompt-len", "8",
                "--gen", "3"])
    assert "out shape (2, 3, 4)" in out
